"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps on synthetic data, with checkpointing and (simulated) fault recovery.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
(CPU-sized by default; pass --full-width to use a true ~100M config.)
"""
import argparse
import tempfile

import numpy as np

from repro.configs import ARCHS, SHAPE_CELLS, reduced
from repro.core.costmodel import CostModel
from repro.launch.mesh import make_host_mesh
from repro.models.zoo import build_model
from repro.sharding.plans import rank_plans
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-width", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--arch", default="internlm2-20b")
    args = ap.parse_args()

    base = ARCHS[args.arch]
    if args.full_width:
        cfg = base.replace(n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
                           head_dim=64, d_ff=2048, vocab_size=32000,
                           microbatch=4, attn_chunk=128)
    else:
        cfg = reduced(base, n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512)
    model = build_model(cfg)
    mesh = make_host_mesh()
    cost_model = CostModel.from_named("tpu_v5e")

    # what mesh WOULD the cost model pick at production scale for this arch?
    plans = rank_plans(base, SHAPE_CELLS["train_4k"], n_devices=256,
                       cost_model=cost_model)
    print(f"cost-model mesh ranking for {base.name} @ 256 chips "
          f"(best first):")
    for p in plans[:3]:
        print(f"  {p.describe()}")

    with tempfile.TemporaryDirectory() as ckpt:
        # phase 1: train halfway, checkpointing (predicted-vs-measured step
        # time rides along in the metrics via the cost model)
        half = args.steps // 2
        r1 = train(model, mesh, num_steps=half, global_batch=8, seq_len=64,
                   ckpt_dir=ckpt, ckpt_every=max(half // 2, 1), lr=3e-3,
                   cost_model=cost_model,
                   hooks=[lambda s, m: print(
                       f"step {s:4d} loss {float(m['loss']):.3f} "
                       f"measured {m['measured_step_s']:.3f}s "
                       f"(predicted {m['predicted_step_s']:.2e}s on v5e)")
                          if s % 20 == 0 else None])
        # phase 2: "crash" and resume from the checkpoint
        print(f"--- simulated failure; restarting from checkpoint ---")
        r2 = train(model, mesh, num_steps=args.steps, global_batch=8,
                   seq_len=64, ckpt_dir=ckpt, ckpt_every=50, lr=3e-3,
                   hooks=[lambda s, m: print(f"step {s:4d} loss "
                                             f"{float(m['loss']):.3f}")
                          if s % 20 == 0 else None])
        assert r2.restored_from == half, r2.restored_from
        print(f"resumed from step {r2.restored_from}; "
              f"loss {np.mean(r1.losses[:5]):.3f} -> {r2.final_loss:.3f}")
        assert r2.final_loss < np.mean(r1.losses[:5])
        print("train_tiny_lm OK")


if __name__ == "__main__":
    main()
