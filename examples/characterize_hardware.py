"""Reproduce the paper's experiment suite on the current backend: per-op
latency tables (dependent/independent), the memory-hierarchy chase, and
matrix-unit probes; then diff the result against the shipped calibrations.

This is the paper-as-a-tool: on a real TPU the emitted table refreshes
repro/core/calibration/tpu_v5e.json; on CPU it characterizes the host.

Run:  PYTHONPATH=src python examples/characterize_hardware.py
"""
import json

import jax

from repro.core.microbench.tables import ampere_table, calibrate, v5e_table


def main():
    print(f"backend: {jax.default_backend()}")
    table = calibrate(quick=True)

    print("\n== per-op latency (ns, steady state) ==")
    for k, v in sorted(table["ops"].items()):
        if k.endswith(".dep") or k.endswith(".ind"):
            print(f"  {k:28s} {v['per_op_ns']:10.2f}  "
                  f"(overhead {v['overhead_ns']:.0f}ns)")

    print("\n== memory hierarchy (pointer chase, ns/hop) ==")
    for size, v in table["memory"].items():
        print(f"  {int(size)//1024:8d} KiB   {v['per_hop_ns']:8.1f}")

    print("\n== matrix unit ==")
    for k, v in table["mxu"].items():
        print(f"  {k:32s} {v['per_op_us']:8.2f}us  {v['tflops']:8.3f} TFLOP/s")

    print("\n== reference tables shipped with the repo ==")
    a100 = ampere_table()
    print(f"  ampere_a100: {len(a100['instructions'])} instruction rows, "
          f"{len(a100['tensor_core'])} tensor-core rows "
          f"(the paper's Tables II-V)")
    v5e = v5e_table()
    print(f"  tpu_v5e: {len(v5e['vpu'])} VPU rows, "
          f"MXU bf16 peak {v5e['mxu']['bf16.f32']['peak_tflops']} TFLOP/s")
    out = "results/host_calibration.json"
    import pathlib
    pathlib.Path("results").mkdir(exist_ok=True)
    pathlib.Path(out).write_text(json.dumps(table, indent=1))
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
