"""Reproduce the paper's experiment suite on the current backend via the
campaign runner, then feed the measured table straight into the unified
cost model — the paper-as-a-tool, end to end:

  1. run the calibration campaigns (resumable; results persist under
     results/campaign/),
  2. normalize the measured table into the three cost-model layers
     (instruction / memory / MXU) and print them,
  3. validate: round-trip every measured row through the layers
     (the prediction-error table; must stay ~0%),
  4. price a real compiled module on THIS host's numbers vs the shipped
     calibrations (the close-the-loop step the follow-on dissection papers
     run against their analytical models),
  5. tune: feed the measured cost model to the kernel autotuner and print
     default-vs-tuned predicted step time for every tunable Pallas kernel
     (the measure -> model -> tune loop, closed).

On a real TPU the emitted table refreshes repro/core/calibration/
tpu_v5e.json; on CPU it characterizes the host.

Run:  PYTHONPATH=src python examples/characterize_hardware.py [--full]
"""
import argparse
import json
import pathlib

import jax

from repro.core.costmodel import (CostModel, prediction_error_rows,
                                  prediction_error_summary, save_calibration)
from repro.core.microbench.tables import calibrate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full grids instead of the quick sweep")
    ap.add_argument("--results-dir", default="results/campaign")
    args = ap.parse_args(argv)

    print(f"backend: {jax.default_backend()}")
    table = calibrate(quick=not args.full, results_dir=args.results_dir)

    # ---- 2. the measured table, as cost-model layers -------------------------
    host = CostModel.from_table(table, name="host")
    print("\n== instruction layer (measured cycles @ "
          f"{host.cal.clock_hz / 1e6:.0f} MHz assumed clock) ==")
    for key, e in sorted(host.cal.instructions.items()):
        print(f"  {key:16s} dep={e.dependent_cycles:10.1f}  "
              f"ind={e.independent_cycles:10.1f}")
    print("\n== memory layer ==")
    for lvl in host.memory.levels:
        print(f"  {lvl.name:12s} <= {int(lvl.capacity_bytes) // 1024:8d} KiB"
              f"   {lvl.latency_ns:10.1f} ns/access")
    print(f"  streaming bandwidth {host.memory.bandwidth_bps / 1e9:10.2f} GB/s")
    print("\n== mxu layer ==")
    for (dt, shape, dep), p in sorted(host.mxu.points.items(),
                                      key=lambda kv: str(kv[0])):
        tag = "dep" if dep else "ind"
        print(f"  {dt:6s} {str(shape):18s} {tag}  "
              f"{p.flops_per_s / 1e12:8.3f} TFLOP/s")

    # ---- 3. validate: measured rows round-trip through the layers ------------
    errs = prediction_error_rows(host)
    s = prediction_error_summary(errs)
    print(f"\n== prediction-error fixture ==\n  {s['rows']} rows, "
          f"max {s['max_err_pct']:.2f}% / mean {s['mean_err_pct']:.2f}% "
          "(measured table vs its own layers)")

    # ---- 4. price one real compiled module, host vs shipped ------------------
    x = jax.numpy.ones((256, 256), jax.numpy.float32)
    fn = jax.jit(lambda v: jax.nn.softmax(v @ v.T, axis=-1))
    models = {"host(measured)": host,
              "tpu_v5e": CostModel.from_named("tpu_v5e"),
              "ampere_a100": CostModel.from_named("ampere_a100")}
    print("\n== one compiled softmax(x@x.T) step under each calibration ==")
    for name, m in models.items():
        pred = m.predict_fn(fn, x, dtype="f32")
        print(f"  {name:16s} {pred.summary()}")

    # ---- 5. autotune: the measured model picks kernel launch configs ---------
    from repro.core.autotune import Autotuner, TuningCache, tunable_names
    tuner = Autotuner(host, TuningCache("results/autotune/host_cache.json"))
    print("\n== autotune: default vs tuned predicted step (host model) ==")
    for kernel in tunable_names():
        r = tuner.tune(kernel)
        cfg = json.dumps(r.best, sort_keys=True)
        print(f"  {kernel:16s} default={r.predicted_default_s:.3e}s  "
              f"tuned={r.predicted_best_s:.3e}s  "
              f"(x{r.predicted_speedup:.2f})  {cfg}")
    print(f"  cache: {tuner.cache.path} ({len(tuner.cache)} entries)")

    out_dir = pathlib.Path("results")
    out = out_dir / "host_calibration.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(table, indent=1))
    canonical = save_calibration(host.cal,
                                 out_dir / "costmodel" / "host_canonical.json")
    print(f"\nwrote {out} (campaign cells in {args.results_dir}/) and "
          f"{canonical} (canonical cost-model format)")


if __name__ == "__main__":
    main()
