"""Reproduce the paper's experiment suite on the current backend via the
campaign runner: per-op latency tables (dependent/independent), the
memory-hierarchy chase, matrix-unit probes and the roofline peaks; then
diff the result against the shipped calibrations.

This is the paper-as-a-tool: on a real TPU the emitted table refreshes
repro/core/calibration/tpu_v5e.json; on CPU it characterizes the host.
Campaign results persist under results/campaign/ — interrupting and
rerunning this script resumes instead of restarting.

Run:  PYTHONPATH=src python examples/characterize_hardware.py [--full]
"""
import argparse
import json
import pathlib

import jax

from repro.core.microbench.tables import ampere_table, calibrate, v5e_table


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full grids instead of the quick sweep")
    ap.add_argument("--results-dir", default="results/campaign")
    args = ap.parse_args(argv)

    print(f"backend: {jax.default_backend()}")
    table = calibrate(quick=not args.full, results_dir=args.results_dir)

    print("\n== per-op latency (ns, steady state) ==")
    for k, v in sorted(table["ops"].items()):
        if k.endswith(".dep") or k.endswith(".ind"):
            print(f"  {k:28s} {v['per_op_ns']:10.2f}  "
                  f"(overhead {v['overhead_ns']:.0f}ns)")

    print("\n== memory hierarchy (pointer chase, ns/hop) ==")
    for size, v in table["memory"].items():
        print(f"  {int(size)//1024:8d} KiB   {v['per_hop_ns']:8.1f}")
    for size, v in table.get("memory_streaming", {}).items():
        print(f"  {size:>8s} streaming read   {v['gbps']:8.2f} GB/s")

    print("\n== matrix unit ==")
    for k, v in table["mxu"].items():
        print(f"  {k:32s} {v['per_op_us']:8.2f}us  {v['tflops']:8.3f} TFLOP/s")

    print("\n== roofline peaks (measured) ==")
    for k, v in table["roofline"].items():
        print(f"  {k:24s} {v['value']:10.3f} {v['unit']}")

    print("\n== reference tables shipped with the repo ==")
    a100 = ampere_table()
    print(f"  ampere_a100: {len(a100['instructions'])} instruction rows, "
          f"{len(a100['tensor_core'])} tensor-core rows "
          f"(the paper's Tables II-V)")
    v5e = v5e_table()
    print(f"  tpu_v5e: {len(v5e['vpu'])} VPU rows, "
          f"MXU bf16 peak {v5e['mxu']['bf16.f32']['peak_tflops']} TFLOP/s")

    out = pathlib.Path("results/host_calibration.json")
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(table, indent=1))
    print(f"\nwrote {out} (campaign cells in {args.results_dir}/)")


if __name__ == "__main__":
    main()
