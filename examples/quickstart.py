"""Quickstart: the framework in five acts.

  1. build an assigned architecture from its config (reduced for CPU),
  2. run one training step,
  3. characterize the hardware with the paper's microbench methodology,
  4. serve a few batched requests through the engine,
  5. price a compiled step with the instruction census + perf model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.costmodel import CostModel
from repro.core.isa import hlo_census
from repro.core.microbench import harness
from repro.models.zoo import build_model
from repro.serve.engine import ServingEngine
from repro.train.optim import make_optimizer
from repro.train.step import make_train_step

# ---- 1. a model from the zoo ------------------------------------------------
cfg = reduced(ARCHS["gemma2-2b"])           # same family, CPU-sized
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"[1] built {cfg.name} (reduced): "
      f"{sum(x.size for x in jax.tree.leaves(params)):,} params")

# ---- 2. one training step ---------------------------------------------------
opt = make_optimizer(cfg.optimizer, lr_peak=1e-3)
step = jax.jit(make_train_step(model, opt, accum=2))
batch = {"tokens": jnp.ones((4, 32), jnp.int32),
         "labels": jnp.ones((4, 32), jnp.int32)}
params2, _, metrics = step(params, opt.init(params), batch)
print(f"[2] train step: loss={float(metrics['loss']):.3f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

# ---- 3. microbenchmark the hardware (paper methodology) ----------------------
r_dep = harness.run_chain(harness.OPS["exp"], "exp", lengths=(8, 32, 128))
r_ind = harness.run_chain(harness.OPS["exp"], "exp", lengths=(8, 32, 128),
                          dependent=False)
print(f"[3] exp.f32 per-op: dependent={r_dep.per_op_s*1e9:.1f}ns "
      f"independent={r_ind.per_op_s*1e9:.1f}ns "
      f"(the paper's Table II effect)")

# ---- 4. batched serving ------------------------------------------------------
eng = ServingEngine(model, params, max_batch=2, max_len=64)
for i in range(3):
    eng.submit(np.arange(4 + i, dtype=np.int32), max_new_tokens=5)
stats = eng.run_until_done()
print(f"[4] served {stats.completed} requests, "
      f"{stats.decoded_tokens} tokens in {stats.steps} engine steps")

# ---- 5. instruction census + cost model --------------------------------------
lowered = jax.jit(model.loss).lower(params, batch)
census = hlo_census.census(lowered.compile().as_text())
pred = CostModel.from_named("tpu_v5e").predict(census, mem_bytes=1e6)
print(f"[5] census: {census['flops']:.2e} FLOPs, "
      f"{len(census['op_histogram'])} op kinds; "
      f"modelled step {pred.step_s*1e6:.1f}us ({pred.bottleneck}-bound, "
      f"{pred.defaulted_op_count:.0f} ops defaulted)")
print("quickstart OK")
