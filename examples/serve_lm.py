"""Batched serving example: continuous batching through the engine with
cost-model-gated admission — predicted decode-step latency decides how many
prefills pack into each engine iteration — plus latency/throughput
accounting per request, then the same trace through the PAGED engine
(block-pool KV cache, chunked prefill) for a like-for-like comparison of
tokens, KV bytes resident and preemption behaviour.  The paged run
streams per-step/per-request telemetry into a MetricsSink (summary
printed, snapshot saved under results/ — see docs/ops-runbook.md for
how to read it).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.costmodel import CostModel
from repro.models.zoo import build_model
from repro.serve.engine import PagedServingEngine, ServingEngine
from repro.serve.telemetry import TelemetryController


def main():
    cfg = reduced(ARCHS["gemma3-1b"], n_layers=4, d_model=128, n_heads=4,
                  n_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cm = CostModel.from_named("tpu_v5e")
    # a tight budget: admissions beyond the first per step defer until the
    # predicted iteration time (decode + prefills) fits again
    eng = ServingEngine(model, params, max_batch=4, max_len=96,
                        cost_model=cm, step_budget_s=5e-5)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=rng.integers(4, 24)).astype(np.int32)
               for _ in range(10)]
    t0 = time.time()
    rids = [eng.submit(p, max_new_tokens=12) for p in prompts]
    stats = eng.run_until_done()
    dt = time.time() - t0

    print(f"completed {stats.completed} requests / "
          f"{stats.decoded_tokens} tokens in {dt:.2f}s "
          f"({stats.decoded_tokens/dt:.1f} tok/s, "
          f"{stats.steps} decode steps, {stats.prefills} prefills, "
          f"{stats.deferred_prefills} admissions deferred, "
          f"{stats.host_syncs/max(stats.steps, 1):.2f} host syncs/step "
          "on the fused hot path)")
    if stats.predicted_step_s:
        print(f"  predicted step time: {min(stats.predicted_step_s):.2e}-"
              f"{max(stats.predicted_step_s):.2e}s "
              f"(measured median {np.median(stats.measured_step_s):.2e}s)")
    for rid in rids[:3]:
        r = eng.done[rid]
        print(f"  req {rid}: prompt[{len(r.prompt)}] -> {r.tokens}")
    assert stats.completed == 10

    # the same trace, paged: a block pool sized at ~half the slot engine's
    # max_batch x max_len rectangle, prompts prefilled in 16-token chunks;
    # a telemetry controller streams per-step/per-request records
    ctl = TelemetryController()
    paged = PagedServingEngine(model, params, max_batch=4, max_len=96,
                               block_size=16, n_blocks=12, chunk_size=16,
                               telemetry=ctl)
    t0 = time.time()
    prids = [paged.submit(p, max_new_tokens=12) for p in prompts]
    pstats = paged.run_until_done()
    pdt = time.time() - t0
    print(f"paged: {pstats.completed} requests in {pdt:.2f}s "
          f"({pstats.decoded_tokens/pdt:.1f} tok/s, "
          f"{pstats.prefill_chunks} chunks, {pstats.preemptions} "
          f"preemptions, peak {pstats.peak_blocks_in_use}/"
          f"{paged.n_blocks} blocks)")
    print(f"  KV bytes resident: slot={eng.kv_cache_bytes()} "
          f"paged={paged.kv_cache_bytes()} "
          f"({paged.kv_cache_bytes()/eng.kv_cache_bytes():.0%})")
    identical = all(eng.done[a].tokens == paged.done[b].tokens
                    for a, b in zip(rids, prids))
    print(f"  greedy tokens identical: {identical}")
    s = ctl.sink.summary()
    snap = ctl.sink.save("results/telemetry/serve_lm_snapshot.json")
    print(f"  telemetry: {s['steps']} steps recorded, "
          f"step p50/p99 {s['step_p50_s']:.2e}/{s['step_p99_s']:.2e}s, "
          f"request p99 {s['request_p99_s']:.2e}s -> {snap}")
    assert identical and pstats.completed == 10
    assert s["steps"] == pstats.steps and s["requests"] == pstats.completed
    print("serve_lm OK")


if __name__ == "__main__":
    main()
