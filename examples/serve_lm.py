"""Batched serving example: continuous batching through the engine with
cost-model-gated admission — predicted decode-step latency decides how many
prefills pack into each engine iteration — plus latency/throughput
accounting per request.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.costmodel import CostModel
from repro.models.zoo import build_model
from repro.serve.engine import ServingEngine


def main():
    cfg = reduced(ARCHS["gemma3-1b"], n_layers=4, d_model=128, n_heads=4,
                  n_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cm = CostModel.from_named("tpu_v5e")
    # a tight budget: admissions beyond the first per step defer until the
    # predicted iteration time (decode + prefills) fits again
    eng = ServingEngine(model, params, max_batch=4, max_len=96,
                        cost_model=cm, step_budget_s=5e-5)

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 24)).astype(np.int32)
        rids.append(eng.submit(prompt, max_new_tokens=12))
    stats = eng.run_until_done()
    dt = time.time() - t0

    print(f"completed {stats.completed} requests / "
          f"{stats.decoded_tokens} tokens in {dt:.2f}s "
          f"({stats.decoded_tokens/dt:.1f} tok/s, "
          f"{stats.steps} decode steps, {stats.prefills} prefills, "
          f"{stats.deferred_prefills} admissions deferred)")
    if stats.predicted_step_s:
        print(f"  predicted step time: {min(stats.predicted_step_s):.2e}-"
              f"{max(stats.predicted_step_s):.2e}s "
              f"(measured median {np.median(stats.measured_step_s):.2e}s)")
    for rid in rids[:3]:
        r = eng.done[rid]
        print(f"  req {rid}: prompt[{len(r.prompt)}] -> {r.tokens}")
    assert stats.completed == 10
    print("serve_lm OK")


if __name__ == "__main__":
    main()
