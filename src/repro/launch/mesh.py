"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module state) so importing this
module never touches jax device state; the dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.

Mesh shapes: single pod = (16, 16) over ('data', 'model') = 256 v5e chips;
multi-pod = (2, 16, 16) over ('pod', 'data', 'model') = 512 chips.  Batch
shards over ('pod', 'data'); FSDP weight sharding over 'data'; tensor/expert/
sequence parallelism over 'model'; 'pod' is pure DP (weights replicated
across pods, gradients all-reduced over the cross-pod links, which is where
gradient compression applies).
"""
from __future__ import annotations

import logging

import jax
import numpy as np

log = logging.getLogger(__name__)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def _largest_divisor_leq(n: int, m: int) -> int:
    for d in range(min(m, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def make_host_mesh(model_axis: int | None = None, *, devices=None,
                   allow_shrink: bool = False):
    """A ``('data', 'model')`` mesh over the host's devices (CPU smoke
    tests: 1 device) or an explicit ``devices`` sub-slice (one serving
    replica's share of a cluster budget).

    ``model_axis`` must divide the device count: the old behaviour
    silently computed ``(n // m, m)`` and DROPPED ``n % m`` devices
    (or failed opaquely inside the mesh constructor).  Now a
    non-divisible ``model_axis`` raises a clear error, unless the
    caller opts into ``allow_shrink=True`` — then the model axis falls
    back to the largest divisor of ``n`` at or under ``model_axis``,
    with a logged warning, and no device is ever dropped."""
    devs = list(jax.devices()) if devices is None else list(devices)
    n = len(devs)
    if n == 0:
        raise ValueError("make_host_mesh needs at least one device")
    m = 1 if model_axis is None else model_axis
    if m < 1:
        raise ValueError(f"model_axis must be >= 1, got {m}")
    if n % m:
        if not allow_shrink:
            raise ValueError(
                f"model_axis={m} does not divide the {n} available "
                f"device(s); a ({n} // {m}, {m}) mesh would drop "
                f"{n % m} device(s).  Pass a divisor of {n}, or "
                f"allow_shrink=True to fall back to the largest "
                f"divisor <= {m}")
        fell_back = _largest_divisor_leq(n, m)
        log.warning(
            "make_host_mesh: model_axis=%d does not divide %d devices; "
            "shrinking to model_axis=%d (allow_shrink)", m, n, fell_back)
        m = fell_back
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs).reshape(n // m, m), ("data", "model"))


def slice_devices(n_replicas: int, devices_per_replica: int, devices=None):
    """Carve the device list into ``n_replicas`` disjoint sub-slices of
    ``devices_per_replica`` each — the per-replica device budgets a
    :class:`~repro.sharding.plans.ClusterTopology` implies.  Raises when
    the budget exceeds the devices physically present."""
    devs = list(jax.devices()) if devices is None else list(devices)
    need = n_replicas * devices_per_replica
    if need > len(devs):
        raise ValueError(
            f"{n_replicas} replica(s) x {devices_per_replica} device(s) "
            f"= {need} exceeds the {len(devs)} device(s) present")
    return [devs[i * devices_per_replica:(i + 1) * devices_per_replica]
            for i in range(n_replicas)]


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_batch_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
