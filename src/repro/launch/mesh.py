"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module state) so importing this
module never touches jax device state; the dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.

Mesh shapes: single pod = (16, 16) over ('data', 'model') = 256 v5e chips;
multi-pod = (2, 16, 16) over ('pod', 'data', 'model') = 512 chips.  Batch
shards over ('pod', 'data'); FSDP weight sharding over 'data'; tensor/expert/
sequence parallelism over 'model'; 'pod' is pure DP (weights replicated
across pods, gradients all-reduced over the cross-pod links, which is where
gradient compression applies).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int | None = None):
    """A mesh over whatever devices exist (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    m = model_axis or 1
    return jax.make_mesh((n // m, m), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_batch_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
