"""Production training launcher: --arch <id> on the production mesh.

On real hardware this runs under `jax.distributed.initialize()` across
hosts; in this container pass --dry-run to lower+compile only (equivalent to
repro.launch.dryrun for the train cell) or --host-mesh to actually execute a
reduced config on the local device.

  python -m repro.launch.train --arch yi-34b --dry-run
  python -m repro.launch.train --arch gemma2-2b --host-mesh --steps 50
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default="train_4k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.dry_run:
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count"
                                     "=512").strip()
        from repro.launch.dryrun import run_cell
        run_cell(args.arch, args.cell, args.multi_pod)
        return

    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.zoo import build_model
    from repro.train.loop import train

    cfg = get_config(args.arch)
    if args.host_mesh:
        cfg = reduced(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    model = build_model(cfg)
    res = train(model, mesh, num_steps=args.steps, global_batch=8,
                seq_len=64, ckpt_dir=args.ckpt_dir,
                hooks=[lambda s, m: print(f"step {s} loss "
                                          f"{float(m['loss']):.4f}")])
    print(f"done: {res.steps_run} steps, final loss {res.final_loss:.4f}")


if __name__ == "__main__":
    main()
