import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the TRAIN step (train_4k) or SERVE step (prefill/decode cells)
is jit-lowered with production shardings against ShapeDtypeStruct inputs (no
allocation), compiled for the 256-chip single-pod mesh and the 512-chip
2-pod mesh, and the compiled artifact is analysed:

  * ``compiled.memory_analysis()``  - proves the cell fits per-device HBM;
  * ``compiled.cost_analysis()``    - XLA's own FLOP/byte counters (loop
    bodies counted ONCE - kept for reference);
  * ``repro.core.isa.hlo_census``   - our instruction census with while-loop
    trip multipliers, HBM-traffic and collective wire-byte estimates (the
    numbers §Roofline uses).

Usage:
  python -m repro.launch.dryrun --arch yi-34b --cell train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every runnable cell x mesh
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, cells_for, get_config
from repro.core.isa import hlo_census
from repro.launch.mesh import (batch_axes, make_production_mesh,
                               n_batch_shards)
from repro.models.zoo import build_model, count_active_params, count_params
from repro.sharding.plans import serve_shardings, train_shardings
from repro.train import optim as optim_mod
from repro.train.step import accum_steps_for, make_decode_step, \
    make_prefill_step, make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mem_analysis_dict(compiled):
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes",
                 "serialized_size_in_bytes"):
        try:
            out[attr] = int(getattr(ma, attr))
        except Exception:
            pass
    return out


def _cost_analysis_dict(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception:
        return {}


OPT_PLAN = dict(head_pad_multiple=16, scatter_cache_update=True,
                cast_params_once=True, moe_impl="shard")


def run_cell(arch: str, cell_name: str, multi_pod: bool,
             out_dir: Path = RESULTS, save_hlo: bool = False,
             opt: bool = False, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if opt:
        cfg = cfg.replace(**OPT_PLAN)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg)
    cell = next(c for c in cells_for(cfg) if c.name == cell_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    mesh_tag = ("pod2x16x16" if multi_pod else "pod16x16") \
        + ("__opt" if opt else "")
    t0 = time.time()

    jax.set_mesh(mesh)  # context mesh: activation sharding constraints resolve
    with mesh:
        if cell.kind == "train":
            optimizer = optim_mod.make_optimizer(cfg.optimizer)
            psh, osh, bsh, shapes, log = train_shardings(
                model, optimizer, mesh, cell)
            accum = accum_steps_for(cfg, cell.global_batch,
                                    n_batch_shards(mesh),
                                    n_pods=mesh.shape.get("pod", 1))
            step = make_train_step(model, optimizer, accum,
                                   batch_axes(mesh))
            opt_shapes = shapes["opt"]
            lowered = jax.jit(
                step, in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            ).lower(shapes["params"], opt_shapes, shapes["batch"])
        else:
            psh, ish, shapes, log = serve_shardings(model, mesh, cell)
            accum = 1
            if cell.kind == "prefill":
                step = make_prefill_step(model)
                lowered = jax.jit(
                    step, in_shardings=(psh, ish),
                ).lower(shapes["params"], shapes["inputs"])
            else:
                step = make_decode_step(model)
                inp = shapes["inputs"]
                lowered = jax.jit(
                    step,
                    in_shardings=(psh, ish["cache"], ish["tokens"],
                                  ish["pos"]),
                    donate_argnums=(1,),
                ).lower(shapes["params"], inp["cache"], inp["tokens"],
                        inp["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = _mem_analysis_dict(compiled)
    cost = _cost_analysis_dict(compiled)
    text = compiled.as_text()
    cens = hlo_census.census(text, n_devices=n_dev)
    colls = hlo_census.collective_table(text, n_devices=n_dev)
    # keep only the heaviest collectives itemized
    colls = sorted(colls, key=lambda c: -c["wire_bytes"])[:40]

    n_params = count_params(cfg)
    n_active = count_active_params(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 6.0 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = cell.global_batch  # one token per row
        model_flops = 2.0 * n_active * tokens

    result = {
        "arch": arch, "cell": cell_name, "mesh": mesh_tag,
        "n_devices": n_dev, "kind": cell.kind,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "accum_steps": accum,
        "params": n_params, "active_params": n_active,
        "model_flops_global": model_flops,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": cost,
        "census": cens,
        "top_collectives": colls,
        "sharding_log": log[:40],
        "hlo_bytes": len(text),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{cell_name}__{mesh_tag}.json"
    out_path.write_text(json.dumps(result, indent=1))
    if save_hlo:
        (out_dir / f"{arch}__{cell_name}__{mesh_tag}.hlo.txt").write_text(text)
    print(f"[dryrun] {arch} {cell_name} {mesh_tag}: "
          f"compile={t_compile:.1f}s "
          f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
          f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
          f"census_flops={cens['flops']:.3e} "
          f"coll={cens['collective_bytes_total']/2**30:.3f}GiB")
    print(f"[dryrun] memory_analysis: {mem}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the beyond-paper optimization plan")
    args = ap.parse_args()

    jobs = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for cell in cells_for(cfg):
                jobs.append((arch, cell.name, False))
                jobs.append((arch, cell.name, True))
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        for arch in archs:
            cfg = get_config(arch)
            cells = ([args.cell] if args.cell
                     else [c.name for c in cells_for(cfg)])
            for cell in cells:
                if args.both_meshes:
                    jobs.append((arch, cell, False))
                    jobs.append((arch, cell, True))
                else:
                    jobs.append((arch, cell, args.multi_pod))

    failures = []
    for arch, cell, mp in jobs:
        tag = ("pod2x16x16" if mp else "pod16x16") + ("__opt" if args.opt else "")
        out = RESULTS / f"{arch}__{cell}__{tag}.json"
        if args.skip_existing and out.exists():
            continue
        try:
            run_cell(arch, cell, mp, save_hlo=args.save_hlo, opt=args.opt)
        except Exception as e:  # noqa
            traceback.print_exc()
            failures.append((arch, cell, tag, repr(e)[:200]))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", *f)
        raise SystemExit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
