"""Serving launcher: --arch <id>, engine over the production mesh (dry-run)
or a reduced config executed locally.

  python -m repro.launch.serve --arch deepseek-v2-236b --dry-run --cell decode_32k
  python -m repro.launch.serve --arch gemma3-1b --host --requests 8
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default="decode_32k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV engine "
                         "(block-pool cache + chunked prefill)")
    ap.add_argument("--block-size", type=int, default=8)
    args = ap.parse_args()

    if args.dry_run:
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count"
                                     "=512").strip()
        from repro.launch.dryrun import run_cell
        run_cell(args.arch, args.cell, args.multi_pod)
        return

    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.models.zoo import build_model
    from repro.serve.engine import PagedServingEngine, ServingEngine

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.paged:
        eng = PagedServingEngine(model, params, max_batch=4, max_len=64,
                                 block_size=args.block_size, chunk_size=8)
    else:
        eng = ServingEngine(model, params, max_batch=4, max_len=64)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                   max_new_tokens=8)
    stats = eng.run_until_done()
    extra = (f", {stats.prefill_chunks} chunks, "
             f"{stats.preemptions} preemptions" if args.paged else "")
    print(f"served {stats.completed} requests, "
          f"{stats.decoded_tokens} tokens{extra}")


if __name__ == "__main__":
    main()
