"""In-kernel ALU chain microbenchmark (TPU Pallas) — the paper's Fig. 1.

The paper's PTX microbenchmark body (clock; op; op; op; clock) becomes a
Pallas kernel whose body is a K-long unrolled chain of one VPU op over one
(8, 128) native vector tile held in VMEM — dependent (latency) or
independent (throughput) exactly like Table II.  On real TPU hardware the
host times `iterations` grid repetitions and regresses t(K); in this
container interpret=True validates the ARITHMETIC against ref.py (timing on
CPU interp is meaningless and not claimed)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# op name -> elementwise lambda (mirrors core.microbench.harness.OPS)
_KERNEL_OPS = {
    "add": lambda y, c: y + c,
    "sub": lambda y, c: y - c,
    "mul": lambda y, c: y * c,
    "fma": lambda y, c: y * c + c,
    "max": lambda y, c: jnp.maximum(y, c),
    "min": lambda y, c: jnp.minimum(y, c),
    "div": lambda y, c: y / c,
    "rsqrt": lambda y, c: jax.lax.rsqrt(jnp.abs(y) + 1e-6),
    "exp": lambda y, c: jnp.exp(y * 0.001),
    "tanh": lambda y, c: jnp.tanh(y),
    "select": lambda y, c: jnp.where(y > c, y, c),
}


def _alu_kernel(x_ref, c_ref, o_ref, *, op, length, dependent):
    f = _KERNEL_OPS[op]
    x = x_ref[...]
    c = c_ref[0, 0]
    if dependent:
        y = x
        for _ in range(length):
            y = f(y, c)
        o_ref[...] = y
    else:
        ys = [f(x + i, c) for i in range(length)]
        out = ys[0]
        for y in ys[1:]:
            out = out + y * 0
        o_ref[...] = out


def alu_chain(x, c, *, op="fma", length=64, dependent=True, interpret=False):
    """x [8,128] one native VPU tile; c scalar -> chained result [8,128]."""
    assert x.shape == (8, 128), "one native VPU tile"
    c2 = jnp.asarray(c, x.dtype).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_alu_kernel, op=op, length=length,
                          dependent=dependent),
        in_specs=[pl.BlockSpec((8, 128), lambda: (0, 0)),
                  pl.BlockSpec((1, 1), lambda: (0, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), x.dtype),
        interpret=interpret,
    )(x, c2)
