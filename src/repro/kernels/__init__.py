"""Public kernel entry points.

``from repro.kernels import flash_attention`` resolves to the jit'd,
config-dispatching wrapper in ``ops`` (interpret-mode on CPU, Mosaic on
TPU); ``ref`` holds the pure-jnp oracles.  ``KERNELS`` maps kernel names to
entry points so the autotuner (``repro.core.autotune``) can enumerate and
invoke tunables by name.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (KERNEL_DEFAULTS, alu_chain,  # noqa: F401
                               flash_attention, mxu_probe, paged_attention,
                               pointer_chase, resolve_kernel_config,
                               ssm_scan, wkv6)

# name -> public entry point (the autotuner's enumeration surface)
KERNELS = {
    "flash_attention": flash_attention,
    "paged_attention": paged_attention,
    "ssm_scan": ssm_scan,
    "wkv6": wkv6,
    "mxu_probe": mxu_probe,
    "alu_chain": alu_chain,
    "pointer_chase": pointer_chase,
}
