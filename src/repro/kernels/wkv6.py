"""RWKV6 (Finch) WKV recurrence kernel (TPU Pallas).

Per (batch, head-block): state S in R^{bh x N x N} lives in VMEM scratch for
the whole sequence; each step reads r,k,v,w rows ([bh, N] each) and writes
one y row.

  y_t = r_t . (S + diag(u) k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T

``block_h`` is the autotuner's grid-factorization axis: one kernel instance
carries ``block_h`` heads' state (more VMEM, fewer grid cells / less issue
overhead).  A ``block_h`` that does not divide the head count is clamped to
the largest common divisor, so any candidate is safe to launch.

The paper-relevant property: this is an *element-wise/outer-product* (VPU)
workload with a long serial dependence — exactly the instruction class whose
latency the per-op tables exist to price (no MXU involvement)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.autotune.space import divisor_clamp


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, *, seq):
    u = u_ref[...].astype(jnp.float32)                    # [bh, N]
    bh, N = u.shape
    s0 = jnp.zeros((bh, N, N), jnp.float32)

    def step(t, s):
        r = r_ref[0, t].astype(jnp.float32)               # [bh, N]
        k = k_ref[0, t].astype(jnp.float32)
        v = v_ref[0, t].astype(jnp.float32)
        w = w_ref[0, t].astype(jnp.float32)
        kv = k[:, :, None] * v[:, None, :]                # [bh, N, N]
        y = jnp.einsum("gi,gij->gj", r, s + u[:, :, None] * kv)   # [bh, N]
        y_ref[0, t] = y.astype(y_ref.dtype)
        return w[:, :, None] * s + kv

    jax.lax.fori_loop(0, seq, step, s0)


def wkv6(r, k, v, w, u, *, block_h=1, interpret=False):
    """r,k,v,w [B,S,H,N]; u [H,N] -> y [B,S,H,N]."""
    B, S, H, N = r.shape
    block_h = divisor_clamp(block_h, H)
    grid = (B, H // block_h)
    spec = pl.BlockSpec((1, S, block_h, N), lambda b, h: (b, 0, h, 0))
    return pl.pallas_call(
        functools.partial(_wkv_kernel, seq=S),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((block_h, N), lambda b, h: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, N), r.dtype),
        interpret=interpret,
    )(r, k, v, w, u)
