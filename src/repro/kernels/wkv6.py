"""RWKV6 (Finch) WKV recurrence kernel (TPU Pallas).

Per (batch, head): state S in R^{N x N} lives in VMEM scratch for the whole
sequence; each step reads r,k,v,w rows ([N] each) and writes one y row.

  y_t = r_t . (S + diag(u) k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T

The paper-relevant property: this is an *element-wise/outer-product* (VPU)
workload with a long serial dependence — exactly the instruction class whose
latency the per-op tables exist to price (no MXU involvement)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, *, seq):
    u = u_ref[0].astype(jnp.float32)                      # [N]
    N = u.shape[0]
    s0 = jnp.zeros((N, N), jnp.float32)

    def step(t, s):
        r = r_ref[0, t, 0].astype(jnp.float32)            # [N]
        k = k_ref[0, t, 0].astype(jnp.float32)
        v = v_ref[0, t, 0].astype(jnp.float32)
        w = w_ref[0, t, 0].astype(jnp.float32)
        kv = k[:, None] * v[None, :]                      # [N, N]
        y = r @ (s + u[:, None] * kv)                     # [N]
        y_ref[0, t, 0] = y.astype(y_ref.dtype)
        return w[:, None] * s + kv

    jax.lax.fori_loop(0, seq, step, s0)


def wkv6(r, k, v, w, u, *, interpret=False):
    """r,k,v,w [B,S,H,N]; u [H,N] -> y [B,S,H,N]."""
    B, S, H, N = r.shape
    grid = (B, H)
    spec = pl.BlockSpec((1, S, 1, N), lambda b, h: (b, 0, h, 0))
    return pl.pallas_call(
        functools.partial(_wkv_kernel, seq=S),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, N), lambda b, h: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, N), r.dtype),
        interpret=interpret,
    )(r, k, v, w, u)
