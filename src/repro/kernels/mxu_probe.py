"""MXU matmul probe kernel (TPU Pallas) — the paper's Fig. 5 (WMMA) adapted.

One kernel instance multiplies MXU-aligned tiles with an in-VMEM dependent
chain (C <- A @ C, `chain` times), the exact analogue of the paper's 4
chained mma_sync fragments: a chain measures MXU latency, chain=1 across a
big grid measures throughput.  Block shapes are the TPU hardware tile
(128 x 128) scaled the way the paper sweeps WMMA fragment shapes."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(a_ref, b_ref, o_ref, *, chain):
    a = a_ref[...]
    c = b_ref[...]
    for _ in range(chain):
        c32 = jax.lax.dot(a, c, preferred_element_type=jnp.float32)
        c = (c32 * 0.001).astype(b_ref.dtype)
    o_ref[...] = c


def mxu_probe(a, b, *, chain=4, block=(128, 128), interpret=False):
    """a [M,K]; b [K,N] -> chained product [M,N], tiled (bm, bn) per grid
    cell with the full K panel in VMEM."""
    M, K = a.shape
    _, N = b.shape
    bm, bn = (max(min(block[0], M), 1), max(min(block[1], N), 1))
    # the tile IS the measured quantity: a silently rewritten block would
    # label a measurement with a shape that never ran.  (The tuned-dispatch
    # wrapper in ops.py divisor-clamps cache-resolved blocks before calling.)
    if M % bm or N % bn:
        raise ValueError(
            f"mxu_probe block ({bm}, {bn}) must divide the problem "
            f"({M}, {N})")
    if chain > 1:
        assert M == K, "a dependent chain needs square A (C <- A @ C)"
    if (bm, bn) != (M, N):
        # throughput mode: grid of independent tiles (chain needs bm == K)
        assert chain == 1 or bm == K
        grid = (M // bm, N // bn)
        return pl.pallas_call(
            functools.partial(_probe_kernel, chain=chain),
            grid=grid,
            in_specs=[pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
                      pl.BlockSpec((K, bn), lambda i, j: (0, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((M, N), b.dtype),
            interpret=interpret,
        )(a, b)
    return pl.pallas_call(
        functools.partial(_probe_kernel, chain=chain),
        in_specs=[pl.BlockSpec((M, K), lambda: (0, 0)),
                  pl.BlockSpec((K, N), lambda: (0, 0))],
        out_specs=pl.BlockSpec((M, N), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), b.dtype),
        interpret=interpret,
    )(a, b)
