"""In-kernel pointer chase (TPU Pallas) — the paper's Fig. 2 adapted.

The GPU version chases through global memory with cache-control operators
(.cv/.cg/.ca) to isolate each cache level.  TPU has no hardware caches to
bypass; the analogous experiment places the chase array either in VMEM (this
kernel: BlockSpec brings the whole array into VMEM — the VMEM-latency
measurement) or leaves it HBM-resident (array larger than VMEM, measured by
the host-level `core.microbench.memory` chase).  Serial dependence is
identical to the paper: each load's address is the previous load's value."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chase_kernel(nxt_ref, start_ref, o_ref, *, hops):
    i = start_ref[0, 0]

    def body(_, i):
        return nxt_ref[0, i]

    o_ref[0, 0] = jax.lax.fori_loop(0, hops, body, i)


def pointer_chase(nxt, start, *, hops=1024, interpret=False):
    """nxt [N] int32 permutation cycle; start scalar -> final index."""
    n = nxt.shape[0]
    nxt2 = nxt.reshape(1, n)
    s2 = jnp.asarray(start, jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_chase_kernel, hops=hops),
        in_specs=[pl.BlockSpec((1, n), lambda: (0, 0)),
                  pl.BlockSpec((1, 1), lambda: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(nxt2, s2)[0, 0]
