"""Blocked flash-attention forward kernel (TPU Pallas).

TPU adaptation of the memory-bounded attention the framework's jnp path
emulates: Q is tiled over the grid, K/V stream through VMEM in blocks, and
the online-softmax running (m, l, acc) state lives in VMEM scratch — the
HBM->VMEM->MXU pipeline replaces the GPU's gmem->smem->TC staging.  Block
shapes default to MXU-aligned (128 x head_dim) and are the autotuner's
primary search axes (``repro.core.autotune``), together with the
accumulator dtype.

Supports causal masking, sliding windows, logit softcaps and GQA (the KV
head for a query head is resolved in the BlockSpec index_map, so no repeated
KV is materialized).  Sequences that do not divide the block shapes are
padded to the next block boundary: padded KV positions carry ``k_pos >=
seq_kv`` and are masked to -inf, padded query rows are sliced off the
output, so ragged tails cost one partial block instead of an assert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38

# accumulator dtype names accepted by the `acc_dtype` tunable
ACC_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window, softcap,
               block_q, block_k, seq_kv, acc_dtype):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, D]
    m = jnp.full((block_q,), NEG_INF, acc_dtype)
    l = jnp.zeros((block_q,), acc_dtype)
    acc = jnp.zeros((block_q, q.shape[-1]), acc_dtype)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    padded_kv = k_ref.shape[2]
    n_blocks = padded_kv // block_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T                                       # [bq, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        # padded tail slots (k_pos >= seq_kv) never attend
        mask = k_pos[None, :] < seq_kv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(acc_dtype))
        p = jnp.exp(s - m_new[:, None].astype(jnp.float32))
        alpha = jnp.exp((m - m_new).astype(jnp.float32))
        l_new = l * alpha.astype(acc_dtype) \
            + jnp.sum(p, axis=-1).astype(acc_dtype)
        acc_new = acc * alpha[:, None].astype(acc_dtype) \
            + (p @ v).astype(acc_dtype)
        return m_new, l_new, acc_new

    upper = n_blocks
    if causal and window is None:
        # skip fully-masked kv blocks above the diagonal
        upper = jnp.minimum(n_blocks, (qi + 1) * block_q // block_k
                            + (1 if block_q % block_k else 0))
        upper = jnp.maximum(upper, 1)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m, l, acc))
    out = acc.astype(jnp.float32) \
        / jnp.maximum(l.astype(jnp.float32), 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, acc_dtype="f32",
                    interpret=False):
    """q [B,Sq,H,D]; k,v [B,Skv,KH,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    _, Skv, KH, _ = k.shape
    scale = scale if scale is not None else D ** -0.5
    block_q = max(min(block_q, Sq), 1)
    block_k = max(min(block_k, Skv), 1)
    if acc_dtype not in ACC_DTYPES:
        raise ValueError(f"acc_dtype must be one of {sorted(ACC_DTYPES)}, "
                         f"got {acc_dtype!r}")
    group = H // KH

    # ragged tails: pad sequences to the next block boundary.  Padded KV
    # slots are masked inside the kernel (k_pos >= Skv); padded query rows
    # compute garbage that is sliced off below.
    pad_q = -Sq % block_q
    pad_k = -Skv % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k

    qt = jnp.moveaxis(q, 2, 1)                            # [B,H,Sq,D]
    kt = jnp.moveaxis(k, 2, 1)                            # [B,KH,Skv,D]
    vt = jnp.moveaxis(v, 2, 1)

    grid = (B, H, Sq_p // block_q)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=block_q,
                          block_k=block_k, seq_kv=Skv,
                          acc_dtype=ACC_DTYPES[acc_dtype]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv_p, D),
                         lambda b, h, i, g=group: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, Skv_p, D),
                         lambda b, h, i, g=group: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    out = jnp.moveaxis(out, 1, 2)
    return out[:, :Sq] if pad_q else out
