"""Blocked flash-attention forward kernel (TPU Pallas).

TPU adaptation of the memory-bounded attention the framework's jnp path
emulates: Q is tiled over the grid, K/V stream through VMEM in blocks, and
the online-softmax running (m, l, acc) state lives in VMEM scratch — the
HBM->VMEM->MXU pipeline replaces the GPU's gmem->smem->TC staging.  Block
shapes default to MXU-aligned (128 x head_dim).

Supports causal masking, sliding windows, logit softcaps and GQA (the KV
head for a query head is resolved in the BlockSpec index_map, so no repeated
KV is materialized).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window, softcap,
               block_q, block_k, seq_kv):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, D]
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[-1]), jnp.float32)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    n_blocks = seq_kv // block_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T                                       # [bq, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    upper = n_blocks
    if causal and window is None:
        # skip fully-masked kv blocks above the diagonal
        upper = jnp.minimum(n_blocks, (qi + 1) * block_q // block_k
                            + (1 if block_q % block_k else 0))
        upper = jnp.maximum(upper, 1)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=False):
    """q [B,Sq,H,D]; k,v [B,Skv,KH,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    _, Skv, KH, _ = k.shape
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, "pad sequences first"
    group = H // KH

    qt = jnp.moveaxis(q, 2, 1)                            # [B,H,Sq,D]
    kt = jnp.moveaxis(k, 2, 1)                            # [B,KH,Skv,D]
    vt = jnp.moveaxis(v, 2, 1)

    grid = (B, H, Sq // block_q)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=block_q,
                          block_k=block_k, seq_kv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv, D),
                         lambda b, h, i, g=group: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, Skv, D),
                         lambda b, h, i, g=group: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
