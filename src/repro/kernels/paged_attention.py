"""Paged-attention decode kernel (TPU Pallas).

Single-token decode attention over a paged KV cache: K/V live in a fixed
pool of ``[n_pages, block_size]`` token pages and each sequence names its
pages through a block table, so the kernel gathers exactly the pages a
context occupies instead of streaming a ``max_len`` stripe per sequence —
the block size *is* the memory-access granularity, which is what the
paper's hierarchy tables price.

Grid is ``(batch, heads)``; the GQA page panel for a query head resolves
in the BlockSpec index_map (like ``flash_attention``), and the inner loop
walks the sequence's valid pages with the online-softmax (m, l, acc)
recurrence.  Page ids are data (loaded from the block-table ref), so the
K/V loads use ``pl.ds`` dynamic slices; the loop trip count is the
sequence's own ``ceil(ctx / block_size)``, so short contexts cost few
iterations regardless of the table width.

The pure-jnp oracle is ``repro.kernels.ref.paged_attention_ref`` (what
CPU CI asserts against); the model-side reference path used by the paged
serving engine lives in ``models.layers.attention`` (it also handles the
paged *write*).

Two lowerings share one wrapper signature:

* ``paged_attention`` — the in_specs declare the whole page pool as one
  block per grid cell.  Exact in interpret mode and fine for CI-sized
  pools, but it stages the *pool* into VMEM.
* ``paged_attention_hbm`` — the HBM-resident lowering: ``k_pages`` /
  ``v_pages`` stay in ``ANY``/HBM memory space and each loop iteration
  async-copies only the table-selected page into a double-buffered VMEM
  scratch (page ``j+1``'s DMA is issued before page ``j`` is consumed),
  so VMEM holds exactly two K pages + two V pages + the q/acc rows —
  the pipelined working set the autotuner's ``space._pa_vmem`` prices,
  independent of pool size.

``kernels.ops.paged_attention`` routes to the HBM lowering on real TPUs
(and on request in interpret mode, which CPU CI asserts against the
oracle); the staged lowering remains the small-pool/debug path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _pa_kernel(q_ref, bt_ref, ctx_ref, k_ref, v_ref, o_ref, *, scale,
               window, softcap, block_size, n_pages):
    q = q_ref[0].astype(jnp.float32) * scale              # [1, D]
    D = q.shape[-1]
    ctx = ctx_ref[0, 0]
    n_valid = pl.cdiv(ctx, block_size)                    # traced trip count

    def body(j, carry):
        m, l, acc = carry
        raw = bt_ref[0, j]
        pid = jnp.clip(raw, 0, n_pages - 1)
        k = k_ref[pl.ds(pid, 1)][0, :, 0].astype(jnp.float32)  # [bs, D]
        v = v_ref[pl.ds(pid, 1)][0, :, 0].astype(jnp.float32)
        s = q @ k.T                                       # [1, bs]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
        # in-ctx positions whose table entry is -1 (unbacked page) must
        # mask, not attend the clipped page 0 — matches the ref oracle
        mask = (k_pos < ctx) & (raw >= 0)                 # causal by layout
        if window is not None:
            mask &= (ctx - 1 - k_pos) < window
        s = jnp.where(mask[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((1,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    acc0 = jnp.zeros((1, D), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_valid, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    scale=None, window=None, softcap=None, interpret=False):
    """q [B,H,D]; k/v_pages [P,bs,KH,D]; block_tables [B,NB] int32 (-1 =
    unbacked); context_lens [B] int32 -> [B,H,D].

    Attention of one new token per sequence over its paged context: the
    query position is ``context_lens - 1`` (causality holds by
    construction — only written positions are < ctx), with optional
    sliding ``window`` and logit ``softcap`` matching the flash kernel.
    Rows with ``context_lens == 0`` produce zeros (masked everywhere).
    """
    B, H, D = q.shape
    P, bs, KH, _ = k_pages.shape
    NB = block_tables.shape[1]
    scale = scale if scale is not None else D ** -0.5
    group = H // KH

    grid = (B, H)
    out = pl.pallas_call(
        functools.partial(_pa_kernel, scale=scale, window=window,
                          softcap=softcap, block_size=bs, n_pages=P),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, NB), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
            pl.BlockSpec((P, bs, 1, D),
                         lambda b, h, g=group: (0, 0, h // g, 0)),
            pl.BlockSpec((P, bs, 1, D),
                         lambda b, h, g=group: (0, 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(q,
      jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(context_lens, jnp.int32).reshape(B, 1),
      k_pages, v_pages)
    return out


# ---------------------------------------------------------------------------
# the HBM-resident lowering
# ---------------------------------------------------------------------------


def _pa_hbm_kernel(q_ref, bt_ref, ctx_ref, k_hbm, v_hbm, o_ref, *, scale,
                   window, softcap, block_size, n_pages, group, kv_dtype):
    """Same online-softmax recurrence as ``_pa_kernel``, but ``k_hbm`` /
    ``v_hbm`` are unblocked ``ANY``-space refs of the WHOLE pool: each
    iteration DMAs the table-selected page (with the GQA head collapsed
    in the copy's source slice) into one slot of a two-slot VMEM scratch,
    issuing page ``j+1``'s copies before waiting on page ``j`` so the
    gather overlaps the compute."""
    q = q_ref[0].astype(jnp.float32) * scale              # [1, D]
    D = q.shape[-1]
    ctx = ctx_ref[0, 0]
    n_valid = pl.cdiv(ctx, block_size)                    # traced trip count
    kh = pl.program_id(1) // group                        # GQA panel

    def body(k_buf, v_buf, k_sem, v_sem):
        def dma(buf, hbm, sem, slot, j):
            pid = jnp.clip(bt_ref[0, j], 0, n_pages - 1)
            return pltpu.make_async_copy(hbm.at[pid, :, kh, :],
                                         buf.at[slot], sem.at[slot])

        @pl.when(n_valid > 0)
        def _():
            dma(k_buf, k_hbm, k_sem, 0, 0).start()
            dma(v_buf, v_hbm, v_sem, 0, 0).start()

        def step(j, carry):
            m, l, acc = carry
            slot = jax.lax.rem(j, 2)
            nxt = jax.lax.rem(j + 1, 2)

            @pl.when(j + 1 < n_valid)
            def _():
                dma(k_buf, k_hbm, k_sem, nxt, j + 1).start()
                dma(v_buf, v_hbm, v_sem, nxt, j + 1).start()

            dma(k_buf, k_hbm, k_sem, slot, j).wait()
            dma(v_buf, v_hbm, v_sem, slot, j).wait()
            k = k_buf[slot].astype(jnp.float32)           # [bs, D]
            v = v_buf[slot].astype(jnp.float32)
            raw = bt_ref[0, j]
            s = q @ k.T                                   # [1, bs]
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            k_pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
            mask = (k_pos < ctx) & (raw >= 0)             # causal by layout
            if window is not None:
                mask &= (ctx - 1 - k_pos) < window
            s = jnp.where(mask[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[:, None] + p @ v
            return m_new, l_new, acc_new

        m0 = jnp.full((1,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((1,), jnp.float32)
        acc0 = jnp.zeros((1, D), jnp.float32)
        _, l, acc = jax.lax.fori_loop(0, n_valid, step, (m0, l0, acc0))
        o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)

    pl.run_scoped(
        body,
        k_buf=pltpu.VMEM((2, block_size, q_ref.shape[-1]), kv_dtype),
        v_buf=pltpu.VMEM((2, block_size, q_ref.shape[-1]), kv_dtype),
        k_sem=pltpu.SemaphoreType.DMA((2,)),
        v_sem=pltpu.SemaphoreType.DMA((2,)))


def paged_attention_hbm(q, k_pages, v_pages, block_tables, context_lens, *,
                        scale=None, window=None, softcap=None,
                        interpret=False):
    """``paged_attention`` with the page pool kept in HBM (``ANY`` memory
    space) and per-page double-buffered async copies — the production
    lowering for pools far larger than VMEM.  Same contract and oracle
    (``ref.paged_attention_ref``) as the staged lowering."""
    B, H, D = q.shape
    P, bs, KH, _ = k_pages.shape
    NB = block_tables.shape[1]
    scale = scale if scale is not None else D ** -0.5
    group = H // KH

    out = pl.pallas_call(
        functools.partial(_pa_hbm_kernel, scale=scale, window=window,
                          softcap=softcap, block_size=bs, n_pages=P,
                          group=group, kv_dtype=k_pages.dtype),
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, NB), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(q,
      jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(context_lens, jnp.int32).reshape(B, 1),
      k_pages, v_pages)
    return out
