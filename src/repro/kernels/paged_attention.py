"""Paged-attention decode kernel (TPU Pallas).

Single-token decode attention over a paged KV cache: K/V live in a fixed
pool of ``[n_pages, block_size]`` token pages and each sequence names its
pages through a block table, so the kernel gathers exactly the pages a
context occupies instead of streaming a ``max_len`` stripe per sequence —
the block size *is* the memory-access granularity, which is what the
paper's hierarchy tables price.

Grid is ``(batch, heads)`` — or ``(batch, heads, num_splits)`` in the
split-KV "flash-decoding" form.  The GQA page panel for a query head
resolves in the BlockSpec index_map (like ``flash_attention``), and the
inner loop walks the sequence's valid pages with the online-softmax
(m, l, acc) recurrence.  Page ids are data (loaded from the block-table
ref), so the K/V loads use ``pl.ds`` dynamic slices; the loop trip count
is the sequence's own ``ceil(ctx / block_size)``, so short contexts cost
few iterations regardless of the table width.

Split-KV decoding (``num_splits > 1``): one ``(b, h)`` cell otherwise
serializes the whole context on one core while the rest of the chip
idles — the memory-latency-hiding bound the paper measures.  The split
form partitions a sequence's valid pages into ``num_splits`` contiguous
slices; each slice runs the same recurrence independently over pages
``[lo, hi)`` and emits its *partial* ``(m, l, acc)`` row, and a second
pass merges partials with the standard log-sum-exp rescale
(``_merge_partials``).  A split whose slice is empty (``lo >= hi`` —
``num_splits`` exceeds the sequence's valid pages, or ``ctx == 0``)
runs zero iterations and emits the identity partial
``(m=NEG_INF, l=0, acc=0)``, which the merge weights to exactly zero.

The pure-jnp oracle is ``repro.kernels.ref.paged_attention_ref`` (what
CPU CI asserts against); the model-side reference path used by the paged
serving engine lives in ``models.layers.attention`` (it also handles the
paged *write*).

Two lowerings share one wrapper signature:

* ``paged_attention`` — the in_specs declare the whole page pool as one
  block per grid cell.  Exact in interpret mode and fine for CI-sized
  pools, but it stages the *pool* into VMEM.
* ``paged_attention_hbm`` — the HBM-resident lowering: ``k_pages`` /
  ``v_pages`` stay in ``ANY``/HBM memory space and each loop iteration
  async-copies only the table-selected page into a double-buffered VMEM
  scratch (page ``j+1``'s DMA is issued before page ``j`` is consumed),
  so VMEM holds exactly two K pages + two V pages + the q/acc rows —
  the pipelined working set the autotuner's ``space._pa_vmem`` prices,
  independent of pool size.  The double-buffer pipeline is per-split:
  each split's slice walks its own consecutive ``j`` range, so the
  two-slot parity scheme works unchanged and VMEM still holds exactly
  two K + two V pages per grid cell regardless of ``num_splits``.

``kernels.ops.paged_attention`` routes to the HBM lowering on real TPUs
(and on request in interpret mode, which CPU CI asserts against the
oracle); the staged lowering remains the small-pool/debug path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _attend_page(q, k, v, raw, j, ctx, carry, *, window, softcap,
                 block_size):
    """One online-softmax step over page ``j`` — shared by all four
    kernel bodies so the split and unsplit lowerings compute the same
    math on the same page in the same order."""
    m, l, acc = carry
    s = q @ k.T                                           # [1, bs]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
    # in-ctx positions whose table entry is -1 (unbacked page) must
    # mask, not attend the clipped page 0 — matches the ref oracle
    mask = (k_pos < ctx) & (raw >= 0)                     # causal by layout
    if window is not None:
        mask &= (ctx - 1 - k_pos) < window
    s = jnp.where(mask[None, :], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[:, None] + p @ v
    return m_new, l_new, acc_new


def _carry_init(D):
    return (jnp.full((1,), NEG_INF, jnp.float32),
            jnp.zeros((1,), jnp.float32),
            jnp.zeros((1, D), jnp.float32))


def _split_bounds(ctx, block_size, num_splits):
    """[lo, hi) page range of this grid cell's split — contiguous slices
    of the sequence's valid pages; trailing splits may be empty."""
    n_valid = pl.cdiv(ctx, block_size)                    # traced trip count
    pages_per_split = pl.cdiv(n_valid, num_splits)
    lo = pl.program_id(2) * pages_per_split
    hi = jnp.minimum(lo + pages_per_split, n_valid)
    return lo, hi


def _merge_partials(m, l, acc, out_dtype):
    """Second flash-decoding pass: fold per-split partial softmax rows
    (``m/l [B,H,S]``, ``acc [B,H,S,D]``) with the log-sum-exp rescale.
    Identity partials (m=NEG_INF, l=0, acc=0) get weight exp(-huge)=0;
    all-identity rows (ctx == 0) divide 0 by the 1e-30 floor and come
    out all-zero, matching the oracle."""
    m_star = jnp.max(m, axis=-1, keepdims=True)           # [B,H,1]
    alpha = jnp.exp(m - m_star)                           # [B,H,S]
    l_star = jnp.sum(l * alpha, axis=-1)                  # [B,H]
    out = jnp.sum(acc * alpha[..., None], axis=2)         # [B,H,D]
    return (out / jnp.maximum(l_star, 1e-30)[..., None]).astype(out_dtype)


def _pa_kernel(q_ref, bt_ref, ctx_ref, k_ref, v_ref, o_ref, *, scale,
               window, softcap, block_size, n_pages):
    q = q_ref[0].astype(jnp.float32) * scale              # [1, D]
    D = q.shape[-1]
    ctx = ctx_ref[0, 0]
    n_valid = pl.cdiv(ctx, block_size)                    # traced trip count

    def body(j, carry):
        raw = bt_ref[0, j]
        pid = jnp.clip(raw, 0, n_pages - 1)
        k = k_ref[pl.ds(pid, 1)][0, :, 0].astype(jnp.float32)  # [bs, D]
        v = v_ref[pl.ds(pid, 1)][0, :, 0].astype(jnp.float32)
        return _attend_page(q, k, v, raw, j, ctx, carry, window=window,
                            softcap=softcap, block_size=block_size)

    _, l, acc = jax.lax.fori_loop(0, n_valid, body, _carry_init(D))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _pa_split_kernel(q_ref, bt_ref, ctx_ref, k_ref, v_ref, m_ref, l_ref,
                     acc_ref, *, scale, window, softcap, block_size,
                     n_pages, num_splits):
    """First flash-decoding pass, staged-pool form: the ``(b, h, s)``
    cell runs the recurrence over its slice of valid pages and writes
    the partial (m, l, acc) row instead of a normalized output."""
    q = q_ref[0].astype(jnp.float32) * scale              # [1, D]
    D = q.shape[-1]
    ctx = ctx_ref[0, 0]
    lo, hi = _split_bounds(ctx, block_size, num_splits)

    def body(j, carry):
        raw = bt_ref[0, j]
        pid = jnp.clip(raw, 0, n_pages - 1)
        k = k_ref[pl.ds(pid, 1)][0, :, 0].astype(jnp.float32)  # [bs, D]
        v = v_ref[pl.ds(pid, 1)][0, :, 0].astype(jnp.float32)
        return _attend_page(q, k, v, raw, j, ctx, carry, window=window,
                            softcap=softcap, block_size=block_size)

    m, l, acc = jax.lax.fori_loop(lo, hi, body, _carry_init(D))
    m_ref[0, 0] = m
    l_ref[0, 0] = l
    acc_ref[0, 0] = acc


def _pa_specs(B, H, D, NB, P, bs, group, *, hbm, num_splits):
    """in/out BlockSpecs + out_shape for either grid form.  The split
    form's outputs are the f32 partial rows; the merge runs in plain
    jnp outside the kernel (tiny: [B,H,S] rows)."""
    if num_splits == 1:
        q_map = lambda b, h: (b, h, 0)                     # noqa: E731
        bt_map = lambda b, h: (b, 0)                       # noqa: E731
        pool_map = lambda b, h, g=group: (0, 0, h // g, 0)  # noqa: E731
        out_specs = pl.BlockSpec((1, 1, D), q_map)
        out_shape = None                                   # caller fills
    else:
        q_map = lambda b, h, s: (b, h, 0)                  # noqa: E731
        bt_map = lambda b, h, s: (b, 0)                    # noqa: E731
        pool_map = lambda b, h, s, g=group: (0, 0, h // g, 0)  # noqa: E731
        part_map = lambda b, h, s: (b, h, s)               # noqa: E731
        acc_map = lambda b, h, s: (b, h, s, 0)             # noqa: E731
        out_specs = [
            pl.BlockSpec((1, 1, 1), part_map),
            pl.BlockSpec((1, 1, 1), part_map),
            pl.BlockSpec((1, 1, 1, D), acc_map),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((B, H, num_splits), jnp.float32),
            jax.ShapeDtypeStruct((B, H, num_splits), jnp.float32),
            jax.ShapeDtypeStruct((B, H, num_splits, D), jnp.float32),
        ]
    pool_spec = (pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
                 if hbm else pl.BlockSpec((P, bs, 1, D), pool_map))
    in_specs = [
        pl.BlockSpec((1, 1, D), q_map),
        pl.BlockSpec((1, NB), bt_map),
        pl.BlockSpec((1, 1), bt_map),
        pool_spec,
        pool_spec,
    ]
    return in_specs, out_specs, out_shape


def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    scale=None, window=None, softcap=None, num_splits=1,
                    interpret=False):
    """q [B,H,D]; k/v_pages [P,bs,KH,D]; block_tables [B,NB] int32 (-1 =
    unbacked); context_lens [B] int32 -> [B,H,D].

    Attention of one new token per sequence over its paged context: the
    query position is ``context_lens - 1`` (causality holds by
    construction — only written positions are < ctx), with optional
    sliding ``window`` and logit ``softcap`` matching the flash kernel.
    Rows with ``context_lens == 0`` produce zeros (masked everywhere).

    ``num_splits > 1`` selects the split-KV flash-decoding form: grid
    ``(B, H, num_splits)``, per-split partial (m, l, acc) rows, and a
    log-sum-exp merge pass — same outputs up to summation order.
    """
    B, H, D = q.shape
    P, bs, KH, _ = k_pages.shape
    NB = block_tables.shape[1]
    scale = scale if scale is not None else D ** -0.5
    group = H // KH
    num_splits = max(int(num_splits), 1)

    in_specs, out_specs, out_shape = _pa_specs(
        B, H, D, NB, P, bs, group, hbm=False, num_splits=num_splits)
    operands = (q,
                jnp.asarray(block_tables, jnp.int32),
                jnp.asarray(context_lens, jnp.int32).reshape(B, 1),
                k_pages, v_pages)

    if num_splits == 1:
        return pl.pallas_call(
            functools.partial(_pa_kernel, scale=scale, window=window,
                              softcap=softcap, block_size=bs, n_pages=P),
            grid=(B, H),
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
            interpret=interpret,
        )(*operands)

    m, l, acc = pl.pallas_call(
        functools.partial(_pa_split_kernel, scale=scale, window=window,
                          softcap=softcap, block_size=bs, n_pages=P,
                          num_splits=num_splits),
        grid=(B, H, num_splits),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return _merge_partials(m, l, acc, q.dtype)


# ---------------------------------------------------------------------------
# the HBM-resident lowering
# ---------------------------------------------------------------------------


def _pa_hbm_loop(q_ref, bt_ref, ctx, k_hbm, v_hbm, *, scale, window,
                 softcap, block_size, n_pages, kh, lo, hi):
    """The double-buffered DMA pipeline over pages ``[lo, hi)``: issue
    page ``j+1``'s copies before waiting on page ``j`` so the gather
    overlaps the compute.  ``j`` runs consecutively within the range,
    so the two-slot parity scheme (``slot = j % 2``) holds for any
    split's ``lo`` — VMEM cost is two K + two V pages regardless of
    how many splits share the sequence.  Returns the final carry."""
    q = q_ref[0].astype(jnp.float32) * scale              # [1, D]
    D = q.shape[-1]

    def body(k_buf, v_buf, k_sem, v_sem):
        def dma(buf, hbm, sem, slot, j):
            pid = jnp.clip(bt_ref[0, j], 0, n_pages - 1)
            return pltpu.make_async_copy(hbm.at[pid, :, kh, :],
                                         buf.at[slot], sem.at[slot])

        @pl.when(hi > lo)
        def _():
            slot0 = jax.lax.rem(lo, 2)
            dma(k_buf, k_hbm, k_sem, slot0, lo).start()
            dma(v_buf, v_hbm, v_sem, slot0, lo).start()

        def step(j, carry):
            slot = jax.lax.rem(j, 2)
            nxt = jax.lax.rem(j + 1, 2)

            @pl.when(j + 1 < hi)
            def _():
                dma(k_buf, k_hbm, k_sem, nxt, j + 1).start()
                dma(v_buf, v_hbm, v_sem, nxt, j + 1).start()

            dma(k_buf, k_hbm, k_sem, slot, j).wait()
            dma(v_buf, v_hbm, v_sem, slot, j).wait()
            k = k_buf[slot].astype(jnp.float32)           # [bs, D]
            v = v_buf[slot].astype(jnp.float32)
            return _attend_page(q, k, v, bt_ref[0, j], j, ctx, carry,
                                window=window, softcap=softcap,
                                block_size=block_size)

        return jax.lax.fori_loop(lo, hi, step, _carry_init(D))

    return pl.run_scoped(
        body,
        k_buf=pltpu.VMEM((2, block_size, q_ref.shape[-1]), k_hbm.dtype),
        v_buf=pltpu.VMEM((2, block_size, q_ref.shape[-1]), v_hbm.dtype),
        k_sem=pltpu.SemaphoreType.DMA((2,)),
        v_sem=pltpu.SemaphoreType.DMA((2,)))


def _pa_hbm_kernel(q_ref, bt_ref, ctx_ref, k_hbm, v_hbm, o_ref, *, scale,
                   window, softcap, block_size, n_pages, group):
    """Same online-softmax recurrence as ``_pa_kernel``, but ``k_hbm`` /
    ``v_hbm`` are unblocked ``ANY``-space refs of the WHOLE pool, walked
    through the double-buffered DMA pipeline (``_pa_hbm_loop``)."""
    ctx = ctx_ref[0, 0]
    n_valid = pl.cdiv(ctx, block_size)                    # traced trip count
    kh = pl.program_id(1) // group                        # GQA panel
    _, l, acc = _pa_hbm_loop(q_ref, bt_ref, ctx, k_hbm, v_hbm, scale=scale,
                             window=window, softcap=softcap,
                             block_size=block_size, n_pages=n_pages, kh=kh,
                             lo=jnp.int32(0), hi=n_valid)
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _pa_split_hbm_kernel(q_ref, bt_ref, ctx_ref, k_hbm, v_hbm, m_ref,
                         l_ref, acc_ref, *, scale, window, softcap,
                         block_size, n_pages, group, num_splits):
    """First flash-decoding pass, HBM-resident form: the ``(b, h, s)``
    cell pipelines only its own page slice through the two-slot VMEM
    scratch and writes the partial (m, l, acc) row."""
    ctx = ctx_ref[0, 0]
    kh = pl.program_id(1) // group                        # GQA panel
    lo, hi = _split_bounds(ctx, block_size, num_splits)
    m, l, acc = _pa_hbm_loop(q_ref, bt_ref, ctx, k_hbm, v_hbm, scale=scale,
                             window=window, softcap=softcap,
                             block_size=block_size, n_pages=n_pages, kh=kh,
                             lo=lo, hi=hi)
    m_ref[0, 0] = m
    l_ref[0, 0] = l
    acc_ref[0, 0] = acc


def paged_attention_hbm(q, k_pages, v_pages, block_tables, context_lens, *,
                        scale=None, window=None, softcap=None, num_splits=1,
                        interpret=False):
    """``paged_attention`` with the page pool kept in HBM (``ANY`` memory
    space) and per-page double-buffered async copies — the production
    lowering for pools far larger than VMEM.  Same contract and oracle
    (``ref.paged_attention_ref``) as the staged lowering, including the
    ``num_splits`` flash-decoding form."""
    B, H, D = q.shape
    P, bs, KH, _ = k_pages.shape
    NB = block_tables.shape[1]
    scale = scale if scale is not None else D ** -0.5
    group = H // KH
    num_splits = max(int(num_splits), 1)

    in_specs, out_specs, out_shape = _pa_specs(
        B, H, D, NB, P, bs, group, hbm=True, num_splits=num_splits)
    operands = (q,
                jnp.asarray(block_tables, jnp.int32),
                jnp.asarray(context_lens, jnp.int32).reshape(B, 1),
                k_pages, v_pages)

    if num_splits == 1:
        return pl.pallas_call(
            functools.partial(_pa_hbm_kernel, scale=scale, window=window,
                              softcap=softcap, block_size=bs, n_pages=P,
                              group=group),
            grid=(B, H),
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
            interpret=interpret,
        )(*operands)

    m, l, acc = pl.pallas_call(
        functools.partial(_pa_split_hbm_kernel, scale=scale, window=window,
                          softcap=softcap, block_size=bs, n_pages=P,
                          group=group, num_splits=num_splits),
        grid=(B, H, num_splits),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return _merge_partials(m, l, acc, q.dtype)


# ---------------------------------------------------------------------------
# the sharded (mesh) route
# ---------------------------------------------------------------------------


def paged_attention_sharded(q, k_pages, v_pages, block_tables, context_lens,
                            mesh, *, scale=None, window=None, softcap=None,
                            num_splits=1, hbm=False, interpret=False):
    """Per-shard head slices of the paged kernel over a ``('data',
    'model')`` mesh: query heads and KV heads split over ``'model'``,
    batch rows over ``'data'``, block tables and context lengths
    replicated per model shard.

    Head cells of the ``(B, H[, num_splits])`` grid are independent (a
    query head only ever reads its own KV-head group), so sharding is a
    pure index-space split: each model shard runs the SAME kernel on its
    local ``H/m`` query heads against its local ``KH/m`` KV-head slice
    of every page — the GQA group size ``H/KH`` is invariant under the
    split, and no cross-shard merge is needed (the split-KV log-sum-exp
    merge stays shard-local).  Falls back to the unsharded call when the
    mesh cannot divide heads/batch evenly (the ``sanitize_specs``
    replication rule) or has no parallelism at all."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, H, D = q.shape
    KH = k_pages.shape[2]
    scale = scale if scale is not None else D ** -0.5
    kern = paged_attention_hbm if hbm else paged_attention
    call = functools.partial(kern, scale=scale, window=window,
                             softcap=softcap, num_splits=num_splits,
                             interpret=interpret)
    d_sz, m_sz = mesh.shape["data"], mesh.shape["model"]
    head_ok = m_sz == 1 or (H % m_sz == 0 and KH % m_sz == 0)
    batch_ok = d_sz == 1 or B % d_sz == 0
    if (d_sz * m_sz == 1) or not head_ok:
        return call(q, k_pages, v_pages, block_tables, context_lens)
    bax = "data" if (d_sz > 1 and batch_ok) else None
    hax = "model" if m_sz > 1 else None
    return shard_map(
        call, mesh,
        in_specs=(P(bax, hax, None),          # q: rows x head slice
                  P(None, None, hax, None),   # pools: KV-head slice
                  P(None, None, hax, None),
                  P(bax, None),               # tables: replicated per shard
                  P(bax,)),                   # context lengths
        out_specs=P(bax, hax, None),
        check_rep=False,
    )(q, k_pages, v_pages, block_tables, context_lens)
