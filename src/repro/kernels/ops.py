"""Jit'd public wrappers for all Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests (interpret mode executes the kernel body in Python — correctness, not
speed) and compile to Mosaic on real TPUs."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import (flash_attention as _fa, microbench_alu as _alu,
                           microbench_chase as _chase, mxu_probe as _mxu,
                           ssm_scan as _ssm, wkv6 as _wkv)


def _default_interpret():
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ssm_scan(x, dt, B, C, A, block_d=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssm.ssm_scan(x, dt, B, C, A, block_d=block_d,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r, k, v, w, u, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _wkv.wkv6(r, k, v, w, u, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("op", "length", "dependent",
                                             "interpret"))
def alu_chain(x, c, op="fma", length=64, dependent=True, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _alu.alu_chain(x, c, op=op, length=length, dependent=dependent,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("hops", "interpret"))
def pointer_chase(nxt, start, hops=1024, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _chase.pointer_chase(nxt, start, hops=hops, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chain", "block", "interpret"))
def mxu_probe(a, b, chain=4, block=(128, 128), interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mxu.mxu_probe(a, b, chain=chain, block=block,
                          interpret=interpret)
