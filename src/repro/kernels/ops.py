"""Jit'd public wrappers for all Pallas kernels, plus the tuned-config
dispatch path.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests (interpret mode executes the kernel body in Python — correctness, not
speed) and compile to Mosaic on real TPUs.

Launch configuration resolves in precedence order: explicit kwarg >
``config=`` mapping > autotuner cache lookup (``tuned=True`` consults the
installed ``repro.core.autotune`` handle) > the MXU-aligned default.  The
resolution happens OUTSIDE jit (each wrapper is a plain function over a
jitted inner), so tuned values become ordinary static arguments and the
lookup costs one dict probe per call."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# the single source of launch-config defaults and divisor clamping
# (space.py is jax-free, so this does not drag accelerators into
# analytic paths)
from repro.core.autotune.space import TUNABLES, divisor_clamp
from repro.kernels import (flash_attention as _fa, microbench_alu as _alu,
                           microbench_chase as _chase, mxu_probe as _mxu,
                           paged_attention as _pa, ssm_scan as _ssm,
                           wkv6 as _wkv)

# kernel name -> default launch config (the pre-autotuner hardcoded values)
KERNEL_DEFAULTS = {name: dict(t.default_config)
                   for name, t in TUNABLES.items()}


def _default_interpret():
    return jax.default_backend() != "tpu"


def resolve_kernel_config(kernel, shapes, dtype, *, config=None, tuned=False,
                          explicit=None):
    """The dispatch-path resolver: explicit kwargs > ``config`` mapping >
    installed-autotuner cache hit > defaults.  Returns a complete plain
    dict of launch parameters for ``kernel``."""
    out = dict(KERNEL_DEFAULTS[kernel])
    if config is None and tuned:
        from repro.core.autotune import tuned_config
        config = tuned_config(kernel, shapes, str(jnp.dtype(dtype).name))
    if config:
        out.update({k: config[k] for k in out if k in config})
    if explicit:
        out.update({k: v for k, v in explicit.items() if v is not None})
    return out


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "block_q", "block_k",
                                             "acc_dtype", "interpret"))
def _fa_jit(q, k, v, causal, window, softcap, scale, block_q, block_k,
            acc_dtype, interpret):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, block_q=block_q,
                               block_k=block_k, acc_dtype=acc_dtype,
                               interpret=interpret)


def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, block_q=None, block_k=None, acc_dtype=None,
                    config=None, tuned=False, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shapes = {"batch": q.shape[0], "seq_q": q.shape[1],
              "seq_kv": k.shape[1], "heads": q.shape[2],
              "kv_heads": k.shape[2], "head_dim": q.shape[3]}
    c = resolve_kernel_config(
        "flash_attention", shapes, q.dtype, config=config, tuned=tuned,
        explicit={"block_q": block_q, "block_k": block_k,
                  "acc_dtype": acc_dtype})
    return _fa_jit(q, k, v, causal, window, softcap, scale,
                   int(c["block_q"]), int(c["block_k"]),
                   str(c["acc_dtype"]), interpret)


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "interpret", "hbm",
                                             "num_splits"))
def _pa_jit(q, k_pages, v_pages, block_tables, context_lens, scale, window,
            softcap, interpret, hbm, num_splits):
    fn = _pa.paged_attention_hbm if hbm else _pa.paged_attention
    return fn(q, k_pages, v_pages, block_tables, context_lens, scale=scale,
              window=window, softcap=softcap, num_splits=num_splits,
              interpret=interpret)


def paged_attention(q, k_pages, v_pages, block_tables, context_lens,
                    scale=None, window=None, softcap=None, num_splits=None,
                    config=None, tuned=False, interpret=None, hbm=None):
    """Paged decode attention.  Two tunable axes resolve differently:
    ``block_size`` is a CACHE-LAYOUT parameter, fixed here by
    ``k_pages.shape[1]`` — the paged serving engine consults the tuning
    cache (``Autotuner.config_for('paged_attention', ...)``) when it lays
    out the block pool, not at dispatch time.  ``num_splits`` (the
    split-KV flash-decoding grid axis) is a pure LAUNCH parameter and
    resolves right here, in the standard precedence order (explicit
    kwarg > ``config=`` > ``tuned=True`` cache hit > default), clamped
    to the table width so every split covers >= 0 whole pages.

    ``hbm`` selects the HBM-resident lowering (the pool stays in ``ANY``
    memory space; pages are double-buffered into VMEM per iteration) —
    the default on real TPUs, where staging a serving-sized pool into
    VMEM cannot fly.  Off-TPU the staged lowering stays the default
    (interpret-mode DMA is slower); pass ``hbm=True`` to exercise the
    production path under interpret mode (what CPU CI does)."""
    interpret = _default_interpret() if interpret is None else interpret
    if hbm is None:
        hbm = jax.default_backend() == "tpu"
    NB = block_tables.shape[1]
    shapes = {"batch": q.shape[0], "heads": q.shape[1],
              "kv_heads": k_pages.shape[2], "head_dim": q.shape[2],
              "ctx": NB * k_pages.shape[1]}
    c = resolve_kernel_config("paged_attention", shapes, q.dtype,
                              config=config, tuned=tuned,
                              explicit={"num_splits": num_splits})
    splits = max(min(int(c.get("num_splits", 1)), NB), 1)
    return _pa_jit(q, k_pages, v_pages, block_tables, context_lens, scale,
                   window, softcap, interpret, bool(hbm), splits)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _ssm_jit(x, dt, B, C, A, block_d, interpret):
    return _ssm.ssm_scan(x, dt, B, C, A, block_d=block_d,
                         interpret=interpret)


def ssm_scan(x, dt, B, C, A, block_d=None, config=None, tuned=False,
             interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shapes = {"batch": x.shape[0], "seq": x.shape[1],
              "d_inner": x.shape[2], "state_dim": A.shape[1]}
    c = resolve_kernel_config("ssm_scan", shapes, x.dtype, config=config,
                              tuned=tuned, explicit={"block_d": block_d})
    return _ssm_jit(x, dt, B, C, A, int(c["block_d"]), interpret)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def _wkv_jit(r, k, v, w, u, block_h, interpret):
    return _wkv.wkv6(r, k, v, w, u, block_h=block_h, interpret=interpret)


def wkv6(r, k, v, w, u, block_h=None, config=None, tuned=False,
         interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shapes = {"batch": r.shape[0], "seq": r.shape[1],
              "heads": r.shape[2], "head_dim": r.shape[3]}
    c = resolve_kernel_config("wkv6", shapes, r.dtype, config=config,
                              tuned=tuned, explicit={"block_h": block_h})
    return _wkv_jit(r, k, v, w, u, int(c["block_h"]), interpret)


@functools.partial(jax.jit, static_argnames=("op", "length", "dependent",
                                             "interpret"))
def alu_chain(x, c, op="fma", length=64, dependent=True, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _alu.alu_chain(x, c, op=op, length=length, dependent=dependent,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("hops", "interpret"))
def pointer_chase(nxt, start, hops=1024, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _chase.pointer_chase(nxt, start, hops=hops, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chain", "block", "interpret"))
def _mxu_jit(a, b, chain, block, interpret):
    return _mxu.mxu_probe(a, b, chain=chain, block=block,
                          interpret=interpret)


def mxu_probe(a, b, chain=4, block=None, config=None, tuned=False,
              interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shapes = {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}
    explicit = None
    if block is not None:
        explicit = {"block_m": block[0], "block_n": block[1]}
    c = resolve_kernel_config("mxu_probe", shapes, a.dtype, config=config,
                              tuned=tuned, explicit=explicit)
    bm, bn = int(c["block_m"]), int(c["block_n"])
    if block is None:
        # config/cache-resolved blocks are perf hints (a bucketed cache
        # entry may not divide this exact problem): clamp to a divisor.
        # An EXPLICIT block= stays strict in the kernel — for measurement
        # callers the tile is the measured quantity itself.
        bm = divisor_clamp(bm, shapes["m"])
        bn = divisor_clamp(bn, shapes["n"])
    return _mxu_jit(a, b, chain, (bm, bn), interpret)
