"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels'
shape/dtype sweeps assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """q [B,Sq,H,D]; k,v [B,Skv,KH,D] -> [B,Sq,H,D] (f32 accumulation)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    if KH != H:
        rep = H // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= (qi - ki) < window
    s = jnp.where(m[None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(x, dt, B, C, A):
    """Selective scan. x,dt [Bt,S,Di]; B,C [Bt,S,N]; A [Di,N] -> y [Bt,S,Di].
    h_t = exp(dt*A)h + dt*B*x; y = C.h  (f32 state)."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * A)
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, c_t)
    h0 = jnp.zeros((x.shape[0], x.shape[2], A.shape[1]), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (x, dt, B, C))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def wkv6_ref(r, k, v, w, u):
    """RWKV6. r,k,v,w [B,S,H,N]; u [H,N] -> y [B,S,H,N] (f32 state)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, y
    B, S, H, N = r.shape
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)


def alu_chain_ref(x, c, *, op="fma", length=64, dependent=True):
    """The microbenchmark workload itself (so the kernel's arithmetic is
    verifiable, not just its timing)."""
    import repro.core.microbench.harness as H
    f = H.OPS[op]
    if dependent:
        y = x
        for _ in range(length):
            y = f(y, c)
        return y
    ys = [f(x + i, c) for i in range(length)]
    out = ys[0]
    for y in ys[1:]:
        out = out + y * 0
    return out


def pointer_chase_ref(nxt, start, hops):
    def body(_, i):
        return nxt[i]
    return jax.lax.fori_loop(0, hops, body, start)


def mxu_probe_ref(a, b, *, chain=1):
    """Dependent tile-matmul chain: C <- (A @ C) * eps, `chain` times."""
    c = b
    for _ in range(chain):
        c = (jnp.dot(a.astype(jnp.float32), c.astype(jnp.float32))
             * 0.001).astype(b.dtype)
    return c


def gather_pages(pages, block_tables):
    """Paged KV -> logical view.  pages [P,bs,KH,D]; block_tables [B,NB]
    (-1 = unbacked, gathered as page 0 and masked by the caller) ->
    [B, NB*bs, KH, D]."""
    P, bs = pages.shape[0], pages.shape[1]
    NB = block_tables.shape[1]
    lslot = jnp.arange(NB * bs, dtype=jnp.int32)
    page = block_tables[:, lslot // bs]                    # [B, NB*bs]
    idx = jnp.where(page >= 0, page * bs + (lslot % bs)[None], 0)
    return pages.reshape((P * bs,) + pages.shape[2:])[idx]


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens, *,
                        scale=None, window=None, softcap=None):
    """Single-token decode attention through a block table (the oracle for
    kernels.paged_attention).  q [B,H,D]; k/v_pages [P,bs,KH,D];
    block_tables [B,NB]; context_lens [B] -> [B,H,D] (f32 accumulation)."""
    B, H, D = q.shape
    bs, KH = k_pages.shape[1], k_pages.shape[2]
    NB = block_tables.shape[1]
    k = gather_pages(k_pages, block_tables)                # [B, L, KH, D]
    v = gather_pages(v_pages, block_tables)
    if KH != H:
        rep = H // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    lslot = jnp.arange(NB * bs, dtype=jnp.int32)[None]     # [1, L]
    ctx = context_lens[:, None]
    valid = (lslot < ctx) & (block_tables[:, lslot[0] // bs] >= 0)
    if window is not None:
        valid &= (ctx - 1 - lslot) < window
    s = jnp.where(valid[:, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (ctx == 0): uniform p, zeroed out explicitly
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    out = jnp.where((context_lens > 0)[:, None, None], out, 0.0)
    return out.astype(q.dtype)
