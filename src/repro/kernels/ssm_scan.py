"""Selective-SSM (Mamba) scan kernel (TPU Pallas).

The recurrence h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t is evaluated with
the state h [bd, N] resident in VMEM scratch across the whole sequence — the
HBM traffic is exactly one read of (x, dt, B, C) and one write of y, which
is the kernel's reason to exist: the lax.scan reference round-trips the
state through HBM every step.  Grid tiles the channel dimension (bd) so one
kernel instance's state fits VMEM regardless of d_inner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.autotune.space import divisor_clamp


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, *, seq):
    A = a_ref[...].astype(jnp.float32)                    # [bd, N]
    bd, N = A.shape
    h0 = jnp.zeros((bd, N), jnp.float32)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)             # [bd]
        dt_t = dt_ref[0, t].astype(jnp.float32)           # [bd]
        b_t = b_ref[0, t].astype(jnp.float32)             # [N]
        c_t = c_ref[0, t].astype(jnp.float32)             # [N]
        dA = jnp.exp(dt_t[:, None] * A)                   # [bd, N]
        h = dA * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t] = (h @ c_t).astype(y_ref.dtype)       # [bd]
        return h

    jax.lax.fori_loop(0, seq, step, h0)


def ssm_scan(x, dt, B, C, A, *, block_d=256, interpret=False):
    """x,dt [Bt,S,Di]; B,C [Bt,S,N]; A [Di,N] -> y [Bt,S,Di].

    ``block_d`` (the autotuner's channel-tile axis) is clamped to the
    largest common divisor of d_inner so any candidate launches cleanly.
    """
    Bt, S, Di = x.shape
    N = A.shape[1]
    block_d = divisor_clamp(block_d, Di)
    grid = (Bt, Di // block_d)
    return pl.pallas_call(
        functools.partial(_ssm_kernel, seq=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, S, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, S, N), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((block_d, N), lambda b, d: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, block_d), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, Di), x.dtype),
        interpret=interpret,
    )(x, dt, B, C, A)
