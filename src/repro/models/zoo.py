"""Unified model API over all assigned architectures.

``build_model(cfg)`` returns a ``Model`` whose members are pure functions
(ready for jax.jit / .lower()):

  init(key)                         -> params
  param_specs()                     -> PartitionSpec pytree (mirrors params)
  loss(params, batch)               -> (scalar loss, aux dict)
  prefill(params, batch, max_len)   -> (last_logits, cache)
  decode(params, cache, tokens,pos) -> (logits, new cache)
  decode_step(params, cache, tokens, pos) -> (next_tokens, new cache)
  init_cache(batch, max_len)        -> decode cache
  cache_specs(batch_axes, seq_axis) -> PartitionSpec pytree for the cache
  input_specs(cell)                 -> ShapeDtypeStructs for a shape cell
  input_shardings(cell, batch_axes) -> PartitionSpecs for those inputs
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelCfg, ShapeCell
from repro.models import encdec as encdec_mod
from repro.models import transformer as lm_mod


def cross_entropy(logits, labels, mask=None):
    """logits [B,S,V] (f32), labels [B,S] -> mean nll over unmasked tokens."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# how many stub patch embeddings each shape cell gets for the VLM arch
_VLM_PATCHES = {"train_4k": 576, "prefill_32k": 2880, "decode_32k": 2880,
                "long_500k": 2880}


def fused_decode_step(decode):
    """Build a ``decode_step`` from a ``decode``: greedy argmax over the
    last-position logit head (``transformer._last_pos_head``), fused so a
    jitted caller returns ``[B]`` int32 tokens and the ``[B, vocab]``
    logit matrix never crosses the step boundary.  THE one
    implementation — both model builders and the serving engines'
    fallback (for harness fakes that only define ``decode``) wrap it."""
    def decode_step(params, cache, tokens, pos, block_tables=None):
        logits, cache = decode(params, cache, tokens, pos, block_tables)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return decode_step


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelCfg
    init: Callable
    param_specs: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    cache_specs: Callable
    input_specs: Callable
    input_shardings: Callable
    # paged serving (None where the family has no paged KV cache):
    # init_paged_cache(n_blocks, block_size, mesh=None) -> pool (laid
    # out sharded when a serving mesh is passed); decode then takes
    # an optional block_tables=[B,NB] arg routing K/V through the pool
    init_paged_cache: Optional[Callable] = None
    # the fused decode hot path: greedy sampling (argmax over the
    # last-position logit head) runs INSIDE the step, so a jitted/AOT
    # caller moves only [B] int32 tokens across the host boundary
    # instead of [B, vocab] logits.  Same signature as ``decode`` but
    # returns (next_tokens [B] int32, new_cache).
    decode_step: Optional[Callable] = None


def _frontend_width(cfg: ModelCfg, cell: ShapeCell) -> int:
    if cfg.frontend == "vision":
        return _VLM_PATCHES[cell.name]
    return 0


def build_model(cfg: ModelCfg) -> Model:
    if cfg.encdec is not None:
        return _build_encdec(cfg)
    return _build_lm(cfg)


# ---------------------------------------------------------------------------
# decoder-only LMs (dense / moe / hybrid / ssm / vlm)


def _build_lm(cfg: ModelCfg) -> Model:
    def init(key):
        return lm_mod.init_lm(key, cfg)

    def param_specs():
        return lm_mod.lm_specs(cfg)

    def loss(params, batch):
        logits, aux, _ = lm_mod.lm_apply(
            params, cfg, tokens=batch["tokens"], mode="train",
            prefix_embeds=batch.get("prefix_embeds"))
        l = cross_entropy(logits, batch["labels"])
        if cfg.moe:
            l = (l + cfg.moe.router_aux_coef * aux["moe_load_balance"]
                 + cfg.moe.router_z_coef * aux["moe_router_z"])
        aux = dict(aux, ce=l)
        return l, aux

    def prefill(params, batch, max_len=None):
        logits, _, cache = lm_mod.lm_apply(
            params, cfg, tokens=batch["tokens"], mode="prefill",
            prefix_embeds=batch.get("prefix_embeds"), max_len=max_len)
        return logits[:, -1, :], cache

    def decode(params, cache, tokens, pos, block_tables=None):
        logits, _, cache = lm_mod.lm_apply(
            params, cfg, tokens=tokens, mode="decode", cache=cache,
            write_pos=pos, block_tables=block_tables)
        return logits[:, -1, :], cache

    def init_cache(batch, max_len):
        return lm_mod.init_decode_cache(cfg, batch, max_len)

    def init_paged_cache(n_blocks, block_size, mesh=None):
        return lm_mod.init_paged_decode_cache(cfg, n_blocks, block_size,
                                              mesh=mesh)

    def cache_specs(batch_axes=("data",), seq_axis="model"):
        return lm_mod.decode_cache_specs(cfg, batch_axes, seq_axis)

    def input_specs(cell: ShapeCell):
        B, S = cell.global_batch, cell.seq_len
        pfx = _frontend_width(cfg, cell)
        tok = lambda s: jax.ShapeDtypeStruct((B, s), jnp.int32)
        if cell.kind == "train":
            out = {"tokens": tok(S), "labels": tok(S)}
            if pfx:
                out["prefix_embeds"] = jax.ShapeDtypeStruct(
                    (B, pfx, cfg.d_model), jnp.bfloat16)
            return out
        if cell.kind == "prefill":
            out = {"tokens": tok(S)}
            if pfx:
                out["prefix_embeds"] = jax.ShapeDtypeStruct(
                    (B, pfx, cfg.d_model), jnp.bfloat16)
            return out
        # decode: one new token against a seq_len cache
        cache = jax.eval_shape(lambda: init_cache(B, S))
        return {"tokens": tok(1), "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
                "cache": cache}

    def input_shardings(cell: ShapeCell, batch_axes=("data",),
                        seq_axis="model"):
        bspec = P(batch_axes, None)
        if cell.kind == "train":
            out = {"tokens": bspec, "labels": bspec}
            if _frontend_width(cfg, cell):
                out["prefix_embeds"] = P(batch_axes, None, None)
            return out
        if cell.kind == "prefill":
            out = {"tokens": bspec}
            if _frontend_width(cfg, cell):
                out["prefix_embeds"] = P(batch_axes, None, None)
            return out
        return {"tokens": bspec, "pos": P(batch_axes),
                "cache": cache_specs(batch_axes, seq_axis)}

    return Model(cfg, init, param_specs, loss, prefill, decode, init_cache,
                 cache_specs, input_specs, input_shardings,
                 init_paged_cache=init_paged_cache,
                 decode_step=fused_decode_step(decode))


# ---------------------------------------------------------------------------
# encoder-decoder (seamless)


def _build_encdec(cfg: ModelCfg) -> Model:
    def init(key):
        return encdec_mod.init_encdec(key, cfg)

    def param_specs():
        return encdec_mod.encdec_specs(cfg)

    def loss(params, batch):
        logits, aux, _ = encdec_mod.encdec_apply(
            params, cfg, tokens=batch["tokens"], frames=batch["frames"],
            mode="train")
        l = cross_entropy(logits, batch["labels"])
        return l, dict(aux, ce=l)

    def prefill(params, batch, max_len=None):
        logits, _, cache = encdec_mod.encdec_apply(
            params, cfg, tokens=batch["tokens"], frames=batch["frames"],
            mode="prefill", max_len=max_len)
        return logits[:, -1, :], cache

    def decode(params, cache, tokens, pos, block_tables=None):
        if block_tables is not None:
            raise NotImplementedError("no paged decode for encoder-decoder")
        logits, _, cache = encdec_mod.encdec_apply(
            params, cfg, tokens=tokens, mode="decode", cache=cache,
            write_pos=pos)
        return logits[:, -1, :], cache

    def init_cache(batch, max_len, enc_len=None):
        return encdec_mod.init_encdec_cache(cfg, batch, max_len,
                                            enc_len or max_len)

    def cache_specs(batch_axes=("data",), seq_axis="model"):
        return encdec_mod.encdec_cache_specs(batch_axes, seq_axis)

    def input_specs(cell: ShapeCell):
        B, S = cell.global_batch, cell.seq_len
        tok = lambda s: jax.ShapeDtypeStruct((B, s), jnp.int32)
        frames = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        if cell.kind == "train":
            return {"frames": frames, "tokens": tok(S), "labels": tok(S)}
        if cell.kind == "prefill":
            return {"frames": frames, "tokens": tok(S)}
        cache = jax.eval_shape(lambda: init_cache(B, S, S))
        return {"tokens": tok(1), "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
                "cache": cache}

    def input_shardings(cell: ShapeCell, batch_axes=("data",),
                        seq_axis="model"):
        bspec = P(batch_axes, None)
        fspec = P(batch_axes, None, None)
        if cell.kind == "train":
            return {"frames": fspec, "tokens": bspec, "labels": bspec}
        if cell.kind == "prefill":
            return {"frames": fspec, "tokens": bspec}
        return {"tokens": bspec, "pos": P(batch_axes),
                "cache": cache_specs(batch_axes, seq_axis)}

    return Model(cfg, init, param_specs, loss, prefill, decode, init_cache,
                 cache_specs, input_specs, input_shardings,
                 decode_step=fused_decode_step(decode))


def count_params(cfg: ModelCfg) -> int:
    """Total parameter count (from shapes only, no allocation)."""
    import math
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def count_active_params(cfg: ModelCfg) -> int:
    """Active-per-token parameter count (MoE: routed experts scaled by k/E)."""
    total = count_params(cfg)
    if not cfg.moe:
        return total
    m = cfg.moe
    expert_p = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = cfg.n_layers - m.first_k_dense
    routed = n_moe_layers * m.n_experts * expert_p
    active_routed = n_moe_layers * m.top_k * expert_p
    return total - routed + active_routed
