"""Selective-SSM (Mamba) mixer used as hymba's parallel SSM heads.

hymba runs attention heads and SSM heads IN PARALLEL inside every layer: both
paths read the same normed input; their pre-projection outputs are each
RMS-normed and mean-fused before the shared output projection.  This module
implements the SSM path; the fusion lives in the trunk.

The recurrence h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t, y_t = C_t.h_t + D x_t
is a lax.scan over the sequence (the jnp reference path used by the dry-run);
`repro.kernels.ssm_scan` is the blocked Pallas TPU kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.basic import act_fn


def init_mamba(key, cfg):
    d, s = cfg.d_model, cfg.ssm
    di = d                       # hymba: expand=1, d_inner == d_model
    k = jax.random.split(key, 6)
    lim = d ** -0.5
    u = lambda kk, shape, l: jax.random.uniform(kk, shape, jnp.float32, -l, l)
    return {
        "w_in": u(k[0], (d, 2 * di), lim),                    # x and gate z
        "conv": u(k[1], (s.conv_width, di), s.conv_width ** -0.5),
        "w_bcdt": u(k[2], (di, 2 * s.state_dim + s.dt_rank), di ** -0.5),
        "w_dt": u(k[3], (s.dt_rank, di), s.dt_rank ** -0.5),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, s.state_dim + 1,
                                             dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
    }


def mamba_specs(cfg):
    return {
        "w_in": P("data", "model"),
        "conv": P(None, "model"),
        "w_bcdt": P("model", None),
        "w_dt": P(None, "model"),
        "dt_bias": P(None),
        "a_log": P("model", None),
        "d_skip": P(None),
    }


def _ssm_scan_ref(xc, dt, B, C, A, h0, chunk=256):
    """xc,dt [Bt,S,di]; B,C [Bt,S,N]; A [di,N]; h0 [Bt,di,N] f32.
    Returns (y [Bt,S,di], hT).

    Two-level scan with remat on the inner chunk (sqrt-remat): only the
    chunk-boundary states are saved for the backward pass, bounding the
    recurrence's residual memory to S/chunk boundary states instead of S
    per-step ones.
    """
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * A)                      # [Bt,di,N]
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    def run(h, xs):
        return jax.lax.scan(step, h, xs)

    S = xc.shape[1]
    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    if S <= chunk or S % chunk != 0:
        hT, ys = run(h0, xs)
    else:
        n = S // chunk
        xs_c = jax.tree.map(lambda t: t.reshape((n, chunk) + t.shape[1:]), xs)
        run_ck = jax.checkpoint(
            run, policy=jax.checkpoint_policies.nothing_saveable)
        hT, ys = jax.lax.scan(run_ck, h0, xs_c)
        ys = ys.reshape((S,) + ys.shape[2:])
    return jnp.moveaxis(ys, 0, 1), hT


def mamba_mixer(p, x, cfg, state=None, need_state=True):
    """x [Bt,S,D] -> (y_pre [Bt,S,di], new_state).

    state (decode): {'conv': [Bt,W-1,di], 'h': [Bt,di,N]} or None (train).
    y_pre is the pre-output-projection SSM path (gated), to be fused with the
    attention path by the trunk.  With ``need_state=False`` (training: the
    returned hT is never consumed) the Pallas kernel path applies.
    """
    s = cfg.ssm
    cdt = x.dtype
    Bt, S, D = x.shape
    di = D
    xz = jnp.einsum("bsd,dz->bsz", x, p["w_in"].astype(cdt))
    xr, z = xz[..., :di], xz[..., di:]

    # depthwise causal conv (width W): pad with state buffer when decoding
    W = s.conv_width
    if state is not None:
        buf = state["conv"].astype(cdt)                        # [Bt,W-1,di]
        xin = jnp.concatenate([buf, xr], axis=1)
        new_conv = xin[:, -(W - 1):, :]
    else:
        xin = jnp.pad(xr, ((0, 0), (W - 1, 0), (0, 0)))
        new_conv = xin[:, -(W - 1):, :]
    conv_w = p["conv"].astype(cdt)
    xc = sum(xin[:, i:i + S, :] * conv_w[i] for i in range(W))
    xc = act_fn("silu")(xc)

    bcdt = jnp.einsum("bsd,dz->bsz", xc, p["w_bcdt"].astype(cdt))
    Bm = bcdt[..., :s.state_dim]
    Cm = bcdt[..., s.state_dim:2 * s.state_dim]
    dt_low = bcdt[..., 2 * s.state_dim:]
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_low,
                                    p["w_dt"].astype(cdt)).astype(jnp.float32)
                         + p["dt_bias"])
    A = -jnp.exp(p["a_log"])                                   # [di,N]
    h0 = (state["h"] if state is not None
          else jnp.zeros((Bt, di, s.state_dim), jnp.float32))
    if cfg.use_pallas and state is None and not need_state:
        # TPU hot path: VMEM-resident scan state (kernels/ssm_scan); only
        # valid when hT is never read (train).  tuned=True picks up the
        # autotuned channel tile (block_d).
        from repro.kernels import ops as kops
        y = kops.ssm_scan(xc, dt, Bm, Cm, A, tuned=True)
        hT = h0
    else:
        y, hT = _ssm_scan_ref(xc, dt, Bm, Cm, A, h0)
    y = (y.astype(cdt) + xc * p["d_skip"].astype(cdt)) * act_fn("silu")(z)
    new_state = {"conv": new_conv.astype(jnp.bfloat16), "h": hT}
    return y, new_state


def init_mamba_state(cfg, batch, n_layers):
    s = cfg.ssm
    di = cfg.d_model
    return {
        "conv": jnp.zeros((n_layers, batch, s.conv_width - 1, di), jnp.bfloat16),
        "h": jnp.zeros((n_layers, batch, di, s.state_dim), jnp.float32),
    }


def mamba_state_specs(batch_axes=("data",)):
    return {"conv": P(None, batch_axes, None, "model"),
            "h": P(None, batch_axes, "model", None)}
