from repro.models.layers import (attention, basic, mamba, mla, moe,  # noqa
                                 rwkv)
