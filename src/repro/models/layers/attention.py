"""GQA attention with memory-efficient (query-chunked) softmax.

Design notes
------------
* The score tensor is never materialized for the full (Sq, Skv) square: a
  ``lax.scan`` over query chunks bounds the transient to (chunk, Skv), which
  is the flash-attention memory behaviour expressed in pure jnp so the 512-way
  SPMD dry-run can lower it on any backend.  The Pallas TPU kernel
  (`repro.kernels.flash_attention`) is the hardware hot path.
* Sliding-window ("local") layers and full ("global") layers share one code
  path: the window is data (a mask term), not structure, so a scan over
  stacked layer params stays uniform.
* ``n_sink`` positions (hymba meta tokens) are always attendable even outside
  a local window.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.basic import apply_rope, rmsnorm, rope_tables
from repro.sharding import ctx

NEG_INF = -2.0e38


def init_attention(key, cfg):
    d, kh, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    h = cfg.padded_heads
    k = jax.random.split(key, 4)
    lim = d ** -0.5
    p = {
        "wq": jax.random.uniform(k[0], (d, h, hd), jnp.float32, -lim, lim),
        "wk": jax.random.uniform(k[1], (d, kh, hd), jnp.float32, -lim, lim),
        "wv": jax.random.uniform(k[2], (d, kh, hd), jnp.float32, -lim, lim),
        "wo": jax.random.uniform(k[3], (h, hd, d), jnp.float32,
                                 -(h * hd) ** -0.5, (h * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.zeros((hd,), jnp.float32)}
    return p


def attention_specs(cfg):
    s = {
        "wq": P("data", "model", None),
        "wk": P("data", "model", None),
        "wv": P("data", "model", None),
        "wo": P("model", None, "data"),
    }
    if cfg.qk_norm:
        s["q_norm"] = {"scale": P(None)}
        s["k_norm"] = {"scale": P(None)}
    return s


def _mask(qpos, kpos, *, causal, window, n_sink, is_global=True):
    """qpos [B,Sq], kpos [B,Skv] -> bool [B,Sq,Skv] (True = attendable).

    ``is_global`` may be a traced bool scalar (layers are scanned with the
    local/global pattern as data); when True the window term is disabled.
    """
    q = qpos[:, :, None]
    k = kpos[:, None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        m &= k <= q
    if window is not None:
        inside = (q - k) < window
        if n_sink:
            inside |= k < n_sink
        m &= inside | jnp.asarray(is_global, bool)
    m &= k >= 0  # kpos = -1 marks invalid (unwritten cache slots)
    return m


def _attend_chunk(q, k, v, qpos, kpos, *, scale, causal, window, n_sink, cap,
                  is_global, kv_map=None):
    """q [B,Cq,H,D], k/v [B,Skv,KH,D] -> [B,Cq,H,D]. Full-KV score per chunk."""
    B, Cq, H, D = q.shape
    KH = k.shape[2]
    if kv_map is not None and (KH != H or any(
            m != h // max(H // KH, 1) for h, m in enumerate(kv_map))):
        idx = jnp.asarray(kv_map, jnp.int32)
        k = jnp.take(k, idx, axis=2)
        v = jnp.take(v, idx, axis=2)
    elif KH != H:  # GQA: broadcast kv heads across query groups
        rep = H // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    m = _mask(qpos, kpos, causal=causal, window=window, n_sink=n_sink,
              is_global=is_global)
    s = jnp.where(m[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _pick_chunk(sq: int, chunk: int):
    """Choose (chunk_used, padded_len): prefer an exact divisor >= chunk/2,
    else pad sq up to a multiple of `chunk` (padded query rows are masked to
    uniform garbage and sliced off)."""
    if sq % chunk == 0:
        return chunk, sq
    for c in range(chunk, chunk // 2 - 1, -1):
        if sq % c == 0:
            return c, sq
    pad = ((sq + chunk - 1) // chunk) * chunk
    return chunk, pad


def attend(q, k, v, qpos, kpos, *, scale, causal=True, window=None, n_sink=0,
           cap=None, chunk=512, is_global=True, kv_map=None):
    """Query-chunked attention. q [B,Sq,H,D]; k,v [B,Skv,KH,D]."""
    B, Sq, H, D = q.shape
    if Sq <= chunk:
        return _attend_chunk(q, k, v, qpos, kpos, scale=scale, causal=causal,
                             window=window, n_sink=n_sink, cap=cap,
                             is_global=is_global, kv_map=kv_map)
    chunk, padded = _pick_chunk(Sq, chunk)
    if padded != Sq:
        q = jnp.pad(q, ((0, 0), (0, padded - Sq), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, padded - Sq)),
                       constant_values=-(2 ** 30))
    n = padded // chunk
    qs = jnp.moveaxis(q.reshape(B, n, chunk, H, D), 1, 0)
    ps = jnp.moveaxis(qpos.reshape(B, n, chunk), 1, 0)

    # remat: the per-chunk scores/softmax are recomputed in the backward pass
    # instead of being stacked across chunks (which would materialize the full
    # (Sq, Skv) square the chunking exists to avoid).
    chunk_fn = jax.checkpoint(
        lambda qc, kk, vv, pc, kp, ig: _attend_chunk(
            qc, kk, vv, pc, kp, scale=scale, causal=causal, window=window,
            n_sink=n_sink, cap=cap, is_global=ig, kv_map=kv_map),
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(_, qc_pc):
        qc, pc = qc_pc
        o = chunk_fn(qc, k, v, pc, kpos, jnp.asarray(is_global, bool))
        return (), o

    _, outs = jax.lax.scan(body, (), (qs, ps))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, padded, H, D)
    return out[:, :Sq]


def _project_q(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q = ctx.constrain(q, "batch", None, "model", None)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    return q


def _project_kv(p, xk, cfg):
    cdt = xk.dtype
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", xk, p["wv"].astype(cdt))
    k = ctx.constrain(k, "batch", None, "model", None)
    v = ctx.constrain(v, "batch", None, "model", None)
    if cfg.qk_norm:
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return k, v


def _paged_update_gather(cache, k_new, v_new, block_tables, write_pos):
    """Write ``Sq`` new tokens per row into the paged KV pool through the
    block table, then gather the logical per-row K/V view for attention.

    cache         {'k','v': [n_blocks, bs, KH, hd]} the physical pool
    k_new, v_new  [B, Sq, KH, hd] projections for this call's tokens
    block_tables  [B, NB] int32 physical block per logical block (-1 =
                  unbacked; positions there are masked)
    write_pos     [B] first write position; may be NEGATIVE (left-padded
                  chunked-prefill calls, or inactive rows at -1) — those
                  token writes scatter out-of-bounds and are dropped

    Returns (new_cache, k [B,L,KH,hd], v, kpos [B,L]) with L = NB*bs; the
    gathered view is the pure-jnp CPU reference of the paged decode (the
    Pallas ``kernels.paged_attention`` gathers page-by-page on TPU).
    """
    ck, cv = cache["k"], cache["v"]
    nb, bs = ck.shape[0], ck.shape[1]
    B, Sq, KH, hd = k_new.shape
    NB = block_tables.shape[1]
    pos = write_pos[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
    blk = jnp.take_along_axis(block_tables,
                              jnp.clip(pos // bs, 0, NB - 1), axis=1)
    # flat physical slot per new token; invalid -> nb*bs, dropped by the
    # out-of-bounds scatter mode
    phys = jnp.where((pos >= 0) & (blk >= 0), blk * bs + pos % bs, nb * bs)

    def write(c, n):
        flat = c.reshape(nb * bs, KH, hd)
        flat = flat.at[phys.reshape(-1)].set(
            n.reshape(B * Sq, KH, hd).astype(c.dtype), mode="drop")
        return flat.reshape(c.shape)

    k_cache, v_cache = write(ck, k_new), write(cv, v_new)

    lslot = jnp.arange(NB * bs, dtype=jnp.int32)
    page = block_tables[:, lslot // bs]                     # [B, L]
    idx = jnp.where(page >= 0, page * bs + (lslot % bs)[None], 0)
    written = (page >= 0) & (lslot[None] <= write_pos[:, None] + Sq - 1)
    k = k_cache.reshape(nb * bs, KH, hd)[idx]
    v = v_cache.reshape(nb * bs, KH, hd)[idx]
    kpos = jnp.where(written, lslot[None], -1)
    return {"k": k_cache, "v": v_cache}, k, v, kpos


def attention(p, x, *, cfg, positions, is_global, theta=None,
              memory=None, mem_positions=None,
              cache: Optional[dict] = None, write_pos=None,
              block_tables=None, pre_output=False, causal=True):
    """Unified attention layer.

    x          [B,Sq,D]   layer input (post-norm)
    positions  [B,Sq]     absolute positions of x tokens
    is_global  bool/array scalar flag; local layers use cfg.window
    memory     [B,Sm,D]   if set: cross-attention onto encoder memory
    cache      {'k','v' : [B,Smax,KH,hd]} decode/prefill KV cache (self-attn)
               — or the paged pool [n_blocks,bs,KH,hd] with block_tables
    write_pos  [B]        decode: slot to write the new token's K/V
    block_tables [B,NB]   paged decode: per-row physical block ids; the
               cache is then the shared block pool and K/V are gathered
               through the table (``models`` CPU reference of the paged
               path; ``kernels.paged_attention`` is the TPU kernel)
    pre_output if True return pre-wo head outputs [B,Sq,H*hd] (hymba fusion)

    Returns (out, new_cache).
    """
    cdt = x.dtype
    B, Sq, _ = x.shape
    hd = cfg.head_dim
    scale = hd ** -0.5
    theta = cfg.rope_theta if theta is None else theta
    cross = memory is not None

    q = _project_q(p, x, cfg)
    if not cross:
        sin_q, cos_q = rope_tables(positions, hd, theta)
        q = apply_rope(q, sin_q, cos_q)

    new_cache = None
    if cross:
        if cache is not None and "k" in cache:   # cached encoder projections
            k, v = cache["k"].astype(cdt), cache["v"].astype(cdt)
        else:
            k, v = _project_kv(p, memory, cfg)
        kpos = mem_positions
        causal = False
        new_cache = {"k": k, "v": v}
    elif cache is None:
        k_new, v_new = _project_kv(p, x, cfg)
        k = apply_rope(k_new, sin_q, cos_q)
        v = v_new
        kpos = positions
        new_cache = {"k": k, "v": v}   # prefill: rope'd K, raw V
    elif block_tables is not None:
        # paged decode: scatter the new K/V through the block table into
        # the shared pool, gather the logical context view back, and
        # attend with unwritten/unbacked slots masked (kpos = -1)
        k_new, v_new = _project_kv(p, x, cfg)
        k_new = apply_rope(k_new, sin_q, cos_q)
        new_cache, k, v, kpos = _paged_update_gather(
            cache, k_new, v_new, block_tables, write_pos)
        k, v = k.astype(cdt), v.astype(cdt)
        causal = True
    else:
        # write new K/V into the cache at write_pos (per-row), then attend.
        k_new, v_new = _project_kv(p, x, cfg)
        k_new = apply_rope(k_new, sin_q, cos_q)

        if cfg.scatter_cache_update:
            # scatter keeps the (batch, seq)-sharded cache in place: the SPMD
            # partitioner masks updates shard-locally instead of re-gathering
            bi = jnp.arange(B, dtype=jnp.int32)[:, None]
            si = write_pos[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
            upd_b = lambda c, n: c.at[bi, si].set(n.astype(c.dtype),
                                                  mode="drop")
            k_cache = upd_b(cache["k"], k_new)
            v_cache = upd_b(cache["v"], v_new)
        else:
            def upd(c, n, wp):
                return jax.lax.dynamic_update_slice(c, n.astype(c.dtype),
                                                    (wp, 0, 0))
            k_cache = jax.vmap(upd)(cache["k"], k_new, write_pos)
            v_cache = jax.vmap(upd)(cache["v"], v_new, write_pos)
        k_cache = ctx.constrain(k_cache, "batch", "seq", None, None)
        v_cache = ctx.constrain(v_cache, "batch", "seq", None, None)
        new_cache = {"k": k_cache, "v": v_cache}
        k, v = k_cache.astype(cdt), v_cache.astype(cdt)
        Smax = k.shape[1]
        slot = jnp.arange(Smax, dtype=jnp.int32)[None, :]
        # slots beyond the write head are unwritten -> kpos=-1 (masked)
        written = slot <= (write_pos[:, None] + Sq - 1)
        kpos = jnp.where(written, slot, -1)
        causal = True

    Hp = cfg.padded_heads
    # is_global is usually a traced scalar (the layer scan carries the
    # local/global pattern as data); the kernel needs a STATIC window, so
    # the pallas path applies when the window question is static: either
    # is_global is a python bool, or the config has no window at all.
    # Under a ('data','model') serving mesh the jnp paged path partitions
    # through GSPMD (pool KV heads over 'model' — see ``paged_pool_spec``);
    # the explicit per-shard kernel route for TPU meshes is
    # ``kernels.paged_attention.paged_attention_sharded`` (head cells of
    # the (B,H,num_splits) grid are independent, so the shard_map split
    # runs the same kernel on local head slices).
    static_global = isinstance(is_global, bool)
    use_paged_kernel = (
        block_tables is not None and cfg.use_pallas and Sq == 1
        and not cross and Hp == cfg.n_heads
        and cfg.n_heads % max(cfg.n_kv_heads, 1) == 0
        and cfg.meta_tokens == 0
        and (static_global or cfg.window is None)
        and jax.default_backend() == "tpu")
    use_pallas = (
        cfg.use_pallas and cache is None and not cross
        and Hp == cfg.n_heads and cfg.n_heads % max(cfg.n_kv_heads, 1) == 0
        and cfg.meta_tokens == 0
        and (static_global or cfg.window is None))
    if use_paged_kernel:
        # TPU hot path for paged decode: gather K/V page-by-page through
        # the block table inside the kernel (the jnp gather above is dead
        # code XLA eliminates).  Context length = write position + 1.
        from repro.kernels import ops as kops
        window = cfg.window if static_global and not is_global else None
        # tuned=True: num_splits (the flash-decoding grid axis) resolves
        # from the installed tuning cache at trace time, like the flash
        # path's blocks — the serving engine installs its autotuner
        # around _step, so long contexts pick their tuned split factor
        out_h = kops.paged_attention(
            q[:, 0], new_cache["k"], new_cache["v"], block_tables,
            write_pos + 1, scale=scale, window=window,
            softcap=cfg.attn_softcap, tuned=True)[:, None]
    elif use_pallas:
        # TPU hot path: the blocked flash kernel (kernels/flash_attention);
        # ragged sequence tails are padded+masked inside the kernel.
        # tuned=True resolves block_q/block_k/acc_dtype from the installed
        # autotuner's cache (repro.core.autotune); without one the kernel's
        # MXU-aligned defaults apply.
        from repro.kernels import ops as kops
        window = cfg.window if static_global and not is_global else None
        out_h = kops.flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap, scale=scale, tuned=True)
    else:
        out_h = attend(q, k, v, positions, kpos, scale=scale, causal=causal,
                       window=None if cross else cfg.window,
                       n_sink=cfg.meta_tokens, cap=cfg.attn_softcap,
                       chunk=cfg.attn_chunk, is_global=is_global,
                       kv_map=cfg.kv_head_map() if Hp != cfg.n_heads else None)
    if Hp != cfg.n_heads:
        # zero the dead padded heads: outputs AND their weight grads vanish
        head_mask = (jax.lax.iota(jnp.int32, Hp) < cfg.n_heads)
        out_h = out_h * head_mask[None, None, :, None].astype(out_h.dtype)
    out_h = ctx.constrain(out_h.reshape(B, Sq, Hp * hd),
                          "batch", None, "model")
    if pre_output:
        return out_h, new_cache
    out = jnp.einsum("bsz,zd->bsd",
                     out_h, p["wo"].astype(cdt).reshape(Hp * hd, -1))
    return ctx.constrain(out, "batch", None, None), new_cache


def init_kv_cache(cfg, batch, max_len, n_layers, dtype=jnp.bfloat16):
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, max_len, kh, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_pool_spec(cfg, mesh=None):
    """The paged pool's partition spec ``[L, n_blocks, bs, KH, hd]``: KV
    heads over ``'model'`` (each model shard owns a head slice of EVERY
    block, so block ids — and the host-side allocator / eviction /
    compaction bookkeeping built on them — stay global), everything else
    replicated.  Falls back to full replication when the mesh's model
    axis cannot divide the KV heads evenly (``sanitize_specs`` rule)."""
    if mesh is not None and mesh.shape.get("model", 1) > 1 \
            and cfg.n_kv_heads % mesh.shape["model"] == 0:
        return P(None, None, None, "model", None)
    return P()


def init_paged_kv_cache(cfg, n_blocks, block_size, n_layers,
                        dtype=jnp.bfloat16, mesh=None):
    """The paged pool: ``n_blocks`` shared blocks of ``block_size`` token
    slots per layer — resident KV bytes scale with the pool, not with
    ``max_batch x max_len``.  With a ``mesh``, the pool is laid out
    sharded at birth (``paged_pool_spec``: KV heads over ``'model'``),
    so a sharded replica never materializes the replicated pool."""
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, n_blocks, block_size, kh, hd)
    pool = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if mesh is not None:
        from jax.sharding import NamedSharding
        sh = NamedSharding(mesh, paged_pool_spec(cfg, mesh))
        pool = jax.device_put(pool, {"k": sh, "v": sh})
    return pool


def kv_cache_specs(batch_axes=("data",), seq_axis="model"):
    """Decode caches shard batch over data and SEQUENCE over the model axis
    (flash-decode style) so tiny-kv-head archs (gemma3 kv=1) still scale."""
    spec = P(None, batch_axes, seq_axis, None, None)
    return {"k": spec, "v": spec}
