"""Norms, activations, rotary embeddings, token embeddings, MLPs.

Every layer exposes ``init_*`` (params), ``*_specs`` (PartitionSpec tree that
mirrors the params) and an apply function.  Specs use the logical mesh axis
names ``'data'`` (FSDP shard axis) and ``'model'`` (tensor-parallel axis);
the launcher maps them onto the production mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import ctx

# ---------------------------------------------------------------------------
# activations


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# norms


def init_rmsnorm(d):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm_specs():
    return {"scale": P(None)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"])).astype(dt)


def init_layernorm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_specs():
    return {"scale": P(None), "bias": P(None)}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def groupnorm_heads(p, x, n_heads, eps=1e-5):
    """Per-head group norm for RWKV wkv output. x: [..., H*hd]."""
    dt = x.dtype
    shp = x.shape
    x = x.reshape(shp[:-1] + (n_heads, shp[-1] // n_heads)).astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = ((x - mu) * jax.lax.rsqrt(var + eps)).reshape(shp)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings (llama-style split-half)


def rope_tables(positions, dim, theta):
    """positions [..., S] -> (sin, cos) [..., S, dim/2] in f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x [B,S,H,D]; sin/cos [B,S,D/2] (or broadcastable)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    sin = sin[..., None, :]  # add head axis
    cos = cos[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding


def init_embed(key, vocab, d, tie, scale_by_dim=False):
    vpad = ((vocab + 127) // 128) * 128   # shardable vocab (pad masked)
    p = {"table": jax.random.normal(key, (vpad, d), jnp.float32) * 0.02}
    if not tie:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = jax.random.normal(k2, (d, vpad), jnp.float32) * 0.02
    return p


def embed_specs(tie):
    # vocab over 'model'; embed dim replicated (sharding d over 'data' makes
    # the token gather unpartitionable: batch and d would fight for 'data').
    s = {"table": P("model", None)}
    if not tie:
        s["unembed"] = P(None, "model")
    return s


def embed_tokens(p, tokens, cdt, scale_by_dim=False):
    tab = p["table"].astype(cdt)
    x = jnp.take(tab, tokens, axis=0)
    x = ctx.constrain(x, "batch", None, None)
    if scale_by_dim:
        x = x * jnp.asarray(tab.shape[-1] ** 0.5, cdt)
    return x


def unembed(p, x, cdt, logit_cap=None, vocab=None):
    if "unembed" in p:
        w = p["unembed"].astype(cdt)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    else:
        w = p["table"].astype(cdt)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    logits = ctx.constrain(logits, "batch", None, "model")
    logits = logits.astype(jnp.float32)
    if logit_cap:
        logits = softcap(logits, logit_cap)
    vpad = logits.shape[-1]
    if vocab is not None and vocab != vpad:
        # vocab-padding rows never win: mask to -1e9 (softmax/argmax exact)
        col = jax.lax.iota(jnp.int32, vpad)
        logits = jnp.where(col[None, None, :] < vocab, logits, -1e9)
    return logits


# ---------------------------------------------------------------------------
# MLPs


def init_mlp(key, d, f, gated=True):
    k = jax.random.split(key, 3)
    lim_in, lim_out = d ** -0.5, f ** -0.5
    if gated:
        return {
            "w_gate": jax.random.uniform(k[0], (d, f), jnp.float32, -lim_in, lim_in),
            "w_up": jax.random.uniform(k[1], (d, f), jnp.float32, -lim_in, lim_in),
            "w_down": jax.random.uniform(k[2], (f, d), jnp.float32, -lim_out, lim_out),
        }
    return {
        "w_in": jax.random.uniform(k[0], (d, f), jnp.float32, -lim_in, lim_in),
        "w_out": jax.random.uniform(k[1], (f, d), jnp.float32, -lim_out, lim_out),
    }


def mlp_specs(gated=True):
    if gated:
        return {"w_gate": P("data", "model"), "w_up": P("data", "model"),
                "w_down": P("model", "data")}
    return {"w_in": P("data", "model"), "w_out": P("model", "data")}


def mlp(p, x, act="silu"):
    cdt = x.dtype
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cdt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cdt))
        h = ctx.constrain(act_fn(act)(g) * u, "batch", None, "model")
        out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cdt))
    else:
        h = act_fn(act)(jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(cdt)))
        h = ctx.constrain(h, "batch", None, "model")
        out = jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(cdt))
    return ctx.constrain(out, "batch", None, None)
