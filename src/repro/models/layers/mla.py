"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill use the decompressed (non-absorbed) formulation; decode uses the
ABSORBED formulation so the per-token state is only the (kv_lora + rope)-wide
latent, which is the whole point of MLA: the cache is
[B, S, kv_lora + qk_rope] regardless of the 128 heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.attention import _mask, NEG_INF
from repro.models.layers.basic import apply_rope, rmsnorm, rope_tables
from repro.sharding import ctx


def init_mla(key, cfg):
    m, d, h = cfg.mla, cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    k = jax.random.split(key, 7)
    lim = d ** -0.5
    u = lambda kk, shape, l: jax.random.uniform(kk, shape, jnp.float32, -l, l)
    return {
        "w_dq": u(k[0], (d, m.q_lora_rank), lim),
        "q_norm": {"scale": jnp.zeros((m.q_lora_rank,), jnp.float32)},
        "w_uq": u(k[1], (m.q_lora_rank, h, qk_dim), m.q_lora_rank ** -0.5),
        "w_dkv": u(k[2], (d, m.kv_lora_rank + m.qk_rope_dim), lim),
        "kv_norm": {"scale": jnp.zeros((m.kv_lora_rank,), jnp.float32)},
        "w_uk": u(k[3], (m.kv_lora_rank, h, m.qk_nope_dim), m.kv_lora_rank ** -0.5),
        "w_uv": u(k[4], (m.kv_lora_rank, h, m.v_head_dim), m.kv_lora_rank ** -0.5),
        "wo": u(k[5], (h, m.v_head_dim, d), (h * m.v_head_dim) ** -0.5),
    }


def mla_specs(cfg):
    return {
        "w_dq": P("data", None),
        "q_norm": {"scale": P(None)},
        "w_uq": P(None, "model", None),
        "w_dkv": P("data", None),
        "kv_norm": {"scale": P(None)},
        "w_uk": P(None, "model", None),
        "w_uv": P(None, "model", None),
        "wo": P("model", None, "data"),
    }


def _latents(p, x, cfg, positions):
    """x -> (q_nope [B,S,H,n], q_rope [B,S,H,r], c_kv [B,S,l], k_rope [B,S,r])."""
    m = cfg.mla
    cdt = x.dtype
    q_low = rmsnorm(p["q_norm"], jnp.einsum("bsd,dl->bsl", x, p["w_dq"].astype(cdt)),
                    cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", q_low, p["w_uq"].astype(cdt))
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    dkv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"].astype(cdt))
    c_kv = rmsnorm(p["kv_norm"], dkv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:]
    sin, cos = rope_tables(positions, m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p, x, *, cfg, positions, cache=None, write_pos=None,
                  chunk=None):
    """Returns (out [B,S,D], new_cache {'ckv','krope'})."""
    m = cfg.mla
    cdt = x.dtype
    B, Sq, _ = x.shape
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    q_nope, q_rope, ckv_new, krope_new = _latents(p, x, cfg, positions)

    if cache is None:  # ------------------------- train / prefill (decompressed)
        k_nope = ctx.constrain(
            jnp.einsum("bsl,lhn->bshn", ckv_new, p["w_uk"].astype(cdt)),
            "batch", None, "model", None)
        v = ctx.constrain(
            jnp.einsum("bsl,lhv->bshv", ckv_new, p["w_uv"].astype(cdt)),
            "batch", None, "model", None)
        q_nope = ctx.constrain(q_nope, "batch", None, "model", None)
        q_rope = ctx.constrain(q_rope, "batch", None, "model", None)
        chunk = chunk or cfg.attn_chunk
        n = max(Sq // chunk, 1) if Sq % (chunk or 1) == 0 else 1

        def chunk_body(qnc, qrc, pc, kn, kr, vv):
            s = (jnp.einsum("bqhn,bkhn->bhqk", qnc, kn)
                 + jnp.einsum("bqhr,bkr->bhqk", qrc, kr)
                 ).astype(jnp.float32) * scale
            msk = _mask(pc, positions, causal=True, window=None, n_sink=0)
            s = jnp.where(msk[:, None, :, :], s, NEG_INF)
            pr = jax.nn.softmax(s, axis=-1).astype(cdt)
            return jnp.einsum("bhqk,bkhv->bqhv", pr, vv)

        chunk_fn = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
        qn = jnp.moveaxis(q_nope.reshape(B, n, Sq // n, *q_nope.shape[2:]), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(B, n, Sq // n, *q_rope.shape[2:]), 1, 0)
        ps = jnp.moveaxis(positions.reshape(B, n, Sq // n), 1, 0)

        def body(_, inp):
            qnc, qrc, pc = inp
            return (), chunk_fn(qnc, qrc, pc, k_nope, krope_new, v)

        _, outs = jax.lax.scan(body, (), (qn, qr, ps))
        ctx_out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, cfg.n_heads,
                                                   m.v_head_dim)
        new_cache = {"ckv": ckv_new, "krope": krope_new}
    else:  # ------------------------------------------------ decode (absorbed)
        def upd(c, nw, wp):
            return jax.lax.dynamic_update_slice(c, nw.astype(c.dtype), (wp, 0))
        ckv = ctx.constrain(jax.vmap(upd)(cache["ckv"], ckv_new, write_pos),
                            "batch", "seq", None)
        krope = ctx.constrain(
            jax.vmap(upd)(cache["krope"], krope_new, write_pos),
            "batch", "seq", None)
        new_cache = {"ckv": ckv, "krope": krope}
        ckv_c, krope_c = ckv.astype(cdt), krope.astype(cdt)
        Smax = ckv.shape[1]
        slot = jnp.arange(Smax, dtype=jnp.int32)[None, :]
        kpos = jnp.where(slot <= (write_pos[:, None] + Sq - 1), slot, -1)
        # absorb W_UK into the query: q_eff [B,S,H,l]
        q_eff = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"].astype(cdt))
        s = (jnp.einsum("bshl,bkl->bshk", q_eff, ckv_c)
             + jnp.einsum("bshr,bkr->bshk", q_rope, krope_c)
             ).astype(jnp.float32) * scale
        msk = _mask(positions, kpos, causal=True, window=None, n_sink=0)
        s = jnp.where(msk[:, :, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1).astype(cdt)
        lat = jnp.einsum("bshk,bkl->bshl", pr, ckv_c)      # [B,S,H,l]
        ctx_out = jnp.einsum("bshl,lhv->bshv", lat, p["w_uv"].astype(cdt))

    out = jnp.einsum("bshv,hvd->bsd", ctx_out, p["wo"].astype(cdt))
    return ctx.constrain(out, "batch", None, None), new_cache


def init_mla_cache(cfg, batch, max_len, n_layers, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((n_layers, batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((n_layers, batch, max_len, m.qk_rope_dim), dtype),
    }


def mla_cache_specs(batch_axes=("data",), seq_axis="model"):
    return {"ckv": P(None, batch_axes, seq_axis, None),
            "krope": P(None, batch_axes, seq_axis, None)}
