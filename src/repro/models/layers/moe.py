"""Token-choice top-k Mixture-of-Experts with capacity-based dispatch.

Sharding strategy (EP over the 'model' mesh axis):
  * routing, dispatch-index construction and combine are LOCAL per batch row
    (vmapped scatters/gathers on [E, C]-shaped per-row tensors), so the SPMD
    partitioner never sees a cross-shard scatter;
  * the three expert einsums contract over stacked expert weights
    [E, d, f] sharded on E -> each model shard computes only its local
    experts; the dispatched activations are batch-sharded and E-replicated
    (bounded by the microbatch size, which gradient accumulation keeps small);
  * the expert outputs are re-replicated over E (one all-gather over the
    'model' axis per layer) before the local combine-gather - this is the EP
    collective, analogous to the second all-to-all of a classic MoE.

Capacity C = ceil(S * top_k / E * capacity_factor); overflow tokens are
dropped (their combine weight is 0), underflow slots read a zero row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.basic import act_fn
from repro.sharding import ctx


def capacity(seq, n_experts, top_k, factor):
    c = int(seq * top_k / n_experts * factor + 0.5)
    return max(8, ((c + 7) // 8) * 8)   # pad to a lane-friendly multiple


def init_moe(key, cfg):
    d, m = cfg.d_model, cfg.moe
    f = m.d_ff_expert
    k = jax.random.split(key, 5)
    lim_d, lim_f = d ** -0.5, f ** -0.5
    u = lambda kk, shape, l: jax.random.uniform(kk, shape, jnp.float32, -l, l)
    p = {
        "router": u(k[0], (d, m.n_experts), lim_d),
        "w_gate": u(k[1], (m.n_experts, d, f), lim_d),
        "w_up": u(k[2], (m.n_experts, d, f), lim_d),
        "w_down": u(k[3], (m.n_experts, f, d), lim_f),
    }
    if m.n_shared:
        from repro.models.layers.basic import init_mlp
        p["shared"] = init_mlp(k[4], d, f * m.n_shared, gated=True)
    return p


def moe_specs(cfg):
    s = {
        "router": P("data", None),
        "w_gate": P("model", "data", None),
        "w_up": P("model", "data", None),
        "w_down": P("model", None, "data"),
    }
    if cfg.moe.n_shared:
        from repro.models.layers.basic import mlp_specs
        s["shared"] = mlp_specs(gated=True)
    return s


def _route(logits, top_k, cap):
    """logits [S,E] f32 -> (gates [S,k], eid [S,k], slot_pos [S,k], keep [S,k]).

    slot_pos is each (token, k)-slot's position within its expert's capacity
    buffer, assigned in token order (earlier tokens win on overflow).
    """
    S, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eid = jax.lax.top_k(probs, top_k)                    # [S,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(eid.reshape(S * top_k), E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - 1                            # [S*k,E]
    slot_pos = jnp.take_along_axis(
        pos, eid.reshape(S * top_k)[:, None], axis=1)[:, 0]
    keep = slot_pos < cap
    return gates, eid, slot_pos.reshape(S, top_k), keep.reshape(S, top_k)


def moe_ffn(p, x, cfg, batch_axes=("data",)):
    """x [B,S,D] -> (out [B,S,D], aux_losses dict)."""
    m = cfg.moe
    cdt = x.dtype
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    C = capacity(S, E, K, m.capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(cdt)
                        ).astype(jnp.float32)

    def route_row(lg):  # [S,E]
        gates, eid, slot_pos, keep = _route(lg, K, C)
        tok = jnp.arange(S, dtype=jnp.int32)[:, None] * jnp.ones((1, K), jnp.int32)
        # dispatch index [E,C]: source token for each capacity slot (S = pad)
        e_flat = jnp.where(keep, eid, E).reshape(-1)            # drop -> OOB
        disp = jnp.full((E, C), S, jnp.int32).at[
            e_flat, slot_pos.reshape(-1)].set(tok.reshape(-1), mode="drop")
        # combine index [S,K] into flattened [E*C] (+pad row at E*C)
        comb = jnp.where(keep, eid * C + slot_pos, E * C)
        return disp, comb, gates

    disp_idx, comb_idx, gates = jax.vmap(route_row)(logits)

    # ---- dispatch (local gather; zero row padded at index S) ----------------
    xp = jnp.concatenate([x, jnp.zeros((B, 1, D), cdt)], axis=1)
    xe = jnp.take_along_axis(xp[:, :, None, :],
                             disp_idx.reshape(B, E * C)[:, :, None, None],
                             axis=1).reshape(B, E, C, D)
    xe = ctx.constrain(xe, "batch", None, None, None)

    # ---- expert FFN (einsums sharded on E over 'model') ---------------------
    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(cdt))
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(cdt))
    g = ctx.constrain(g, "batch", "model", None, None)
    h = act_fn(cfg.act)(g) * u
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cdt))
    # EP collective: re-replicate expert outputs across the model axis
    y = ctx.constrain(y, "batch", None, None, None)

    # ---- combine (local gather + weighted sum over k slots) -----------------
    yf = jnp.concatenate([y.reshape(B, E * C, D), jnp.zeros((B, 1, D), cdt)],
                         axis=1)
    ys = jnp.take_along_axis(yf[:, :, None, :],
                             comb_idx.reshape(B, S * K)[:, :, None, None],
                             axis=1).reshape(B, S, K, D)
    out = jnp.einsum("bskd,bsk->bsd", ys, gates.astype(cdt))
    out = ctx.constrain(out, "batch", None, None)

    if m.n_shared:
        from repro.models.layers.basic import mlp
        out = out + mlp(p["shared"], x, cfg.act)

    # ---- aux losses ----------------------------------------------------------
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(jnp.argmax(probs, -1), E)).reshape(-1, E), axis=0)
    frac_probs = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = {
        "moe_load_balance": E * jnp.sum(frac_tokens * frac_probs),
        "moe_router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return out, aux


# ---------------------------------------------------------------------------
# EP-sharded implementation (beyond-paper optimization, cfg.moe_impl="shard")
#
# Activations are replicated over the 'model' (EP) axis, so no expert-output
# all-gather is needed at all: each model shard dispatches the SAME routing
# decisions but keeps only the slots of its local experts, runs its local
# expert FFNs (FSDP weight shards explicitly cast to bf16 BEFORE the manual
# all-gather - half the wire of the auto-partitioned f32 gather), combines
# locally, and one bf16 psum of the partial outputs finishes the layer.
# Numerics are IDENTICAL to moe_ffn (same capacity competition per shard).


def moe_ffn_sharded(p, x, cfg):
    """x [B,S,D] (batch-sharded, model-replicated) -> (out, aux)."""
    from repro.sharding.ctx import _mapping
    mesh = jax.sharding.get_abstract_mesh()
    names = set(getattr(mesh, "axis_names", ()) or ())
    mapping = _mapping()
    model_ax = mapping["model"]
    if model_ax not in names or mesh.shape[model_ax] <= 1 \
            or cfg.moe.n_experts % mesh.shape[model_ax] != 0:
        return moe_ffn(p, x, cfg)
    batch_ax = tuple(a for a in mapping["batch"] if a in names)

    m = cfg.moe
    cdt = x.dtype
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    C = capacity(S, E, K, m.capacity_factor)
    n_sh = mesh.shape[model_ax]
    E_loc = E // n_sh

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(cdt)
                        ).astype(jnp.float32)

    bspec = P(batch_ax if batch_ax else None, None, None)
    has_data = "data" in names and mesh.shape["data"] > 1
    wspec_in = P(model_ax, "data" if has_data else None, None)
    wspec_out = P(model_ax, None, "data" if has_data else None)

    def body(xb, lg, wg, wu, wd):
        shard = jax.lax.axis_index(model_ax)
        # FSDP gather of the local experts' weights, explicitly in bf16.
        # optimization_barrier pins the f32->bf16 convert BEFORE the gather:
        # without it XLA:CPU folds the convert into its f32-legalized dots
        # and the gather silently goes back to full f32 width.
        def cast(w):
            return jax.lax.optimization_barrier(w.astype(cdt))
        if has_data:
            wg = jax.lax.all_gather(cast(wg), "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(cast(wu), "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(cast(wd), "data", axis=2, tiled=True)
        else:
            wg, wu, wd = cast(wg), cast(wu), cast(wd)

        def route_row(lgr):                              # [S,E]
            gates, eid, slot_pos, keep = _route(lgr, K, C)
            local = (eid >= shard * E_loc) & (eid < (shard + 1) * E_loc)
            keep = keep & local
            e_loc = eid - shard * E_loc
            tok = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[:, None], (S, K))
            e_flat = jnp.where(keep, e_loc, E_loc).reshape(-1)
            disp = jnp.full((E_loc, C), S, jnp.int32).at[
                e_flat, slot_pos.reshape(-1)].set(tok.reshape(-1),
                                                  mode="drop")
            comb = jnp.where(keep, e_loc * C + slot_pos, E_loc * C)
            return disp, comb, gates

        disp_idx, comb_idx, gates = jax.vmap(route_row)(lg)
        Bl = xb.shape[0]
        xp = jnp.concatenate([xb, jnp.zeros((Bl, 1, D), cdt)], axis=1)
        xe = jnp.take_along_axis(
            xp[:, :, None, :],
            disp_idx.reshape(Bl, E_loc * C)[:, :, None, None],
            axis=1).reshape(Bl, E_loc, C, D)
        g = jnp.einsum("becd,edf->becf", xe, wg)
        u = jnp.einsum("becd,edf->becf", xe, wu)
        y = jnp.einsum("becf,efd->becd", act_fn(cfg.act)(g) * u, wd)
        yf = jnp.concatenate([y.reshape(Bl, E_loc * C, D),
                              jnp.zeros((Bl, 1, D), cdt)], axis=1)
        ys = jnp.take_along_axis(
            yf[:, :, None, :],
            comb_idx.reshape(Bl, S * K)[:, :, None, None],
            axis=1).reshape(Bl, S, K, D)
        partial = jnp.einsum("bskd,bsk->bsd", ys, gates.astype(cdt))
        return jax.lax.psum(partial, model_ax)           # one bf16 psum

    out = jax.shard_map(
        body,
        in_specs=(bspec, bspec, wspec_in, wspec_in, wspec_out),
        out_specs=bspec,
    )(x, logits, p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared:
        from repro.models.layers.basic import mlp
        out = out + mlp(p["shared"], x, cfg.act)
    return out, _aux_losses(logits)


def _aux_losses(logits):
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(jnp.argmax(probs, -1), E)).reshape(-1, E), axis=0)
    frac_probs = jnp.mean(probs.reshape(-1, E), axis=0)
    return {
        "moe_load_balance": E * jnp.sum(frac_tokens * frac_probs),
        "moe_router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
