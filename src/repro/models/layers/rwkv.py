"""RWKV6 (Finch) time-mix and channel-mix with data-dependent decay.

Per head (dim N): state S in R^{N x N};
  y_t = r_t . (S_t + diag(u) k_t v_t^T)          (read)
  S_{t+1} = diag(w_t) S_t + k_t v_t^T            (update; w_t data-dependent)
Token shift uses the v6 "ddlerp" (LoRA-modulated lerp with x_{t-1}).

The sequence recurrence is a lax.scan (jnp reference / dry-run path);
`repro.kernels.wkv6` is the chunked Pallas TPU kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.basic import groupnorm_heads

_TM_TARGETS = ("r", "k", "v", "w", "g")


def init_rwkv_tmix(key, cfg):
    d, r = cfg.d_model, cfg.rwkv
    k = jax.random.split(key, 12)
    lim = d ** -0.5
    u = lambda kk, shape, l: jax.random.uniform(kk, shape, jnp.float32, -l, l)
    H = d // r.head_dim
    return {
        "mu": jnp.full((len(_TM_TARGETS), d), 0.5, jnp.float32),
        "mix_a": u(k[0], (d, len(_TM_TARGETS) * r.mix_lora), lim),
        "mix_b": u(k[1], (len(_TM_TARGETS), r.mix_lora, d), r.mix_lora ** -0.5),
        "wr": u(k[2], (d, d), lim),
        "wk": u(k[3], (d, d), lim),
        "wv": u(k[4], (d, d), lim),
        "wg": u(k[5], (d, d), lim),
        "wo": u(k[6], (d, d), lim),
        "w_base": jnp.full((d,), -6.0, jnp.float32),
        "w_lora_a": u(k[7], (d, r.decay_lora), lim),
        "w_lora_b": u(k[8], (r.decay_lora, d), r.decay_lora ** -0.5),
        "u_bonus": u(k[9], (H, r.head_dim), 1.0),
        "gn": {"scale": jnp.ones((d,), jnp.float32),
               "bias": jnp.zeros((d,), jnp.float32)},
    }


def rwkv_tmix_specs(cfg):
    return {
        "mu": P(None, None), "mix_a": P("data", None), "mix_b": P(None, None, None),
        "wr": P("data", "model"), "wk": P("data", "model"),
        "wv": P("data", "model"), "wg": P("data", "model"),
        "wo": P("model", "data"),
        "w_base": P(None), "w_lora_a": P("data", None), "w_lora_b": P(None, None),
        "u_bonus": P("model", None),
        "gn": {"scale": P(None), "bias": P(None)},
    }


def _ddlerp(p, x, x_prev):
    """v6 data-dependent token shift -> dict of mixed inputs per target."""
    cdt = x.dtype
    dx = x_prev - x
    # low-rank modulation trunk (v6 "ddlerp": shared half-mix input)
    a = jnp.tanh(jnp.einsum("bsd,dz->bsz", x + dx * 0.5,
                            p["mix_a"].astype(cdt)))
    a = a.reshape(a.shape[:-1] + (len(_TM_TARGETS), -1))
    mods = jnp.einsum("bstr,trd->tbsd", a, p["mix_b"].astype(cdt))
    out = {}
    for i, t in enumerate(_TM_TARGETS):
        mu = p["mu"][i].astype(cdt) + mods[i]
        out[t] = x + dx * mu
    return out


def _wkv_scan_ref(r, k, v, w, u, s0, chunk=256):
    """r,k,v [B,S,H,N]; w [B,S,H,N] decay in (0,1); u [H,N]; s0 [B,H,N,N] f32.
    Returns y [B,S,H,N], sT.  Two-level sqrt-remat scan (see mamba)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                         # [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]       # [B,H,N,N]
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, y

    def run(s, xs):
        return jax.lax.scan(step, s, xs)

    S = r.shape[1]
    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32) for t in (r, k, v, w))
    if S <= chunk or S % chunk != 0:
        sT, ys = run(s0, xs)
    else:
        n = S // chunk
        xs_c = jax.tree.map(lambda t: t.reshape((n, chunk) + t.shape[1:]), xs)
        run_ck = jax.checkpoint(
            run, policy=jax.checkpoint_policies.nothing_saveable)
        sT, ys = jax.lax.scan(run_ck, s0, xs_c)
        ys = ys.reshape((S,) + ys.shape[2:])
    return jnp.moveaxis(ys, 0, 1), sT


def rwkv_time_mix(p, x, cfg, state=None, need_state=True):
    """x [B,S,D] -> (out [B,S,D], new_state {'shift':[B,D], 'wkv':[B,H,N,N]})."""
    r_cfg = cfg.rwkv
    cdt = x.dtype
    B, S, D = x.shape
    H, N = D // r_cfg.head_dim, r_cfg.head_dim
    x_prev = (jnp.concatenate([state["shift"][:, None, :].astype(cdt),
                               x[:, :-1, :]], axis=1)
              if state is not None else
              jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :])
    mixed = _ddlerp(p, x, x_prev)
    proj = lambda name, t: jnp.einsum("bsd,dz->bsz", mixed[t],
                                      p[name].astype(cdt))
    r = proj("wr", "r").reshape(B, S, H, N)
    k = proj("wk", "k").reshape(B, S, H, N)
    v = proj("wv", "v").reshape(B, S, H, N)
    g = jax.nn.silu(proj("wg", "g"))
    w_log = (p["w_base"].astype(cdt)
             + jnp.einsum("bsd,dz,ze->bse", mixed["w"],
                          p["w_lora_a"].astype(cdt), p["w_lora_b"].astype(cdt)))
    w = jnp.exp(-jnp.exp(w_log.astype(jnp.float32))).reshape(B, S, H, N)
    s0 = (state["wkv"] if state is not None
          else jnp.zeros((B, H, N, N), jnp.float32))
    if cfg.use_pallas and state is None and not need_state:
        # TPU hot path: VMEM-resident WKV state (kernels/wkv6).  Training
        # never reads the final state, so the kernel (which emits only y)
        # applies; prefill needs s_T and stays on the reference scan.
        # tuned=True picks up the autotuned heads-per-cell factorization.
        from repro.kernels import ops as kops
        y = kops.wkv6(r, k, v, w, p["u_bonus"].astype(r.dtype), tuned=True)
        sT = s0
    else:
        y, sT = _wkv_scan_ref(r, k, v, w, p["u_bonus"].astype(jnp.float32),
                              s0)
    y = groupnorm_heads(p["gn"], y.astype(cdt).reshape(B, S, D), H) * g
    out = jnp.einsum("bsd,dz->bsz", y, p["wo"].astype(cdt))
    new_state = {"shift": x[:, -1, :].astype(jnp.bfloat16), "wkv": sT}
    return out, new_state


def init_rwkv_cmix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    k = jax.random.split(key, 3)
    lim = d ** -0.5
    u = lambda kk, shape, l: jax.random.uniform(kk, shape, jnp.float32, -l, l)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": u(k[0], (d, f), lim),
        "wv": u(k[1], (f, d), f ** -0.5),
        "wr": u(k[2], (d, d), lim),
    }


def rwkv_cmix_specs(cfg):
    return {"mu_k": P(None), "mu_r": P(None),
            "wk": P("data", "model"), "wv": P("model", "data"),
            "wr": P("data", "model")}


def rwkv_channel_mix(p, x, cfg, state=None):
    cdt = x.dtype
    x_prev = (jnp.concatenate([state[:, None, :].astype(cdt), x[:, :-1, :]],
                              axis=1)
              if state is not None else
              jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :])
    dx = x_prev - x
    xk = x + dx * p["mu_k"].astype(cdt)
    xr = x + dx * p["mu_r"].astype(cdt)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk,
                                          p["wk"].astype(cdt))))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(cdt))
    out = jax.nn.sigmoid(jnp.einsum("bsd,dz->bsz", xr, p["wr"].astype(cdt))) * kv
    return out, x[:, -1, :].astype(jnp.bfloat16)


def init_rwkv_state(cfg, batch, n_layers):
    d = cfg.d_model
    H, N = d // cfg.rwkv.head_dim, cfg.rwkv.head_dim
    return {
        "tm_shift": jnp.zeros((n_layers, batch, d), jnp.bfloat16),
        "cm_shift": jnp.zeros((n_layers, batch, d), jnp.bfloat16),
        "wkv": jnp.zeros((n_layers, batch, H, N, N), jnp.float32),
    }


def rwkv_state_specs(batch_axes=("data",)):
    return {"tm_shift": P(None, batch_axes, "model"),
            "cm_shift": P(None, batch_axes, "model"),
            "wkv": P(None, batch_axes, "model", None, None)}
