"""Decoder-only LM trunk: scan-over-layers, uniform across families.

One layer body serves dense / moe / hybrid(attn+mamba) / ssm(rwkv6) / vlm
configs; per-layer variation (local vs global attention) is DATA (a scanned
bool), not structure, so the stacked-parameter scan stays uniform and the HLO
(and compile time for the 512-device dry-run) stays small.  DeepSeek-V2's
leading dense-FFN layer(s) sit outside the scanned stack.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import attention as attn_mod
from repro.models.layers import basic, mamba as mamba_mod, mla as mla_mod
from repro.models.layers import moe as moe_mod, rwkv as rwkv_mod
from repro.sharding import ctx


# ---------------------------------------------------------------------------
# per-layer init/specs


def _init_layer(key, cfg, moe_layer: bool):
    ks = jax.random.split(key, 8)
    fam = cfg.family
    if fam == "ssm":  # rwkv6
        return {
            "ln1": basic.init_layernorm(cfg.d_model),
            "tmix": rwkv_mod.init_rwkv_tmix(ks[0], cfg),
            "ln2": basic.init_layernorm(cfg.d_model),
            "cmix": rwkv_mod.init_rwkv_cmix(ks[1], cfg),
        }
    p = {"ln1": basic.init_rmsnorm(cfg.d_model),
         "ln2": basic.init_rmsnorm(cfg.d_model)}
    if cfg.attn_impl == "mla":
        p["attn"] = mla_mod.init_mla(ks[0], cfg)
    else:
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
    if fam == "hybrid":
        p["mamba"] = mamba_mod.init_mamba(ks[1], cfg)
        p["norm_attn"] = basic.init_rmsnorm(cfg.n_heads * cfg.head_dim)
        p["norm_ssm"] = basic.init_rmsnorm(cfg.d_model)
    if moe_layer:
        p["ffn"] = moe_mod.init_moe(ks[2], cfg)
    else:
        p["ffn"] = basic.init_mlp(ks[2], cfg.d_model, cfg.d_ff, gated=True)
    if cfg.post_norms:
        p["post_ln1"] = basic.init_rmsnorm(cfg.d_model)
        p["post_ln2"] = basic.init_rmsnorm(cfg.d_model)
    return p


def _layer_specs(cfg, moe_layer: bool):
    fam = cfg.family
    if fam == "ssm":
        return {
            "ln1": basic.layernorm_specs(),
            "tmix": rwkv_mod.rwkv_tmix_specs(cfg),
            "ln2": basic.layernorm_specs(),
            "cmix": rwkv_mod.rwkv_cmix_specs(cfg),
        }
    s = {"ln1": basic.rmsnorm_specs(), "ln2": basic.rmsnorm_specs()}
    if cfg.attn_impl == "mla":
        s["attn"] = mla_mod.mla_specs(cfg)
    else:
        s["attn"] = attn_mod.attention_specs(cfg)
    if fam == "hybrid":
        s["mamba"] = mamba_mod.mamba_specs(cfg)
        s["norm_attn"] = basic.rmsnorm_specs()
        s["norm_ssm"] = basic.rmsnorm_specs()
    if moe_layer:
        s["ffn"] = moe_mod.moe_specs(cfg)
    else:
        s["ffn"] = basic.mlp_specs(gated=True)
    if cfg.post_norms:
        s["post_ln1"] = basic.rmsnorm_specs()
        s["post_ln2"] = basic.rmsnorm_specs()
    return s


def _n_pre_layers(cfg) -> int:
    return cfg.moe.first_k_dense if cfg.moe else 0


def _norm(cfg):
    return basic.layernorm if cfg.family == "ssm" else basic.rmsnorm


# ---------------------------------------------------------------------------
# whole-model init/specs


def init_lm(key, cfg):
    ks = jax.random.split(key, cfg.n_layers + 4)
    n_pre = _n_pre_layers(cfg)
    p = {
        "embed": basic.init_embed(ks[0], cfg.vocab_size, cfg.d_model,
                                  cfg.tie_embeddings),
        "ln_f": (basic.init_layernorm(cfg.d_model) if cfg.family == "ssm"
                 else basic.init_rmsnorm(cfg.d_model)),
    }
    if cfg.family == "ssm":
        p["ln0"] = basic.init_layernorm(cfg.d_model)   # rwkv embeds norm
    if cfg.meta_tokens:
        p["meta"] = jax.random.normal(ks[1], (cfg.meta_tokens, cfg.d_model),
                                      jnp.float32) * 0.02
    if cfg.frontend == "vision":
        p["mm_proj"] = jax.random.normal(
            ks[2], (cfg.d_model, cfg.d_model), jnp.float32) * cfg.d_model ** -0.5
    moe_layer = cfg.moe is not None
    p["pre_layers"] = [
        _init_layer(ks[3 + i], cfg, moe_layer=False) for i in range(n_pre)]
    stack_keys = jnp.stack(
        [ks[3 + n_pre + i] for i in range(cfg.n_layers - n_pre)])
    p["layers"] = jax.vmap(
        functools.partial(_init_layer, cfg=cfg, moe_layer=moe_layer)
    )(stack_keys)
    return p


def lm_specs(cfg):
    n_pre = _n_pre_layers(cfg)
    s = {
        "embed": basic.embed_specs(cfg.tie_embeddings),
        "ln_f": (basic.layernorm_specs() if cfg.family == "ssm"
                 else basic.rmsnorm_specs()),
    }
    if cfg.family == "ssm":
        s["ln0"] = basic.layernorm_specs()
    if cfg.meta_tokens:
        s["meta"] = P(None, None)
    if cfg.frontend == "vision":
        s["mm_proj"] = P("data", "model")
    s["pre_layers"] = [_layer_specs(cfg, moe_layer=False) for _ in range(n_pre)]
    # stacked layers: same specs with a leading (unsharded) layer axis
    per = _layer_specs(cfg, moe_layer=cfg.moe is not None)
    s["layers"] = jax.tree.map(lambda sp: P(None, *sp), per,
                               is_leaf=lambda x: isinstance(x, P))
    return s


# ---------------------------------------------------------------------------
# decode cache


def init_decode_cache(cfg, batch, max_len):
    L = cfg.n_layers
    c = {}
    if cfg.attn_impl == "mla":
        c.update(mla_mod.init_mla_cache(cfg, batch, max_len, L))
    elif cfg.attn_impl == "gqa":
        c.update(attn_mod.init_kv_cache(cfg, batch, max_len, L))
    if cfg.family == "hybrid":
        c.update(mamba_mod.init_mamba_state(cfg, batch, L))
    if cfg.family == "ssm":
        c.update(rwkv_mod.init_rwkv_state(cfg, batch, L))
    return c


def init_paged_decode_cache(cfg, n_blocks, block_size, mesh=None):
    """The paged decode cache: one shared pool of KV blocks per layer.

    Only plain GQA-attention stacks page cleanly — recurrent families
    (ssm/rwkv/hybrid) carry per-slot state that is not positional, and
    meta tokens / modality prefixes are prepended by prefill-mode calls
    the chunked path never makes — so everything else raises loudly.

    ``mesh``: lay the pool out sharded at birth (KV heads over the
    mesh's ``'model'`` axis — ``attention.paged_pool_spec``) for a
    replica that decodes over multiple chips."""
    if (cfg.attn_impl != "gqa" or cfg.family in ("ssm", "hybrid")
            or cfg.ssm is not None or cfg.rwkv is not None
            or cfg.meta_tokens or cfg.frontend is not None):
        raise NotImplementedError(
            f"{cfg.name}: paged KV cache needs a plain GQA attention "
            "stack (no recurrent state, meta tokens, or prefix embeds)")
    return attn_mod.init_paged_kv_cache(cfg, n_blocks, block_size,
                                        cfg.n_layers, mesh=mesh)


def decode_cache_specs(cfg, batch_axes=("data",), seq_axis="model"):
    s = {}
    if cfg.attn_impl == "mla":
        s.update(mla_mod.mla_cache_specs(batch_axes, seq_axis))
    elif cfg.attn_impl == "gqa":
        s.update(attn_mod.kv_cache_specs(batch_axes, seq_axis))
    if cfg.family == "hybrid":
        s.update(mamba_mod.mamba_state_specs(batch_axes))
    if cfg.family == "ssm":
        s.update(rwkv_mod.rwkv_state_specs(batch_axes))
    return s


def _split_cache(cache, kind):
    """Split a stacked cache dict into (attn_part, state_part) per kind."""
    attn_keys = {"k", "v", "ckv", "krope"}
    a = {k: v for k, v in cache.items() if k in attn_keys} if cache else None
    st = {k: v for k, v in cache.items() if k not in attn_keys} if cache else None
    return (a or None), (st or None)


# ---------------------------------------------------------------------------
# one decoder layer


def _layer(x, lp, *, cfg, positions, is_global, cache_layer, write_pos, mode,
           block_tables=None):
    """Returns (x, new_cache_layer, aux)."""
    cdt = x.dtype
    x = ctx.constrain(x, "batch", None, None)
    aux = {"moe_load_balance": jnp.zeros((), jnp.float32),
           "moe_router_z": jnp.zeros((), jnp.float32)}
    norm = _norm(cfg)

    if cfg.family == "ssm":
        tm_state = None
        if mode == "decode":
            tm_state = {"shift": cache_layer["tm_shift"],
                        "wkv": cache_layer["wkv"]}
        h, tm_new = rwkv_mod.rwkv_time_mix(
            lp["tmix"], basic.layernorm(lp["ln1"], x), cfg, tm_state,
            need_state=(mode != "train"))
        x = x + h
        cm_state = cache_layer["cm_shift"] if mode == "decode" else None
        h, cm_new = rwkv_mod.rwkv_channel_mix(
            lp["cmix"], basic.layernorm(lp["ln2"], x), cfg, cm_state)
        x = x + h
        new_cache = {"tm_shift": tm_new["shift"], "wkv": tm_new["wkv"],
                     "cm_shift": cm_new}
        return x, new_cache, aux

    h_in = norm(lp["ln1"], x, cfg.norm_eps)
    attn_cache, state_cache = _split_cache(cache_layer, cfg.family)
    use_cache = attn_cache if mode == "decode" else None

    if cfg.attn_impl == "mla":
        a_out, a_cache = mla_mod.mla_attention(
            lp["attn"], h_in, cfg=cfg, positions=positions,
            cache=use_cache, write_pos=write_pos)
    else:
        a_out, a_cache = attn_mod.attention(
            lp["attn"], h_in, cfg=cfg, positions=positions,
            is_global=is_global, cache=use_cache, write_pos=write_pos,
            block_tables=block_tables,
            pre_output=(cfg.family == "hybrid"))

    new_cache = {}
    if cfg.family == "hybrid":
        m_state = state_cache if mode == "decode" else None
        if m_state is not None:
            m_state = {"conv": m_state["conv"], "h": m_state["h"]}
        s_out, s_new = mamba_mod.mamba_mixer(lp["mamba"], h_in, cfg, m_state,
                                             need_state=(mode != "train"))
        # padded dead heads are zero; slice back to the real width so the
        # parallel SSM path (d_inner == n_heads*head_dim) fuses exactly
        real = cfg.n_heads * cfg.head_dim
        a_pre = a_out[..., :real]
        fused = 0.5 * (basic.rmsnorm(lp["norm_attn"], a_pre, cfg.norm_eps)
                       + basic.rmsnorm(lp["norm_ssm"], s_out, cfg.norm_eps))
        wo = lp["attn"]["wo"].astype(cdt)[:cfg.n_heads].reshape(
            real, cfg.d_model)
        a_out = jnp.einsum("bsz,zd->bsd", fused, wo)
        new_cache.update({"conv": s_new["conv"], "h": s_new["h"]})

    if cfg.post_norms:
        a_out = norm(lp["post_ln1"], a_out, cfg.norm_eps)
    if cfg.remat_policy == "save_attn":
        # tag the attention output so the remat policy can keep it: the
        # backward pass then skips recomputing the whole attention block
        from jax.ad_checkpoint import checkpoint_name
        a_out = checkpoint_name(a_out, "attn_out")
    x = x + a_out

    h_in = norm(lp["ln2"], x, cfg.norm_eps)
    if "router" in lp["ffn"]:
        moe_fn = (moe_mod.moe_ffn_sharded if cfg.moe_impl == "shard"
                  else moe_mod.moe_ffn)
        f_out, moe_aux = moe_fn(lp["ffn"], h_in, cfg)
        aux.update(moe_aux)
    else:
        f_out = basic.mlp(lp["ffn"], h_in, cfg.act)
    if cfg.post_norms:
        f_out = norm(lp["post_ln2"], f_out, cfg.norm_eps)
    x = x + f_out

    if mode != "train" and a_cache is not None:
        new_cache.update(a_cache)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# trunk drivers


def _prefill_pad_cache(cache_layer, max_len):
    """Pad per-layer [B,S,...] attention caches up to max_len slots and cast
    to the cache storage dtype (bf16)."""
    def pad(c):
        c = c.astype(jnp.bfloat16)
        S = c.shape[1]
        if S == max_len:
            return c
        pads = [(0, 0)] * c.ndim
        pads[1] = (0, max_len - S)
        return jnp.pad(c, pads)
    return {k: (pad(v) if k in ("k", "v", "ckv", "krope") else v)
            for k, v in cache_layer.items()}


def _last_pos_head(x, mode):
    """The last-position logit head: every non-train call (prefill, decode,
    chunked-prefill-through-decode) unembeds ONLY the final position.

    This slice is the contract the fused serving hot path builds on: with
    the trunk output reduced to ``[B, 1, D]`` before the unembed, a fused
    ``decode_step`` (``models.zoo``) can argmax ``[B, 1, V] -> [B]``
    entirely on device and a serving engine moves 4 bytes per sequence
    across the host boundary instead of a ``[B, V]`` logit row."""
    if mode != "train" and x.shape[1] > 1:
        return x[:, -1:, :]
    return x


def lm_apply(params, cfg, *, tokens, mode, prefix_embeds=None, cache=None,
             write_pos=None, block_tables=None, max_len=None, remat=True):
    """Run the LM trunk.

    tokens        [B,S] int32 (decode: S==1, or a chunked-prefill chunk)
    prefix_embeds [B,P,D] stub modality embeddings (vlm), prepended
    cache         stacked decode cache (mode == 'decode'); with
                  block_tables, the stacked PAGED pool [L,n_blocks,bs,...]
    write_pos     [B] cache slot for the new tokens (decode); may be
                  negative for left-padded chunked-prefill rows (those
                  writes are dropped by the paged scatter)
    block_tables  [B,NB] paged decode: per-row physical block ids
    Returns (logits, aux, new_cache).
    """
    if block_tables is not None and cfg.attn_impl != "gqa":
        raise NotImplementedError(
            f"paged decode needs a GQA KV cache, not {cfg.attn_impl}")
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    x = basic.embed_tokens(params["embed"], tokens, cdt,
                           scale_by_dim=cfg.scale_embeds)
    if cfg.family == "ssm":
        x = basic.layernorm(params["ln0"], x)

    n_prefix = 0
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(cdt)
        if "mm_proj" in params:
            pe = jnp.einsum("bpd,de->bpe", pe, params["mm_proj"].astype(cdt))
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix += pe.shape[1]
    if cfg.meta_tokens and mode != "decode":
        meta = jnp.broadcast_to(params["meta"].astype(cdt),
                                (B, cfg.meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
        n_prefix += cfg.meta_tokens

    St = x.shape[1]
    if mode == "decode":
        # decode calls may carry St > 1 tokens (chunked prefill through
        # the decode path); token t sits at absolute position
        # write_pos + t.  For St == 1 this is the old write_pos[:, None].
        positions = write_pos[:, None] + jnp.arange(St, dtype=jnp.int32)[None]
    else:
        positions = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32)[None], (B, St))

    n_pre = _n_pre_layers(cfg)
    glob = jnp.asarray(cfg.global_layer_mask(), bool)
    aux_tot = {"moe_load_balance": jnp.zeros((), jnp.float32),
               "moe_router_z": jnp.zeros((), jnp.float32)}
    max_len = max_len or St
    pre_caches = []

    # --- leading unstacked layers (deepseek dense layer 0) -------------------
    for i, lp in enumerate(params["pre_layers"]):
        cl = (jax.tree.map(lambda c: c[i], cache) if cache is not None else None)
        x, ncl, aux = _layer(x, lp, cfg=cfg, positions=positions,
                             is_global=glob[i], cache_layer=cl,
                             write_pos=write_pos, mode=mode,
                             block_tables=block_tables)
        aux_tot = jax.tree.map(jnp.add, aux_tot, aux)
        if mode != "train":
            pre_caches.append(_prefill_pad_cache(ncl, max_len)
                              if mode == "prefill" else ncl)

    # --- scanned stack --------------------------------------------------------
    stack = params["layers"]
    glob_stack = glob[n_pre:]
    cache_stack = (jax.tree.map(lambda c: c[n_pre:], cache)
                   if cache is not None else None)

    def body(carry, xs):
        x, aux_acc = carry
        if mode == "decode":
            lp, g, cl = xs
        else:
            lp, g = xs
            cl = None
        x, ncl, aux = _layer(x, lp, cfg=cfg, positions=positions,
                             is_global=g, cache_layer=cl,
                             write_pos=write_pos, mode=mode,
                             block_tables=block_tables)
        aux_acc = jax.tree.map(jnp.add, aux_acc, aux)
        if mode == "train":
            ys = 0.0
        elif mode == "prefill":
            ys = _prefill_pad_cache(ncl, max_len)
        else:
            ys = ncl
        return (x, aux_acc), ys

    if mode == "train" and remat:
        policy = (jax.checkpoint_policies.save_only_these_names("attn_out")
                  if cfg.remat_policy == "save_attn"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)

    xs = ((stack, glob_stack, cache_stack) if mode == "decode"
          else (stack, glob_stack))
    (x, aux_tot), ys = jax.lax.scan(body, (x, aux_tot), xs)

    x = (basic.layernorm if cfg.family == "ssm" else basic.rmsnorm)(
        params["ln_f"], x, cfg.norm_eps)
    if n_prefix and mode != "decode":
        x = x[:, n_prefix:, :]
    x = _last_pos_head(x, mode)
    logits = basic.unembed(params["embed"], x, cdt, cfg.logit_softcap,
                           vocab=cfg.vocab_size)

    new_cache = None
    if mode != "train":
        new_cache = ys
        if pre_caches:
            pre_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *pre_caches)
            new_cache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                pre_stacked, new_cache)
    return logits, aux_tot, new_cache
