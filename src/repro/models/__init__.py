from repro.models.zoo import Model, build_model  # noqa
