"""Encoder-decoder trunk (seamless-m4t backbone).

Encoder: bidirectional self-attention over stub frame embeddings.
Decoder: causal self-attention (cached) + cross-attention onto the encoder
memory (cross K/V computed once at prefill and cached) + FFN.
RoPE is used for self-attention positions (the speech frontend that would
supply convolutional relative positions is a stub per the task spec).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import attention as attn_mod
from repro.models.layers import basic


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": basic.init_rmsnorm(cfg.d_model),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "ln2": basic.init_rmsnorm(cfg.d_model),
        "ffn": basic.init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated=False),
    }


def _enc_layer_specs(cfg):
    return {"ln1": basic.rmsnorm_specs(),
            "attn": attn_mod.attention_specs(cfg),
            "ln2": basic.rmsnorm_specs(),
            "ffn": basic.mlp_specs(gated=False)}


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": basic.init_rmsnorm(cfg.d_model),
        "self_attn": attn_mod.init_attention(ks[0], cfg),
        "ln_x": basic.init_rmsnorm(cfg.d_model),
        "cross_attn": attn_mod.init_attention(ks[1], cfg),
        "ln2": basic.init_rmsnorm(cfg.d_model),
        "ffn": basic.init_mlp(ks[2], cfg.d_model, cfg.d_ff, gated=False),
    }


def _dec_layer_specs(cfg):
    return {"ln1": basic.rmsnorm_specs(),
            "self_attn": attn_mod.attention_specs(cfg),
            "ln_x": basic.rmsnorm_specs(),
            "cross_attn": attn_mod.attention_specs(cfg),
            "ln2": basic.rmsnorm_specs(),
            "ffn": basic.mlp_specs(gated=False)}


def init_encdec(key, cfg):
    e = cfg.encdec
    ks = jax.random.split(key, 4)
    enc_keys = jnp.stack(jax.random.split(ks[0], e.n_enc_layers))
    dec_keys = jnp.stack(jax.random.split(ks[1], e.n_dec_layers))
    return {
        "embed": basic.init_embed(ks[2], cfg.vocab_size, cfg.d_model,
                                  cfg.tie_embeddings),
        "frame_proj": jax.random.normal(
            ks[3], (cfg.d_model, cfg.d_model), jnp.float32) * cfg.d_model ** -0.5,
        "enc_layers": jax.vmap(functools.partial(_init_enc_layer, cfg=cfg))(enc_keys),
        "enc_ln_f": basic.init_rmsnorm(cfg.d_model),
        "dec_layers": jax.vmap(functools.partial(_init_dec_layer, cfg=cfg))(dec_keys),
        "ln_f": basic.init_rmsnorm(cfg.d_model),
    }


def encdec_specs(cfg):
    lift = lambda per: jax.tree.map(lambda sp: P(None, *sp), per,
                                    is_leaf=lambda x: isinstance(x, P))
    return {
        "embed": basic.embed_specs(cfg.tie_embeddings),
        "frame_proj": P("data", "model"),
        "enc_layers": lift(_enc_layer_specs(cfg)),
        "enc_ln_f": basic.rmsnorm_specs(),
        "dec_layers": lift(_dec_layer_specs(cfg)),
        "ln_f": basic.rmsnorm_specs(),
    }


def init_encdec_cache(cfg, batch, max_len, enc_len):
    e, kh, hd = cfg.encdec, cfg.n_kv_heads, cfg.head_dim
    L = e.n_dec_layers
    return {
        "k": jnp.zeros((L, batch, max_len, kh, hd), jnp.bfloat16),
        "v": jnp.zeros((L, batch, max_len, kh, hd), jnp.bfloat16),
        "xk": jnp.zeros((L, batch, enc_len, kh, hd), jnp.bfloat16),
        "xv": jnp.zeros((L, batch, enc_len, kh, hd), jnp.bfloat16),
    }


def encdec_cache_specs(batch_axes=("data",), seq_axis="model"):
    spec = P(None, batch_axes, seq_axis, None, None)
    return {"k": spec, "v": spec, "xk": spec, "xv": spec}


def encode(params, cfg, frames, remat=True):
    """frames [B,S_enc,D] (stub embeddings) -> memory [B,S_enc,D]."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.einsum("bsd,de->bse", frames.astype(cdt),
                   params["frame_proj"].astype(cdt))
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        h, _ = attn_mod.attention(lp["attn"],
                                  basic.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                                  cfg=cfg, positions=pos, is_global=True,
                                  causal=False)
        x = x + h
        x = x + basic.mlp(lp["ffn"], basic.rmsnorm(lp["ln2"], x, cfg.norm_eps),
                          "relu")
        return x, 0.0

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return basic.rmsnorm(params["enc_ln_f"], x, cfg.norm_eps)


def encdec_apply(params, cfg, *, tokens, frames=None, memory=None, mode="train",
                 cache=None, write_pos=None, max_len=None, remat=True):
    """Returns (logits, aux, new_cache).

    train:   frames [B,S_enc,D], tokens [B,S_dec]  -> logits over tokens
    prefill: same; returns cache (self KV padded to max_len, cross KV, memory
             is re-derivable so not stored)
    decode:  tokens [B,1], cache, write_pos [B]
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    aux = {"moe_load_balance": jnp.zeros((), jnp.float32),
           "moe_router_z": jnp.zeros((), jnp.float32)}
    if mode != "decode":
        memory = encode(params, cfg, frames, remat=remat)
    mem_pos = None
    if memory is not None:
        mem_pos = jnp.broadcast_to(
            jnp.arange(memory.shape[1], dtype=jnp.int32)[None],
            (B, memory.shape[1]))

    x = basic.embed_tokens(params["embed"], tokens, cdt)
    if mode == "decode":
        positions = write_pos[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    max_len = max_len or S

    def body(x, xs):
        if mode == "decode":
            lp, cl = xs
        else:
            lp, cl = xs, None
        h, self_kv = attn_mod.attention(
            lp["self_attn"], basic.rmsnorm(lp["ln1"], x, cfg.norm_eps),
            cfg=cfg, positions=positions, is_global=True,
            cache={"k": cl["k"], "v": cl["v"]} if mode == "decode" else None,
            write_pos=write_pos)
        x = x + h
        if mode == "decode":
            xkv = {"k": cl["xk"], "v": cl["xv"]}
            h, _ = attn_mod.attention(
                lp["cross_attn"], basic.rmsnorm(lp["ln_x"], x, cfg.norm_eps),
                cfg=cfg, positions=positions, is_global=True,
                memory=jnp.zeros((B, xkv["k"].shape[1], cfg.d_model), cdt),
                mem_positions=jnp.broadcast_to(
                    jnp.arange(xkv["k"].shape[1], dtype=jnp.int32)[None],
                    (B, xkv["k"].shape[1])),
                cache=xkv)
            cross_kv = xkv
        else:
            h, cross_kv = attn_mod.attention(
                lp["cross_attn"], basic.rmsnorm(lp["ln_x"], x, cfg.norm_eps),
                cfg=cfg, positions=positions, is_global=True,
                memory=memory, mem_positions=mem_pos)
        x = x + h
        x = x + basic.mlp(lp["ffn"], basic.rmsnorm(lp["ln2"], x, cfg.norm_eps),
                          "relu")
        if mode == "train":
            return x, 0.0
        def pad(c):
            if c.shape[1] == max_len:
                return c
            pads = [(0, 0)] * c.ndim
            pads[1] = (0, max_len - c.shape[1])
            return jnp.pad(c, pads)
        if mode == "prefill":
            ys = {"k": pad(self_kv["k"]).astype(jnp.bfloat16),
                  "v": pad(self_kv["v"]).astype(jnp.bfloat16),
                  "xk": cross_kv["k"].astype(jnp.bfloat16),
                  "xv": cross_kv["v"].astype(jnp.bfloat16)}
        else:
            ys = {"k": self_kv["k"], "v": self_kv["v"],
                  "xk": cross_kv["k"], "xv": cross_kv["v"]}
        return x, ys

    xs = ((params["dec_layers"], cache) if mode == "decode"
          else params["dec_layers"])
    if mode == "train" and remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, ys = jax.lax.scan(body, x, xs)
    x = basic.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if mode == "prefill":
        x = x[:, -1:, :]   # only the last position's logits are used
    logits = basic.unembed(params["embed"], x, cdt, cfg.logit_softcap,
                           vocab=cfg.vocab_size)
    new_cache = None if mode == "train" else ys
    return logits, aux, new_cache
