"""Training / serving step builders.

``make_train_step`` builds a jit-able function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with gradient accumulation over micro-batches (a lax.scan so the HLO stays
small), global-norm clipping and the configured optimizer.

The step function is pure; in_shardings/out_shardings are attached by the
launcher (`repro.launch.dryrun` / `repro.launch.train`).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.zoo import Model
from repro.train import optim as optim_mod


def accum_steps_for(cfg, global_batch: int, n_batch_shards: int,
                    n_pods: int = 1) -> int:
    """Gradient-accumulation steps.  cfg.microbatch is per-DATA-SHARD rows at
    one pod; with more pods the per-shard microbatch shrinks so the global
    microbatch (and per-device activation footprint) stays constant."""
    per_shard = max(1, cfg.microbatch // max(n_pods, 1))
    micro_global = per_shard * n_batch_shards
    if global_batch % micro_global == 0 and global_batch >= micro_global:
        return global_batch // micro_global
    return 1


def make_train_step(model: Model, optimizer: optim_mod.Optimizer,
                    accum: int, batch_axes=("data",)):
    cfg = model.cfg

    def split_micro(batch):
        def r(x):
            b = x.shape[0]
            return x.reshape((accum, b // accum) + x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(params, opt_state, batch):
        if cfg.cast_params_once:
            # Hoist the f32->bf16 weight casts above the accumulation loop:
            # the FSDP all-gathers then move bf16 (half the wire bytes) and
            # the casts themselves run once per step, not once per microbatch.
            cdt = jnp.dtype(cfg.compute_dtype)
            def cast(p):
                return p.astype(cdt) if (p.dtype == jnp.float32
                                         and p.ndim >= 2) else p
            def lossf(p, mb):
                return model.loss(jax.tree.map(cast, p), mb)
        else:
            lossf = model.loss
        grad_fn = jax.value_and_grad(lossf, has_aux=True)

        if accum > 1:
            micro = split_micro(batch)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _aux), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), ()

            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(body, (gzero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            (loss, _aux), grads = grad_fn(params, batch)

        updates, opt_state, ometrics = optimizer.update(grads, opt_state,
                                                        params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
        metrics = {"loss": loss, **ometrics}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, aux = model.loss(params, batch)
        return {"loss": loss}
    return eval_step


def make_prefill_step(model: Model, max_len=None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return prefill_step


def make_decode_step(model: Model):
    def serve_step(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)
    return serve_step
