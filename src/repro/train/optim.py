"""Optimizers in pure JAX: AdamW and (factored) Adafactor, with schedules,
global-norm clipping, and PartitionSpec derivation so optimizer state shards
exactly like (or more compactly than) its parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]    # (grads, state, params)
    state_specs: Callable[[Any], Any]           # param_specs -> state specs


def warmup_cosine(peak_lr: float, warmup: int = 200, total: int = 10_000,
                  floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / warmup)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return sched


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


# ---------------------------------------------------------------------------
# AdamW


def make_adamw(lr: Callable, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
               clip_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros(), "v": zeros(),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        gn = jnp.zeros((), jnp.float32)
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        lr_t = lr(c)
        def upd(mm, vv, p):
            mhat = mm / (1 - b1 ** cf)
            vhat = vv / (1 - b2 ** cf)
            step = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr_t * step).astype(p.dtype)
        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": c}, {"grad_norm": gn,
                                                       "lr": lr_t}

    def state_specs(param_specs, param_shapes=None):
        return {"m": param_specs, "v": param_specs, "count": P()}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory-lean for the 236B config)


def make_adafactor(lr: Callable, *, decay=0.8, eps=1e-30, clip_threshold=1.0,
                   min_dim_factored=128, weight_decay=0.0,
                   clip_norm: Optional[float] = 1.0) -> Optimizer:
    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def slot(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(slot, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        gn = jnp.zeros((), jnp.float32)
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** -decay
        lr_t = lr(c)

        def upd(slot, g, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in slot:
                vr = beta * slot["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * slot["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                pre = g * jax.lax.rsqrt(denom + eps)
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                pre = g * jax.lax.rsqrt(v + eps)
                new_slot = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(pre)) + 1e-12)
            pre = pre / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                pre = pre + weight_decay * p.astype(jnp.float32)
            return (-lr_t * pre).astype(p.dtype), new_slot

        flat = jax.tree.map(upd, state["slots"], grads, params,
                            is_leaf=lambda x: isinstance(x, dict)
                            and ("v" in x or "vr" in x))
        updates = jax.tree.map(lambda x: x[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        slots = jax.tree.map(lambda x: x[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"slots": slots, "count": c}, {"grad_norm": gn,
                                                       "lr": lr_t}

    def state_specs(param_specs, param_shapes):
        # vr drops the last param axis, vc the second-to-last; specs follow.
        def slot_spec(spec, shp):
            axes = tuple(spec) if spec is not None else ()
            axes = axes + (None,) * (len(shp.shape) - len(axes))
            if factored(shp):
                return {"vr": P(*axes[:-1]), "vc": P(*(axes[:-2] + axes[-1:]))}
            return {"v": P(*axes)}
        slots = jax.tree.map(slot_spec, param_specs, param_shapes,
                             is_leaf=lambda x: isinstance(x, P))
        return {"slots": slots, "count": P()}

    return Optimizer(init, update, state_specs)


def make_optimizer(name: str, lr_peak: float = 3e-4, **kw) -> Optimizer:
    sched = warmup_cosine(lr_peak)
    if name == "adamw":
        return make_adamw(sched, **kw)
    if name == "adafactor":
        return make_adafactor(sched, **kw)
    raise ValueError(f"unknown optimizer {name}")
