from repro.train import optim, step  # noqa
