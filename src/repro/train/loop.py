"""The training loop: data -> step -> metrics/heartbeat -> checkpoint.

Composes every substrate layer: synthetic pipeline (restart-deterministic),
sharded jit step (grad accumulation), async checkpointing, heartbeat-based
fault detection, and straggler flagging.  Used by examples/train_tiny_lm.py
and (with the production mesh) repro.launch.train.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.fault_tolerance import (FaultTolerantRunner,
                                               HeartbeatRegistry)
from repro.launch.mesh import batch_axes, n_batch_shards
from repro.models.zoo import Model
from repro.sharding.plans import train_shardings
from repro.train import optim as optim_mod
from repro.train.step import accum_steps_for, make_train_step


@dataclasses.dataclass
class TrainResult:
    steps_run: int
    final_loss: float
    losses: List[float]
    restored_from: Optional[int]
    events: List
    predicted_step_s: Optional[float] = None   # cost-model verdict
    step_times_s: List[float] = dataclasses.field(default_factory=list)
    # autotuner verdict: kernel -> launch config resolved for this run's
    # shapes (tuned cache entry when present, else the kernel default)
    tuned_configs: Optional[Dict[str, Dict]] = None


def train(model: Model, mesh, *, num_steps: int = 50,
          global_batch: int = 8, seq_len: int = 64,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 25,
          lr: float = 3e-3, seed: int = 0,
          hooks: Optional[List[Callable]] = None,
          cost_model=None, log_prediction: bool = False,
          autotuner=None) -> TrainResult:
    """Run the training loop; with ``cost_model`` (a ``repro.core.costmodel.
    CostModel``) the compiled step is priced once up front and every step's
    metrics carry ``predicted_step_s`` / ``measured_step_s`` so hooks (and
    ``log_prediction=True`` stdout) can track predicted-vs-measured drift —
    the paper's close-the-loop validation applied to a live training run.

    ``autotuner`` (a ``repro.core.autotune.Autotuner``) is installed as the
    process-global tuned-dispatch handle for the duration of the run, so
    the model's ``use_pallas`` kernels trace with the tuned launch configs
    from its cache; the loop also resolves (and, with ``log_prediction``,
    prints) the tuned configs for this run's kernel shapes into
    ``TrainResult.tuned_configs``.  The previous handle is restored on
    exit."""
    from repro.core import autotune as autotune_mod
    prev_tuner = autotune_mod.install(autotuner) \
        if autotuner is not None else None
    try:
        return _train(model, mesh, num_steps=num_steps,
                      global_batch=global_batch, seq_len=seq_len,
                      ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, lr=lr,
                      seed=seed, hooks=hooks, cost_model=cost_model,
                      log_prediction=log_prediction, autotuner=autotuner)
    finally:
        if autotuner is not None:
            autotune_mod.install(prev_tuner)


def _train_kernel_shapes(cfg, seq_len: int, rows: int) -> Dict[str, Dict]:
    """The tunable-kernel problem shapes one train microstep presents."""
    shapes: Dict[str, Dict] = {}
    if cfg.rwkv:
        shapes["wkv6"] = {
            "batch": rows, "seq": seq_len,
            "heads": cfg.d_model // cfg.rwkv.head_dim,
            "head_dim": cfg.rwkv.head_dim}
    else:
        shapes["flash_attention"] = {
            "batch": rows, "seq_q": seq_len, "seq_kv": seq_len,
            "heads": cfg.padded_heads, "kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim}
    if cfg.ssm:
        shapes["ssm_scan"] = {
            "batch": rows, "seq": seq_len, "d_inner": cfg.d_model,
            "state_dim": cfg.ssm.state_dim}
    return shapes


def _train(model: Model, mesh, *, num_steps, global_batch, seq_len,
           ckpt_dir, ckpt_every, lr, seed, hooks, cost_model,
           log_prediction, autotuner=None) -> TrainResult:
    cfg = model.cfg
    optimizer = optim_mod.make_optimizer(cfg.optimizer, lr_peak=lr)

    # ----- shardings / step ---------------------------------------------------
    from repro.configs.base import ShapeCell
    cell = ShapeCell("loop", "train", seq_len, global_batch)
    if hasattr(jax, "set_mesh"):       # jax>=0.6; shardings below are explicit
        jax.set_mesh(mesh)
    psh, osh, bsh, shapes, _ = train_shardings(model, optimizer, mesh, cell)
    accum = accum_steps_for(cfg, global_batch, n_batch_shards(mesh))

    # ----- autotuner: resolve tuned launch configs for this run's shapes ------
    tuned_configs = None
    if autotuner is not None:
        # the jitted step traces GLOBAL microbatch shapes (sharding is a
        # partitioning detail): one accumulation microstep carries
        # global_batch // accum rows
        rows = max(global_batch // accum, 1)
        # key on the model's compute dtype — the same dtype the in-model
        # tuned=True dispatch sees on its activations
        tuned_configs = {
            kernel: autotuner.config_for(kernel, shapes,
                                         dtype=cfg.compute_dtype)
            for kernel, shapes in
            _train_kernel_shapes(cfg, seq_len, rows).items()}
        if log_prediction:
            for kernel, kcfg in tuned_configs.items():
                print(f"autotune: {kernel} -> {kcfg}")

    step_fn = jax.jit(
        make_train_step(model, optimizer, accum, batch_axes(mesh)),
        in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None),
        donate_argnums=(0, 1))

    # ----- state (fresh or restored) ------------------------------------------
    params = jax.jit(model.init, out_shardings=psh)(jax.random.PRNGKey(seed))
    opt_state = jax.jit(optimizer.init, out_shardings=osh)(params)
    start_step, restored_from = 0, None
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr is not None:
        got = mgr.restore_latest(like={"p": params, "o": opt_state},
                                 shardings={"p": psh, "o": osh})
        if got is not None:
            start_step, state = got
            params, opt_state = state["p"], state["o"]
            restored_from = start_step

    # ----- data (deterministic resume at start_step) ---------------------------
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len, global_batch,
                                  seed=seed))
    def to_dev(b):
        extra = {}
        if cfg.encdec:
            extra["frames"] = jnp.zeros(
                (global_batch, seq_len, cfg.d_model), jnp.bfloat16)
        return {**{k: jnp.asarray(v) for k, v in b.items()}, **extra}
    it = Prefetcher(data.iterate(start_step), transform=to_dev)

    # ----- fault tolerance ------------------------------------------------------
    runner = FaultTolerantRunner(HeartbeatRegistry(["host0"]))

    # ----- cost model: price the compiled step once, log against it each step --
    predicted_step_s = None
    if cost_model is not None:
        peek = next(it)
        # compile ONCE ahead of time, price that executable, and run the
        # loop on it (jit's dispatch cache would not reuse an AOT compile)
        step_fn = step_fn.lower(params, opt_state, peek).compile()
        pred = cost_model.predict_compiled(step_fn.as_text())
        predicted_step_s = pred.step_s
        first_batch = peek
    else:
        first_batch = None

    losses = []
    step_times: List[float] = []
    t_step = time.time()
    for step in range(start_step, num_steps):
        batch = first_batch if first_batch is not None else next(it)
        first_batch = None
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t_step
        t_step = time.time()
        step_times.append(dt)
        runner.on_step("host0", step, dt)
        if predicted_step_s is not None:
            metrics = {**metrics, "predicted_step_s": predicted_step_s,
                       "measured_step_s": dt}
            if log_prediction:
                print(f"step {step}: predicted={predicted_step_s:.3e}s "
                      f"measured={dt:.3e}s "
                      f"ratio={dt / max(predicted_step_s, 1e-12):.2f}x")
        for h in hooks or []:
            h(step, metrics)
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"p": params, "o": opt_state})
    if mgr is not None:
        mgr.save(num_steps, {"p": params, "o": opt_state}, block=True)
        mgr.wait()
    return TrainResult(num_steps - start_step, losses[-1] if losses else
                       float("nan"), losses, restored_from, runner.events,
                       predicted_step_s=predicted_step_s,
                       step_times_s=step_times,
                       tuned_configs=tuned_configs)
