"""Activation-sharding context.

Model code calls ``constrain(x, *logical_axes)`` at anchor points (post-embed,
layer carries, attention heads, MLP hidden, logits).  The step builder sets
the mapping from logical axes to mesh axes for the current launch; with no
mesh in context the constraints are no-ops, so the same model code runs in
CPU smoke tests and in the 512-device dry-run.

Logical activation axes: 'batch', 'model' (TP/heads/ffn), 'seq' (SP/decode
KV), None (replicated).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _mapping():
    return getattr(_state, "mapping", {"batch": ("data",), "model": "model",
                                       "seq": "model"})


@contextlib.contextmanager
def use_axes(batch=("data",), model="model", seq="model"):
    old = getattr(_state, "mapping", None)
    _state.mapping = {"batch": tuple(batch), "model": model, "seq": seq}
    try:
        yield
    finally:
        if old is None:
            del _state.mapping
        else:
            _state.mapping = old


def spec(*logical) -> P:
    m = _mapping()
    return P(*(m.get(a) if a is not None else None for a in logical))


def constrain(x, *logical):
    """with_sharding_constraint if a usable mesh is in context, else no-op.

    Axes whose dim is not divisible by the mesh-axis size are replicated
    instead (e.g. gemma3's single KV head over 16-way model parallelism).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = set(getattr(mesh, "axis_names", ()) or ())
        if not names:
            return x
        sizes = dict(getattr(mesh, "shape", {}) or {})
        sp = tuple(spec(*logical))
        fixed = []
        used_any = False
        for i, a in enumerate(sp):
            if a is None or i >= x.ndim:
                fixed.append(None)
                continue
            axes = (a,) if isinstance(a, str) else tuple(a)
            if not set(axes).issubset(names):
                fixed.append(None)
                continue
            total = 1
            for ax in axes:
                total *= sizes.get(ax, 1)
            if total > 1 and x.shape[i] % total == 0:
                fixed.append(a)
                used_any = True
            else:
                fixed.append(None)
        if not used_any:
            return x
        return jax.lax.with_sharding_constraint(x, P(*fixed))
    except Exception:
        return x
