"""Turn logical PartitionSpec trees into concrete NamedShardings for a mesh,
and RANK candidate mesh layouts by predicted step time.

Specs are authored with logical axis names 'data' (FSDP) and 'model'
(TP/EP/SP).  ``sanitize_specs`` drops a sharded axis from a spec when the
corresponding dim is not divisible by the axis size (GSPMD supports padding,
but uneven shardings of tiny dims - e.g. 4 query heads over 16-way model
parallelism - waste >50% of the axis; replication is strictly better there).
The sanitation decisions are returned so EXPERIMENTS.md can report them.

``rank_plans`` replaces the old fixed 16-way-model heuristic with the
calibrated cost model: every (data, model) factorization of the device
count is priced through ``CostModel.predict`` over an analytic census
(``repro.core.costmodel.analytic``) and candidates come back sorted by
predicted step time — measured microarchitecture tables choosing the mesh,
which is the ROADMAP's point of calibrating them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.costmodel.model import CostModel, Prediction
from repro.launch.mesh import batch_axes, n_batch_shards


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_specs(specs, shapes, mesh: Mesh, log: List[str] | None = None):
    """Replace non-divisible sharded dims with replication (see module doc)."""
    def fix(spec, shp):
        if spec is None:
            return P()
        dims = tuple(shp.shape)
        new_axes = []
        for i, axes in enumerate(tuple(spec) + (None,) * (len(dims) - len(tuple(spec)))):
            if axes is None:
                new_axes.append(None)
                continue
            size = _axis_size(mesh, axes)
            if i < len(dims) and dims[i] % size == 0:
                new_axes.append(axes)
            else:
                if log is not None:
                    log.append(f"replicated dim {i} ({dims[i]}) of {dims} "
                               f"instead of sharding over {axes} ({size})")
                new_axes.append(None)
        while new_axes and new_axes[-1] is None:
            new_axes.pop()
        return P(*new_axes)

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def named_tree(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def train_shardings(model, optimizer, mesh: Mesh, cell):
    """Returns (param_sh, opt_sh, batch_sh, shapes, log)."""
    log: List[str] = []
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, key)
    pspecs = sanitize_specs(model.param_specs(), param_shapes, mesh, log)
    opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
    ospecs = optimizer.state_specs(pspecs, param_shapes)
    ospecs = sanitize_specs(ospecs, opt_shapes, mesh, log)
    baxes = batch_axes(mesh)
    bspecs = model.input_shardings(cell, batch_axes=baxes)
    batch_shapes = model.input_specs(cell)
    bspecs = sanitize_specs(bspecs, batch_shapes, mesh, log)
    return (named_tree(mesh, pspecs), named_tree(mesh, ospecs),
            named_tree(mesh, bspecs),
            {"params": param_shapes, "opt": opt_shapes,
             "batch": batch_shapes}, log)


@dataclasses.dataclass
class RankedPlan:
    """One candidate mesh layout with its cost-model verdict."""
    data: int                       # data-parallel (FSDP/batch) axis size
    model: int                      # model-parallel (TP/EP/SP) axis size
    prediction: Prediction

    @property
    def step_s(self) -> float:
        return self.prediction.step_s

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return (self.data, self.model)

    def describe(self) -> str:
        p = self.prediction
        return (f"data={self.data} model={self.model}: "
                f"step={p.step_s:.3e}s ({p.bottleneck}-bound)")


def candidate_mesh_shapes(n_devices: int,
                          cfg=None) -> List[Tuple[int, int]]:
    """All (data, model) factorizations of the device count, dropping model
    widths that cannot shard BOTH the Q and KV head dims evenly
    (approximating the per-dim divisibility rule ``sanitize_specs``
    enforces — an uneven model axis replicates those projections at
    mesh-build time, so the analytic census would overprice its benefit).

    The head filter only applies to attention archs: headless configs
    (attn_impl='none' — RWKV/Mamba-style state archs — or duck-typed
    cfgs without head fields at all) have no head dim to shard, so every
    factorization stays a candidate instead of crashing on a missing or
    meaningless attribute."""
    n_heads = getattr(cfg, "n_heads", None)
    n_kv = getattr(cfg, "n_kv_heads", None) or 0
    headless = (cfg is None or not n_heads
                or getattr(cfg, "attn_impl", "gqa") in (None, "none"))
    shapes = []
    for m in range(1, n_devices + 1):
        if n_devices % m:
            continue
        if not headless and m > 1 and (n_heads % m or n_kv % m):
            continue
        shapes.append((n_devices // m, m))
    return shapes or [(n_devices, 1)]


def strip_axis(specs, axis: str = "data"):
    """Drop one mesh axis from every PartitionSpec in a tree (the dims it
    sharded become replicated).

    Serving replicas use this on ``model.param_specs()``: those specs
    carry the TRAINING layout, where 'data' is the FSDP axis sharding
    weights across the batch dimension of the mesh.  A decode step wants
    weights REPLICATED across 'data' (every batch shard multiplies the
    whole matrix — the classic inference TP layout) — and not only for
    speed: an FSDP-split gemm accumulates partial sums in a different
    order, so its bf16 rounding can diverge from the single-device
    engine's and break the sharded replica's byte-identical-tokens
    contract on argmax ties."""
    def fix(spec):
        new = []
        for e in tuple(spec):
            if e == axis:
                new.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a != axis)
                new.append(kept if kept else None)
            else:
                new.append(e)
        while new and new[-1] is None:
            new.pop()
        return P(*new)
    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, P))


def paged_decode_shardings(cfg, mesh: Mesh, max_batch: int,
                           log: List[str] | None = None):
    """The concrete :class:`NamedSharding` set for one sharded paged
    replica's fused decode step (``serve.engine.PagedServingEngine``):

    * ``pool``  — the paged KV pool ``[L, n_blocks, bs, KH, hd]`` with
      the KV-head dim over ``'model'`` (each model shard owns a head
      slice of every block; block ids stay global, so the host-side
      allocator/eviction/compaction bookkeeping is sharding-agnostic);
    * ``batch`` — ``[B]`` decode loop state (tokens, write positions)
      over ``'data'``;
    * ``io``    — the ``[2, B]`` input-echo + output stack, batch dim
      over ``'data'``;
    * ``repl``  — replicated (block tables: every shard reads the whole
      table to translate logical slots to physical blocks).

    Dims that the mesh cannot divide evenly fall back to replication,
    logged — the same rule (and reason) as ``sanitize_specs``."""
    m_sz, d_sz = mesh.shape["model"], mesh.shape["data"]
    kv = getattr(cfg, "n_kv_heads", 0) or 0
    if m_sz > 1 and kv % m_sz == 0:
        pool = P(None, None, None, "model", None)
    else:
        if m_sz > 1 and log is not None:
            log.append(f"replicated KV pool: {kv} kv heads not divisible "
                       f"by model={m_sz}")
        pool = P()
    if d_sz > 1 and max_batch % d_sz == 0:
        batch = P("data")
        io = P(None, "data")
    else:
        if d_sz > 1 and log is not None:
            log.append(f"replicated batch state: max_batch={max_batch} "
                       f"not divisible by data={d_sz}")
        batch = P()
        io = P()
    sh = lambda spec: NamedSharding(mesh, spec)
    return {"pool": sh(pool), "batch": sh(batch), "io": sh(io),
            "repl": sh(P())}


def rank_plans(cfg, cell, n_devices: int,
               cost_model: Optional[CostModel] = None,
               accum: int = 1) -> List[RankedPlan]:
    """Rank candidate (data, model) mesh layouts by predicted step time.

    Each candidate is priced through the calibrated cost model over an
    analytic census parameterized by the candidate's model-parallel width
    (per-device FLOPs, HBM bytes, ring-collective wire bytes, op
    histogram).  Returns plans sorted ascending by predicted step time —
    ``rank_plans(...)[0]`` is the recommended mesh."""
    from repro.core.costmodel.analytic import analytic_census
    cost_model = cost_model or CostModel.from_named("tpu_v5e")
    plans = []
    for d, m in candidate_mesh_shapes(n_devices, cfg):
        census = analytic_census(cfg, cell, n_devices, n_model=m,
                                 accum=accum)
        pred = cost_model.predict(census)   # hbm_bytes already analytic
        plans.append(RankedPlan(data=d, model=m, prediction=pred))
    plans.sort(key=lambda pl: pl.step_s)
    return plans


@dataclasses.dataclass
class ClusterTopology:
    """One way to spend a device budget on a serving cluster: how many
    engine replicas, and the best-ranked (data, model) mesh inside each."""
    n_replicas: int
    plan: RankedPlan                # per-replica factorization (rank_plans)
    predicted_tok_s: float          # n_replicas x batch / per-replica step_s

    @property
    def devices_per_replica(self) -> int:
        return self.plan.data * self.plan.model

    def describe(self) -> str:
        return (f"replicas={self.n_replicas} x [data={self.plan.data} "
                f"model={self.plan.model}]: "
                f"predicted={self.predicted_tok_s:.1f} tok/s "
                f"(step={self.plan.step_s:.3e}s, "
                f"{self.plan.prediction.bottleneck}-bound)")


def rank_cluster_topologies(cfg, cell, n_devices: int,
                            cost_model: Optional[CostModel] = None,
                            max_replicas: Optional[int] = None,
                            ) -> List["ClusterTopology"]:
    """Factor a device budget into ``replicas x (data, model)`` and rank
    by predicted cluster throughput.

    For every replica count dividing the budget, the per-replica mesh is
    chosen by ``rank_plans`` over the remaining devices and the cluster's
    predicted rate is ``n_replicas x global_batch / step_s`` — replicas
    serve independent traffic, so their rates add while their step time
    is the per-replica plan's.  Returned descending by predicted tok/s
    (ties to FEWER replicas: fewer routing seams for the same rate);
    ``[0]`` is the topology ``serve.cluster.ServingCluster.build`` uses
    when handed a device budget."""
    tops: List[ClusterTopology] = []
    for r in range(1, n_devices + 1):
        if n_devices % r or (max_replicas is not None and r > max_replicas):
            continue
        plan = rank_plans(cfg, cell, n_devices // r, cost_model)[0]
        rate = r * cell.global_batch / max(plan.step_s, 1e-30)
        tops.append(ClusterTopology(n_replicas=r, plan=plan,
                                    predicted_tok_s=rate))
    tops.sort(key=lambda t: (-t.predicted_tok_s, t.n_replicas))
    return tops


def serve_shardings(model, mesh: Mesh, cell):
    """Returns (param_sh, input_sh, shapes, log) for prefill/decode cells."""
    log: List[str] = []
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, key)
    pspecs = sanitize_specs(model.param_specs(), param_shapes, mesh, log)
    baxes = batch_axes(mesh)
    ispecs = model.input_shardings(cell, batch_axes=baxes)
    input_shapes = model.input_specs(cell)
    ispecs = sanitize_specs(ispecs, input_shapes, mesh, log)
    return (named_tree(mesh, pspecs), named_tree(mesh, ispecs),
            {"params": param_shapes, "inputs": input_shapes}, log)
