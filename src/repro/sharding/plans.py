"""Turn logical PartitionSpec trees into concrete NamedShardings for a mesh.

Specs are authored with logical axis names 'data' (FSDP) and 'model'
(TP/EP/SP).  ``sanitize_specs`` drops a sharded axis from a spec when the
corresponding dim is not divisible by the axis size (GSPMD supports padding,
but uneven shardings of tiny dims - e.g. 4 query heads over 16-way model
parallelism - waste >50% of the axis; replication is strictly better there).
The sanitation decisions are returned so EXPERIMENTS.md can report them.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes, n_batch_shards


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_specs(specs, shapes, mesh: Mesh, log: List[str] | None = None):
    """Replace non-divisible sharded dims with replication (see module doc)."""
    def fix(spec, shp):
        if spec is None:
            return P()
        dims = tuple(shp.shape)
        new_axes = []
        for i, axes in enumerate(tuple(spec) + (None,) * (len(dims) - len(tuple(spec)))):
            if axes is None:
                new_axes.append(None)
                continue
            size = _axis_size(mesh, axes)
            if i < len(dims) and dims[i] % size == 0:
                new_axes.append(axes)
            else:
                if log is not None:
                    log.append(f"replicated dim {i} ({dims[i]}) of {dims} "
                               f"instead of sharding over {axes} ({size})")
                new_axes.append(None)
        while new_axes and new_axes[-1] is None:
            new_axes.pop()
        return P(*new_axes)

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def named_tree(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def train_shardings(model, optimizer, mesh: Mesh, cell):
    """Returns (param_sh, opt_sh, batch_sh, shapes, log)."""
    log: List[str] = []
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, key)
    pspecs = sanitize_specs(model.param_specs(), param_shapes, mesh, log)
    opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
    ospecs = optimizer.state_specs(pspecs, param_shapes)
    ospecs = sanitize_specs(ospecs, opt_shapes, mesh, log)
    baxes = batch_axes(mesh)
    bspecs = model.input_shardings(cell, batch_axes=baxes)
    batch_shapes = model.input_specs(cell)
    bspecs = sanitize_specs(bspecs, batch_shapes, mesh, log)
    return (named_tree(mesh, pspecs), named_tree(mesh, ospecs),
            named_tree(mesh, bspecs),
            {"params": param_shapes, "opt": opt_shapes,
             "batch": batch_shapes}, log)


def serve_shardings(model, mesh: Mesh, cell):
    """Returns (param_sh, input_sh, shapes, log) for prefill/decode cells."""
    log: List[str] = []
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, key)
    pspecs = sanitize_specs(model.param_specs(), param_shapes, mesh, log)
    baxes = batch_axes(mesh)
    ispecs = model.input_shardings(cell, batch_axes=baxes)
    input_shapes = model.input_specs(cell)
    ispecs = sanitize_specs(ispecs, input_shapes, mesh, log)
    return (named_tree(mesh, pspecs), named_tree(mesh, ispecs),
            {"params": param_shapes, "inputs": input_shapes}, log)
