"""CLI surface for the sharding-plan ranker: the topology choice the
serving cluster makes, inspectable offline.

  python -m repro.sharding --calibration tpu_v5e --topology 8,8,2048

``--topology B,H,ctx`` names the serving shape: global batch, attention
heads (the arch's head count is overridden when divisible — the same
head-divisibility rule ``candidate_mesh_shapes`` prunes with), and
context length.  The first table is ``rank_plans`` verbatim — every
(data, model) factorization of ``--devices`` priced by the calibrated
cost model, ascending by predicted step time.  With more than one
device the second table is ``rank_cluster_topologies`` — the same
pricing deciding how many engine REPLICAS the budget should buy
(``serve.cluster.ServingCluster.build`` consumes ``[0]``), descending
by predicted cluster tok/s.
"""
from __future__ import annotations

import argparse
from typing import List, Optional, Tuple


def _parse_topology(text: str) -> Tuple[int, int, int]:
    parts = text.split(",")
    if len(parts) != 3:
        raise argparse.ArgumentTypeError(
            f"--topology wants B,H,ctx (three comma-separated ints), "
            f"got {text!r}")
    try:
        b, h, ctx = (int(p) for p in parts)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--topology wants integers, got {text!r}") from None
    if min(b, h, ctx) <= 0:
        raise argparse.ArgumentTypeError("--topology values must be positive")
    return b, h, ctx


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.sharding",
        description=__doc__.splitlines()[0])
    p.add_argument("--calibration", default="tpu_v5e",
                   help="named calibration the cost model prices with "
                        "(default tpu_v5e)")
    p.add_argument("--topology", type=_parse_topology, required=True,
                   metavar="B,H,ctx",
                   help="serving shape: global batch, attention heads, "
                        "context length")
    p.add_argument("--arch", default="gemma2-2b",
                   help="architecture from the configs zoo "
                        "(default gemma2-2b)")
    p.add_argument("--devices", type=int, default=16,
                   help="device budget to factorize (default 16)")
    p.add_argument("--kind", default="decode",
                   choices=("decode", "prefill", "train"),
                   help="step kind the census prices (default decode)")
    p.add_argument("--max-replicas", type=int, default=None,
                   help="cap the cluster-topology table's replica counts")
    args = p.parse_args(argv)

    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeCell
    from repro.core.costmodel import CostModel
    from repro.sharding.plans import rank_cluster_topologies, rank_plans

    if args.arch not in ARCHS:
        p.error(f"unknown arch {args.arch!r}; "
                f"available: {', '.join(sorted(ARCHS))}")
    batch, heads, ctx = args.topology
    cfg = ARCHS[args.arch]
    if cfg.n_heads != heads:
        # honor the requested head count when the arch divides into it;
        # kv heads shrink with it so GQA grouping stays legal
        cfg = reduced(cfg, n_heads=heads,
                      n_kv_heads=min(cfg.n_kv_heads, heads))
    cell = ShapeCell("cli", args.kind, ctx, batch)
    cm = CostModel.from_named(args.calibration)

    print(f"# rank_plans: arch={cfg.name} kind={args.kind} "
          f"B={batch} H={cfg.n_heads} ctx={ctx} "
          f"devices={args.devices} calibration={args.calibration}")
    plans = rank_plans(cfg, cell, args.devices, cm)
    for rank, plan in enumerate(plans):
        marker = "  <- best" if rank == 0 else ""
        print(f"{rank:3d}  {plan.describe()}{marker}")

    if args.devices > 1:
        print(f"\n# rank_cluster_topologies: {args.devices} devices as "
              f"replicas x per-replica mesh (descending predicted tok/s)")
        tops = rank_cluster_topologies(cfg, cell, args.devices, cm,
                                       max_replicas=args.max_replicas)
        for rank, top in enumerate(tops):
            marker = "  <- best" if rank == 0 else ""
            print(f"{rank:3d}  {top.describe()}{marker}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
