from repro.sharding.cli import main

raise SystemExit(main())
