from repro.sharding.plans import (named_tree, sanitize_specs,  # noqa
                                  train_shardings, serve_shardings)
