from repro.sharding.plans import (ClusterTopology, RankedPlan,  # noqa
                                  candidate_mesh_shapes, named_tree,
                                  rank_cluster_topologies, rank_plans,
                                  sanitize_specs, serve_shardings,
                                  train_shardings)
