"""Experiment specs and deterministic grid expansion.

An :class:`Experiment` is a *description* of a microbenchmark campaign: a
named parameter grid (dtype x op x shape x ...), the backend(s) it can run
on, a per-cell cost estimate, and a runner callable that measures exactly
one cell.  The campaign scheduler (``repro.core.campaign.runner``) expands
the grid into :class:`Cell`s, skips cells a previous run already completed,
and persists every measurement through ``repro.core.campaign.results``.

The grid model mirrors the paper's campaign structure (Abdelkhalik et al.,
arXiv:2208.11174): each published table is a sweep over instruction x dtype
x dependence (Tables I/II), fragment shape (Table III) or working-set size
(Table IV), so one ``Experiment`` per table reproduces the whole deliverable.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

# A cell runner measures one grid point: runner(params, quick=...) -> metrics.
CellRunner = Callable[..., Dict[str, Any]]


def _fmt_value(v: Any) -> str:
    """Canonical, filesystem/CSV-safe rendering of one grid value."""
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (tuple, list)):
        return "x".join(_fmt_value(x) for x in v)
    return str(v)


def cell_key(params: Mapping[str, Any]) -> str:
    """Stable identifier for a grid point: ``axis=value`` sorted by axis.

    This key is what resume-skip logic matches on across runs, so it must be
    deterministic and independent of grid declaration order.
    """
    return ",".join(f"{k}={_fmt_value(params[k])}" for k in sorted(params))


@dataclass(frozen=True)
class Cell:
    """One grid point of an experiment."""
    experiment: str
    params: Dict[str, Any]

    @property
    def key(self) -> str:
        return cell_key(self.params)


@dataclass(frozen=True)
class Experiment:
    """A named, schedulable microbenchmark campaign.

    ``grid`` maps axis name -> sequence of values; the campaign is the full
    cartesian product, optionally filtered by ``constraint`` (e.g. skip
    integer dtypes for MUFU-class ops).  ``quick_grid``, when given, is the
    reduced sweep used by ``--quick`` runs and CI smoke mode.
    """
    name: str
    description: str
    grid: Mapping[str, Sequence[Any]]
    runner: CellRunner
    quick_grid: Optional[Mapping[str, Sequence[Any]]] = None
    constraint: Optional[Callable[[Dict[str, Any]], bool]] = None
    backends: Tuple[str, ...] = ("cpu", "gpu", "tpu")
    cost_per_cell_s: float = 1.0
    tags: Tuple[str, ...] = field(default=())

    def axes(self, quick: bool = False) -> Mapping[str, Sequence[Any]]:
        if quick and self.quick_grid is not None:
            return self.quick_grid
        return self.grid

    def cells(self, quick: bool = False) -> list[Cell]:
        """Expand the (quick or full) grid into concrete cells, in a
        deterministic order, dropping constraint-violating combinations."""
        axes = self.axes(quick)
        names = list(axes)
        out = []
        for values in itertools.product(*(axes[n] for n in names)):
            params = dict(zip(names, values))
            if self.constraint is not None and not self.constraint(params):
                continue
            out.append(Cell(experiment=self.name, params=params))
        return out

    def estimated_cost_s(self, quick: bool = False) -> float:
        return self.cost_per_cell_s * len(self.cells(quick))

    def supports_backend(self, backend: str) -> bool:
        return backend in self.backends
