"""Schema-versioned, resumable result persistence for campaigns.

One result file per experiment: a JSON document holding campaign metadata
plus one record per completed cell, keyed by the cell's canonical key
(``spec.cell_key``).  The store writes after *every* cell (atomic
tmp+rename), so an interrupted campaign loses at most the in-flight cell
and a rerun skips everything already measured — the property that keeps
multi-hour hardware sweeps reproducible.

The schema is versioned; ``validate`` migrates older documents forward so
downstream consumers (report generator, calibration loader, perf model)
only ever see the current shape.
"""
from __future__ import annotations

import csv
import json
import os
import time
from pathlib import Path
from typing import Any, Dict, Iterable, Optional

SCHEMA_VERSION = 1

# status values a cell record may carry
STATUS_OK = "ok"
STATUS_ERROR = "error"


def new_document(experiment: str, backend: str, quick: bool,
                 meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    return {
        "schema_version": SCHEMA_VERSION,
        "experiment": experiment,
        "backend": backend,
        "quick": bool(quick),
        "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "meta": dict(meta or {}),
        "cells": {},
    }


def _migrate(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Forward-migrate older schema versions.  v0 files (pre-versioning)
    carry no per-cell records this code can trust; their metadata survives
    and the cell map starts empty so a rerun re-measures everything."""
    version = doc.get("schema_version", 0)
    if version == 0 and "cells" not in doc:
        doc = {
            "schema_version": SCHEMA_VERSION,
            "experiment": doc.get("experiment", "unknown"),
            "backend": doc.get("backend", doc.get("hardware", "unknown")),
            "quick": bool(doc.get("quick", False)),
            "created": doc.get("created", ""),
            "meta": {},
            "cells": {},
        }
    doc["schema_version"] = SCHEMA_VERSION
    return doc


def validate(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Check (and migrate) a result document; raise ValueError if unusable."""
    if not isinstance(doc, dict):
        raise ValueError("result document must be a JSON object")
    version = doc.get("schema_version", 0)
    if version > SCHEMA_VERSION:
        raise ValueError(
            f"result schema v{version} is newer than supported "
            f"v{SCHEMA_VERSION}; upgrade the repo to read this file")
    if version < SCHEMA_VERSION:
        doc = _migrate(doc)
    for field in ("experiment", "cells"):
        if field not in doc:
            raise ValueError(f"result document missing field {field!r}")
    if not isinstance(doc["cells"], dict):
        raise ValueError("result document 'cells' must be an object")
    for key, rec in doc["cells"].items():
        if "params" not in rec or "metrics" not in rec:
            raise ValueError(f"cell {key!r} missing params/metrics")
    return doc


def load_results(path: os.PathLike | str) -> Dict[str, Any]:
    """Read + validate one campaign result file."""
    return validate(json.loads(Path(path).read_text()))


def load_results_dir(results_dir: os.PathLike | str,
                     experiments: Optional[Iterable[str]] = None
                     ) -> Dict[str, Dict[str, Any]]:
    """Load every ``<experiment>.json`` in a directory -> {experiment: doc}."""
    wanted = set(experiments) if experiments is not None else None
    out: Dict[str, Dict[str, Any]] = {}
    root = Path(results_dir)
    if not root.is_dir():
        return out
    for p in sorted(root.glob("*.json")):
        try:
            doc = load_results(p)
        except (ValueError, json.JSONDecodeError):
            continue   # unrelated JSON (e.g. dry-run artifacts) in the dir
        if wanted is None or doc["experiment"] in wanted:
            out[doc["experiment"]] = doc
    return out


class ResultStore:
    """Incremental writer for one experiment's result file."""

    def __init__(self, path: os.PathLike | str, experiment: str,
                 backend: str = "unknown", quick: bool = False,
                 meta: Optional[Dict[str, Any]] = None):
        self.path = Path(path)
        if self.path.exists():
            doc = load_results(self.path)
            if doc["experiment"] != experiment:
                raise ValueError(
                    f"{self.path} holds results for {doc['experiment']!r}, "
                    f"not {experiment!r}")
            self.doc = doc
        else:
            self.doc = new_document(experiment, backend, quick, meta)

    @property
    def completed(self) -> set[str]:
        """Keys of cells measured successfully (errors are retried)."""
        return {k for k, rec in self.doc["cells"].items()
                if rec.get("status", STATUS_OK) == STATUS_OK}

    @property
    def completed_full(self) -> set[str]:
        """Keys measured successfully with the FULL sweep.  A full campaign
        must not reuse quick-mode measurements (shorter chains, smaller
        shapes), so only these satisfy a quick=False run."""
        return {k for k, rec in self.doc["cells"].items()
                if rec.get("status", STATUS_OK) == STATUS_OK
                and not rec.get("quick", False)}

    def record(self, key: str, params: Dict[str, Any],
               metrics: Dict[str, Any], elapsed_s: float = 0.0,
               status: str = STATUS_OK, error: Optional[str] = None,
               quick: bool = False) -> None:
        rec: Dict[str, Any] = {
            "params": params, "metrics": metrics, "status": status,
            "elapsed_s": float(elapsed_s), "quick": bool(quick),
        }
        if error is not None:
            rec["error"] = error
        self.doc["cells"][key] = rec
        # the document-level flag reflects what the cells actually are
        self.doc["quick"] = any(r.get("quick", False)
                                for r in self.doc["cells"].values())
        self.flush()

    def flush(self) -> None:
        """Atomic write: a crash mid-campaign never corrupts the file."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(self.doc, indent=1, sort_keys=False))
        os.replace(tmp, self.path)

    # ----- CSV export --------------------------------------------------------

    def write_csv(self, path: Optional[os.PathLike | str] = None) -> Path:
        out = Path(path) if path else self.path.with_suffix(".csv")
        write_csv(self.doc, out)
        return out


def _scalar(v: Any) -> bool:
    return isinstance(v, (int, float, str, bool)) or v is None


def write_csv(doc: Dict[str, Any], path: os.PathLike | str) -> None:
    """Flatten a result document to CSV: one row per cell, scalar metrics
    as columns, nested metrics (curves, histograms) JSON-encoded."""
    cells = doc["cells"]
    param_cols: list[str] = []
    metric_cols: list[str] = []
    for rec in cells.values():
        for k in rec["params"]:
            if k not in param_cols:
                param_cols.append(k)
        for k in rec["metrics"]:
            if k not in metric_cols:
                metric_cols.append(k)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["experiment", "cell", "status"] + param_cols + metric_cols)
        for key in sorted(cells):
            rec = cells[key]
            row = [doc["experiment"], key, rec.get("status", STATUS_OK)]
            for k in param_cols:
                row.append(spec_fmt(rec["params"].get(k)))
            for k in metric_cols:
                v = rec["metrics"].get(k)
                row.append(v if _scalar(v) else json.dumps(v))
            w.writerow(row)


def spec_fmt(v: Any) -> Any:
    if isinstance(v, (tuple, list)):
        return "x".join(str(x) for x in v)
    return v
