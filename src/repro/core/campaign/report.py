"""Regenerate the paper-style tables from persisted campaign results.

Every function here consumes *only* validated result documents
(``repro.core.campaign.results``) — no re-measurement — so the published
tables can be rebuilt from the JSON artifacts alone, on any machine.
Rows keep the repo's long-standing CSV shape ``name,us_per_call,derived``
so existing tooling keeps parsing them.

``calibration_from_results`` converts campaign measurements into the
calibration-table format consumed by ``repro.core.microbench.tables`` and
``repro.core.costmodel`` (whose loaders normalize it into the instruction/
memory/MXU layers), closing the loop: measured tables feed the cost model
directly.  ``prediction_error_table`` is the validation half of that loop.
"""
from __future__ import annotations

import sys
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def _cells(doc: Mapping[str, Any], ok_only: bool = True):
    for key in sorted(doc["cells"]):
        rec = doc["cells"][key]
        if ok_only and rec.get("status", "ok") != "ok":
            continue
        yield key, rec["params"], rec["metrics"]


def cpi_table(doc: Mapping[str, Any]) -> List[Row]:
    """Tables I/II from an ``alu_chain`` result file: the chain-length CPI
    convergence curve plus dependent/independent per-op latency."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        tag = "dep" if p["dependent"] else "ind"
        name = f"table2/{p['op']}.{p['dtype']}.{tag}"
        rows.append((name, m["per_op_ns"] / 1e3,
                     f"overhead_us={m['overhead_ns'] / 1e3:.2f}"))
        for k in sorted(m.get("cpi_curve", {}), key=int):
            rows.append((f"table1/{p['op']}.{p['dtype']}.{tag}/K={k}",
                         m["times_us"][m["lengths"].index(int(k))]
                         if int(k) in m.get("lengths", []) else 0.0,
                         f"t(K)/(K*t_inf)={m['cpi_curve'][k]:.2f}"))
    return rows


def mxu_table(doc: Mapping[str, Any]) -> List[Row]:
    """Table III from an ``mxu_shapes`` result file."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        mm, nn, kk = p["shape"]
        tag = "dep" if p["dependent"] else "ind"
        rows.append((f"table3/{p['dtype']}.m{mm}n{nn}k{kk}.{tag}",
                     m["per_op_us"], f"tflops={m['tflops']:.3f}"))
    return rows


def memory_table(doc: Mapping[str, Any]) -> List[Row]:
    """Table IV from a ``memory_chase`` result file: chase latency per
    working-set size plus the contrasting streaming-read bandwidth."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        if p.get("access", "chase") == "stream":
            rows.append((f"table4/streaming_read_{p['size_kib']}KiB", 0.0,
                         f"GBps={m['gbps']:.2f}"))
        else:
            rows.append((f"table4/chase_{p['size_kib']}KiB",
                         m["per_hop_ns"] / 1e3,
                         f"per_hop_ns={m['per_hop_ns']:.1f}"))
    return rows


def isa_table(doc: Mapping[str, Any]) -> List[Row]:
    """Table V from an ``isa_mapping`` result file."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        top = ",".join(f"{k}x{v}" for k, v in m.get("top_ops", {}).items())
        rows.append((f"table5/{p['case']}", 0.0,
                     f"src_ops={m['n_source_ops']};"
                     f"opt_ops={m['n_optimized_ops']};top={top};"
                     f"flops={m['flops']}"))
    return rows


def roofline_table(doc: Mapping[str, Any]) -> List[Row]:
    """Achieved-peak terms from a ``roofline_calibration`` result file."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        rows.append((f"roofline/{p['term']}", 0.0,
                     f"value={m['value']:.3f};unit={m['unit']};"
                     f"{m.get('detail', '')}"))
    return rows


def autotune_table(doc: Mapping[str, Any]) -> List[Row]:
    """Tuning outcomes from an ``autotune`` result file: predicted (and,
    for measured cells, wall-time) best config + speedup over default."""
    import json as _json

    rows: List[Row] = []
    for _, p, m in _cells(doc):
        derived = (f"best={_json.dumps(m['best_config'], sort_keys=True)};"
                   f"default_s={m['predicted_default_s']:.3e};"
                   f"speedup={m['predicted_speedup']:.2f};"
                   f"candidates={m['n_candidates']}")
        if "measured_best_s" in m:
            derived += f";measured_s={m['measured_best_s']:.3e}"
            if "measured_speedup" in m:
                derived += f";measured_speedup={m['measured_speedup']:.2f}"
        rows.append((f"autotune/{p['kernel']}.{p['dtype']}.{p['mode']}",
                     m["predicted_best_s"] * 1e6, derived))
    return rows


def paged_serve_table(doc: Mapping[str, Any]) -> List[Row]:
    """Slot-vs-paged serving comparison from a ``paged_serve`` result
    file: throughput side by side with resident KV bytes, plus the
    correctness/accounting columns the CI smoke step greps."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        name = f"paged_serve/bs{p['block_size']}"
        derived = (f"slot_tok_s={m['slot_tok_per_s']:.1f};"
                   f"paged_tok_s={m['paged_tok_per_s']:.1f};"
                   f"slot_kv_bytes={m['slot_kv_bytes']};"
                   f"paged_kv_bytes={m['paged_kv_bytes']};"
                   f"kv_ratio={m['kv_bytes_ratio']:.3f};"
                   f"identical={m['identical_tokens']};"
                   f"completed={m['completed_paged']}/{m['completed_slot']};"
                   f"preemptions={m['preemptions']};"
                   f"blocks_leaked={m['blocks_leaked']}")
        rows.append((name, 0.0, derived))
    return rows


def decode_hotpath_table(doc: Mapping[str, Any]) -> List[Row]:
    """Legacy-vs-fused decode hot path from a ``decode_hotpath`` result
    file: throughput and host-sync rate side by side, the correctness
    column CI greps, and the cost model's predicted byte savings."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        derived = (f"baseline_tok_s={m['baseline_tok_per_s']:.1f};"
                   f"fused_tok_s={m['fused_tok_per_s']:.1f};"
                   f"speedup={m['speedup']:.2f};"
                   f"baseline_syncs_per_step={m['baseline_syncs_per_step']:.2f};"
                   f"fused_syncs_per_step={m['fused_syncs_per_step']:.2f};"
                   f"identical={m['identical_tokens']};"
                   f"kv_bytes={m['fused_kv_bytes']};"
                   f"pred_hbm_saved={m['predicted_hbm_bytes_saved']:.3e};"
                   f"pred_boundary_saved={m['predicted_boundary_bytes_saved']:.3e}")
        rows.append((f"decode_hotpath/{p['engine']}", 0.0, derived))
    return rows


def decode_longctx_table(doc: Mapping[str, Any]) -> List[Row]:
    """Split-KV flash-decoding evidence from a ``decode_longctx`` result
    file: the lane-utilization proxy tok/s at this split factor vs the
    unsplit kernel, the tuned pick's speedup at the same context, the
    cost model's predicted crossover, and the token-equality column CI
    greps in every cell."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        name = f"decode_longctx/ctx{p['ctx']}.s{p['num_splits']}"
        derived = (f"proxy_tok_s={m['proxy_tok_s']:.1f};"
                   f"unsplit_tok_s={m['unsplit_proxy_tok_s']:.1f};"
                   f"speedup={m['speedup']:.2f};"
                   f"tuned_splits={m['tuned_splits']};"
                   f"tuned_speedup={m['tuned_speedup']:.2f};"
                   f"pred_speedup={m['predicted_speedup']:.2f};"
                   f"pred_best_splits={m['predicted_best_splits']};"
                   f"identical={m['identical_tokens']}")
        rows.append((name, float(m["wall_us"]), derived))
    return rows


def telemetry_table(doc: Mapping[str, Any]) -> List[Row]:
    """Telemetry-scenario evidence from a ``telemetry_replay`` result
    file: the drift row shows the recalibration count and the error
    before/after (the 10% gate), the overload row shows measured p99
    against the SLO target next to the ungated baseline's spike — plus
    the token-equality column CI greps on both."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        if p["scenario"] == "drift":
            derived = (f"events={m['n_events']};"
                       f"pre_err={m['pre_error']:.3f};"
                       f"post_err={m['post_error']:.3f};"
                       f"gate={m['gate']:.2f};"
                       f"identical={m['tokens_ok']};"
                       f"completed={m['completed']}/{m['n_requests']}")
        else:
            derived = (f"p99_s={m['p99_s']:.2f};"
                       f"target_s={m['target_p99_s']:.2f};"
                       f"baseline_p99_s={m['baseline_p99_s']:.2f};"
                       f"slo_held={m['slo_held']};"
                       f"deferred={m['deferred']};"
                       f"fifo={m['admission_fifo']};"
                       f"identical={m['tokens_ok']};"
                       f"completed={m['completed']}/{m['n_requests']}")
        rows.append((f"telemetry/{p['scenario']}", 0.0, derived))
    return rows


def traffic_scaling_table(doc: Mapping[str, Any]) -> List[Row]:
    """Cluster traffic-scaling evidence from a ``traffic_scaling`` result
    file: round-robin vs cost-aware tok/s and tail latency per
    (replicas, load) point, the shed/conservation/identity columns CI
    greps, and the cost-model-chosen topology for the device budget."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        name = f"traffic_scaling/r{m['replicas']}_load{m['load']:g}"
        derived = (f"rr_tok_s={m['rr_tok_per_s']:.1f};"
                   f"ca_tok_s={m['ca_tok_per_s']:.1f};"
                   f"speedup={m['speedup_tok_s']:.2f};"
                   f"rr_p99_s={m['rr_p99_s']:.2f};"
                   f"ca_p99_s={m['ca_p99_s']:.2f};"
                   f"p99_ratio={m['p99_ratio']:.2f};"
                   f"shed_rr={m['rr_shed_rate']:.2f};"
                   f"shed_ca={m['ca_shed_rate']:.2f};"
                   f"reroutes={m['ca_reroutes']};"
                   f"identical={m['identical_tokens']};"
                   f"conserved={m['rr_conserved'] and m['ca_conserved']};"
                   f"topology={m['topology_replicas']}x"
                   f"[{m['topology_data']},{m['topology_model']}]")
        rows.append((name, 0.0, derived))
    return rows


def sharded_decode_table(doc: Mapping[str, Any]) -> List[Row]:
    """Sharded-replica evidence from a ``sharded_decode`` result file:
    one row per (data, model) factorization with measured step time,
    the cost model's predicted step time for that mesh, and the
    byte-identical/sync/donation columns CI greps."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        for key in sorted(k[:-7] for k in m if k.endswith("_step_s")
                          and not k.endswith("_pred_step_s")
                          and k != "ref_step_s"):
            pred = m.get(f"{key}_pred_step_s")
            derived = (f"step_us={m[f'{key}_step_s'] * 1e6:.1f};"
                       f"ref_step_us={m['ref_step_s'] * 1e6:.1f};"
                       f"pred_step_us="
                       f"{(pred or 0.0) * 1e6:.3f};"
                       f"identical={m[f'{key}_identical']};"
                       f"sync_ok={m[f'{key}_sync_ok']};"
                       f"donated={m[f'{key}_donated']};"
                       f"preemptions={m[f'{key}_preemptions']};"
                       f"compactions={m[f'{key}_compactions']}")
            rows.append((f"sharded_decode/{key}",
                         float(m[f"{key}_step_s"]) * 1e6, derived))
    return rows


def chaos_serving_table(doc: Mapping[str, Any]) -> List[Row]:
    """Chaos-drill evidence from a ``chaos_serving`` result file: one
    row per (fault, replicas) cell with the recovery-invariant columns
    CI greps (byte-identical survivors, lost tokens, leaked blocks) and
    the detection/recovery trace (failures seen, requests recovered or
    abandoned, worst detection-to-rejoin latency, quarantine verdict)."""
    rows: List[Row] = []
    for _, p, m in _cells(doc):
        name = f"chaos_serving/{m['fault']}_r{m['replicas']}"
        derived = (f"failures={m['failures']};"
                   f"kinds={m['failure_kinds']};"
                   f"recovered={m['recovered']};"
                   f"abandoned={m['abandoned']};"
                   f"recovery_s={m['recovery_latency_s']:.2f};"
                   f"survivors_identical={m['survivors_identical']};"
                   f"tokens_lost={m['tokens_lost']};"
                   f"blocks_leaked={m['blocks_leaked']};"
                   f"quarantined={m['quarantined']};"
                   f"ok={m['ok']}")
        rows.append((name, float(m["recovery_latency_s"]), derived))
    return rows


_TABLE_FOR = {
    "alu_chain": cpi_table,
    "mxu_shapes": mxu_table,
    "memory_chase": memory_table,
    "isa_mapping": isa_table,
    "roofline_calibration": roofline_table,
    "autotune": autotune_table,
    "paged_serve": paged_serve_table,
    "decode_hotpath": decode_hotpath_table,
    "decode_longctx": decode_longctx_table,
    "telemetry_replay": telemetry_table,
    "traffic_scaling": traffic_scaling_table,
    "sharded_decode": sharded_decode_table,
    "chaos_serving": chaos_serving_table,
}


def table_for(doc: Mapping[str, Any]) -> List[Row]:
    """Dispatch a result document to its paper-table renderer."""
    exp = doc["experiment"]
    try:
        return _TABLE_FOR[exp](doc)
    except KeyError:
        raise ValueError(f"no table renderer for experiment {exp!r}; "
                         f"known: {sorted(_TABLE_FOR)}") from None


def render_rows(rows: Iterable[Row], file=None, header: bool = True) -> None:
    file = file or sys.stdout
    if header:
        print("name,us_per_call,derived", file=file)
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}", file=file)


def render_result_files(paths, file=None) -> None:
    """Load + render paper tables from result files alone — the shared body
    of `campaign report` and `paper_tables.py --from-results`."""
    from repro.core.campaign.results import load_results

    first = True
    for path in paths:
        try:
            doc = load_results(path)
            rows = table_for(doc)
        except (OSError, ValueError) as e:   # ValueError covers bad JSON too
            raise SystemExit(f"{path}: {e}") from None
        render_rows(rows, file=file, header=first)
        first = False


# ---------------------------------------------------------------------------
# prediction-error table: validate the cost model against a calibration
# ---------------------------------------------------------------------------

def prediction_error_table(table: Mapping[str, Any],
                           name: str = "") -> List[Row]:
    """The model-validation table: every row of a calibration (the paper's
    published A100 numbers, the v5e target table, or a measured campaign
    table) predicted back through the three cost-model layers, with the
    relative error.  A summary row carries max/mean error — the fixture CI
    asserts stays within 10%.  ``table`` may be a raw table dict or an
    already-normalized ``Calibration``."""
    from repro.core.costmodel.calibration import Calibration
    from repro.core.costmodel.model import (CostModel,
                                            prediction_error_rows,
                                            prediction_error_summary)
    if isinstance(table, Calibration):
        model = CostModel(table)
    else:
        model = CostModel.from_table(dict(table), name=name)
    err_rows = prediction_error_rows(model)
    rows: List[Row] = []
    for r in err_rows:
        rows.append((f"prederr/{r['name']}", 0.0,
                     f"predicted={r['predicted']:.6g};"
                     f"recorded={r['recorded']:.6g};unit={r['unit']};"
                     f"err_pct={r['err_pct']:.2f}"))
    s = prediction_error_summary(err_rows)
    rows.append(("prederr/summary", 0.0,
                 f"rows={s['rows']};max_err_pct={s['max_err_pct']:.2f};"
                 f"mean_err_pct={s['mean_err_pct']:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# calibration-table bridge: campaign results -> perf-model input
# ---------------------------------------------------------------------------

def calibration_from_results(docs: Mapping[str, Mapping[str, Any]],
                             clock_hz: Optional[float] = None
                             ) -> Dict[str, Any]:
    """Build a calibration table (the ``tables.py`` format) from campaign
    result documents, keyed by experiment name.

    The ``vpu`` section converts measured per-op latency to CPI at
    ``clock_hz`` (default 1 GHz when the host clock is unknown) so the
    cost model's instruction layer can price instruction streams straight
    from a measured campaign.
    """
    clock = clock_hz or 1e9
    backend = next((d.get("backend") for d in docs.values()
                    if d.get("backend")), "unknown")
    table: Dict[str, Any] = {
        "schema_version": 1,
        "hardware": backend,
        "source": "repro.core.campaign results "
                  f"({', '.join(sorted(docs))}) at "
                  f"{time.strftime('%F %T')}",
        "methodology": "chain-length regression (paper Fig.1/Table I), "
                       "dependent vs independent (Table II), pointer chase "
                       "(Fig.2, Table IV), matrix-unit probes (Table III)",
        "ops": {}, "memory": {}, "mxu": {}, "vpu": {}, "roofline": {},
    }
    alu = docs.get("alu_chain")
    if alu:
        for _, p, m in _cells(alu):
            tag = "dep" if p["dependent"] else "ind"
            table["ops"][f"{p['op']}.{p['dtype']}.{tag}"] = {
                "per_op_ns": m["per_op_ns"],
                "overhead_ns": m["overhead_ns"],
                "cpi_curve": m.get("cpi_curve", {}),
            }
            if p["dtype"] == "float32" and p["dependent"]:
                table["vpu"][f"{p['op']}.f32"] = {
                    "cpi": m["per_op_ns"] * 1e-9 * clock,
                    "measured_per_op_ns": m["per_op_ns"],
                }
    chase = docs.get("memory_chase")
    if chase:
        for _, p, m in _cells(chase):
            if p.get("access", "chase") == "stream":
                table.setdefault("memory_streaming", {})[
                    f"{p['size_kib']}KiB"] = {"gbps": m["gbps"]}
            else:
                table["memory"][str(m["working_set_bytes"])] = {
                    "per_hop_ns": m["per_hop_ns"],
                    "overhead_ns": m["overhead_ns"],
                }
    mxus = docs.get("mxu_shapes")
    if mxus:
        for _, p, m in _cells(mxus):
            mm, nn, kk = p["shape"]
            tag = "dep" if p["dependent"] else "ind"
            table["mxu"][f"{p['dtype']}.m{mm}n{nn}k{kk}.{tag}"] = {
                "per_op_us": m["per_op_us"],
                "tflops": m["tflops"],
            }
    roof = docs.get("roofline_calibration")
    if roof:
        for _, p, m in _cells(roof):
            table["roofline"][p["term"]] = {
                "value": m["value"], "unit": m["unit"],
            }
    return table
