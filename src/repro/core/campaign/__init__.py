"""Unified microbenchmark campaign runner.

The paper's deliverable is a set of latency/CPI tables produced by sweeping
instructions, dtypes and memory levels; this subsystem is the single
structured runner that keeps those campaigns reproducible: a registry of
named experiments (``registry``), deterministic grid expansion (``spec``),
a resumable scheduler (``runner``), schema-versioned persistence
(``results``) and the paper-table/report generator (``report``).

CLI: ``PYTHONPATH=src python -m repro.core.campaign run <experiment>``.
"""
from repro.core.campaign import report, results, runner, spec  # noqa: F401
from repro.core.campaign.registry import REGISTRY, get, names, register  # noqa: F401
from repro.core.campaign.results import ResultStore, load_results  # noqa: F401
from repro.core.campaign.runner import run, run_many  # noqa: F401
from repro.core.campaign.spec import Cell, Experiment, cell_key  # noqa: F401
