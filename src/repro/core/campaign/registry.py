"""The named-experiment registry: one entry per paper campaign.

Each experiment maps a published table of the paper (Abdelkhalik et al.,
arXiv:2208.11174) onto this backend's measurement primitives:

  * ``alu_chain``            - Tables I/II: per-op latency via chain-length
                               regression, dependent vs independent
  * ``memory_chase``         - Table IV / Fig. 2-3: pointer-chase walk of the
                               memory hierarchy + streaming bandwidth
  * ``mxu_shapes``           - Table III: matrix-unit latency/throughput per
                               dtype x tile shape (the WMMA fragment sweep)
  * ``roofline_calibration`` - achieved peaks (MXU TFLOP/s, HBM GB/s,
                               dispatch overhead) that anchor the perf model
  * ``isa_mapping``          - Table V: source -> optimized instruction
                               expansion per op class (the PTX->SASS map)
  * ``autotune``             - the tables applied: cost-model-guided launch
                               configs per tunable kernel (predicted best
                               vs default, optional measured refinement)
  * ``paged_serve``          - the memory model applied to serving: slot vs
                               paged KV cache on the same request trace
                               (tokens/s, resident KV bytes, preemptions)
  * ``decode_hotpath``       - the transfer/donation model applied to the
                               decode loop: legacy blocking path vs the
                               fused one (on-device sampling, donated
                               caches, pipelined steps) on the same trace
  * ``telemetry_replay``     - the model watched in production: the drift
                               -> recalibration and SLO-overload scenarios
                               replayed on the deterministic sim harness
  * ``traffic_scaling``      - the model placing traffic: offered load x
                               replica count through the cluster router,
                               round-robin vs cost-aware placement
                               (tok/s, p50/p99, shed rate, conservation)
  * ``chaos_serving``        - the cluster under injected faults: crash /
                               hang / corrupt / crash-loop x replica
                               count, gating byte-identical survivors,
                               zero lost tokens, zero leaked blocks and
                               restart-budget quarantine

Cell runners take ``(params, quick=...)`` and return a flat-ish metrics
dict; the scheduler in ``runner.py`` owns ordering, persistence and resume.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.core.campaign.spec import Experiment

# ---------------------------------------------------------------------------
# cell runners (one grid point each; heavy imports stay inside the calls so
# `campaign list` and the result/report tooling never pay jax startup twice)
# ---------------------------------------------------------------------------


def run_alu_cell(params: Dict[str, Any], quick: bool = False) -> Dict[str, Any]:
    import jax.numpy as jnp
    from repro.core.microbench import harness

    lengths = (4, 16, 64) if quick else (4, 16, 64, 256)
    r = harness.run_chain(harness.OPS[params["op"]], params["op"],
                          dtype=jnp.dtype(params["dtype"]), lengths=lengths,
                          dependent=params["dependent"])
    return {
        "per_op_ns": r.per_op_s * 1e9,
        "overhead_ns": r.overhead_s * 1e9,
        "lengths": list(r.lengths),
        "times_us": [t * 1e6 for t in r.times_s],
        "cpi_curve": {str(k): v for k, v in r.cpi_curve.items()},
    }


def run_chase_cell(params: Dict[str, Any], quick: bool = False
                   ) -> Dict[str, Any]:
    from repro.core.microbench import memory

    size_bytes = params["size_kib"] * 1024
    if params.get("access", "chase") == "stream":
        bw = memory.streaming_bandwidth(size_bytes)
        return {"gbps": bw / 1e9, "working_set_bytes": size_bytes}
    hops = (64, 256, 1024) if quick else (256, 1024, 4096)
    r = memory.run_chase(size_bytes, hop_counts=hops)
    return {
        "per_hop_ns": r.per_hop_s * 1e9,
        "overhead_ns": r.overhead_s * 1e9,
        "working_set_bytes": r.working_set_bytes,
        "hops": list(r.hops),
        "times_us": [t * 1e6 for t in r.times_s],
    }


def run_mxu_cell(params: Dict[str, Any], quick: bool = False
                 ) -> Dict[str, Any]:
    from repro.core.microbench import mxu

    lengths = (1, 2, 4) if quick else (1, 2, 4, 8)
    # no s8 dot on this harness's backends: int8 cells measure the bf16
    # path (the old table3 behaviour) and record the substitution
    dtype = params["dtype"]
    compute_dtype = "bfloat16" if dtype == "int8" else dtype
    r = mxu.run_mxu(dtype=compute_dtype, shape=tuple(params["shape"]),
                    dependent=params["dependent"], lengths=lengths)
    return {
        "per_op_us": r.per_op_s * 1e6,
        "overhead_us": r.overhead_s * 1e6,
        "flops": r.flops,
        "tflops": r.tflops,
        "compute_dtype": compute_dtype,
    }


def run_roofline_cal_cell(params: Dict[str, Any], quick: bool = False
                          ) -> Dict[str, Any]:
    """Measure one achieved-peak term of the roofline on this backend."""
    term = params["term"]
    if term == "mxu_peak_tflops":
        from repro.core.microbench import mxu
        shape = (256, 256, 256) if quick else (512, 512, 512)
        r = mxu.run_mxu(dtype="float32", shape=shape, dependent=False,
                        lengths=(1, 2, 4))
        return {"value": r.tflops, "unit": "TFLOP/s",
                "detail": f"independent f32 matmul {shape}"}
    if term == "hbm_stream_gbs":
        from repro.core.microbench import memory
        size = 16 * 2**20 if quick else 64 * 2**20
        bw = memory.streaming_bandwidth(size)
        return {"value": bw / 1e9, "unit": "GB/s",
                "detail": f"sequential reduce over {size // 2**20} MiB"}
    if term == "dispatch_overhead_us":
        import jax.numpy as jnp
        from repro.core.microbench import harness
        r = harness.run_chain(harness.OPS["add"], "add", dtype=jnp.float32,
                              lengths=(1, 2, 4, 8), dependent=True)
        return {"value": r.overhead_s * 1e6, "unit": "us",
                "detail": "t(K)=a+bK regression intercept, add.f32"}
    raise ValueError(f"unknown roofline calibration term {term!r}")


def run_isa_cell(params: Dict[str, Any], quick: bool = False
                 ) -> Dict[str, Any]:
    """StableHLO -> optimized-HLO expansion for one op class (Table V)."""
    import jax
    import jax.numpy as jnp
    from repro.core.isa import hlo_census as hc

    cases = {
        "add.f32": lambda x: x + 1.0,
        "mul.f32": lambda x: x * 1.5,
        "fma.f32": lambda x: x * 1.5 + 2.0,
        "div.f32": lambda x: x / 1.5,
        "rsqrt.f32": lambda x: jax.lax.rsqrt(jnp.abs(x) + 1e-3),
        "exp.f32": lambda x: jnp.exp(x * 1e-3),
        "tanh.f32": lambda x: jnp.tanh(x),
        "softmax.f32": lambda x: jax.nn.softmax(x, axis=-1),
        "matmul.f32": lambda x: x @ x.T,
        "reduce.f32": lambda x: jnp.sum(x, axis=-1),
        "gather": lambda x: x[jnp.arange(8) % x.shape[0]],
        "scan8": lambda x: jax.lax.scan(lambda c, _: (c * 1.01, ()), x,
                                        None, length=8)[0],
    }
    fn = cases[params["case"]]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    lowered = jax.jit(fn).lower(x)
    compiled = lowered.compile()
    m = hc.op_mapping_table(lowered.as_text(), compiled.as_text())
    c = hc.census(compiled.as_text())
    top = {k: int(v) for k, v in list(c["op_histogram"].items())[:3]}
    return {
        "n_source_ops": m["n_source_ops"],
        "n_optimized_ops": m["n_optimized_ops"],
        "flops": int(c["flops"]),
        "top_ops": top,
    }


ISA_CASES = ("add.f32", "mul.f32", "fma.f32", "div.f32", "rsqrt.f32",
             "exp.f32", "tanh.f32", "softmax.f32", "matmul.f32",
             "reduce.f32", "gather", "scan8")


def run_autotune_cell(params: Dict[str, Any], quick: bool = False
                      ) -> Dict[str, Any]:
    """Tune one kernel's launch space: analytic ranking always (pure cost
    model, runs on CPU), measured top-K refinement when mode='measured'
    (interpret-mode kernels off-TPU — slow but true wall time)."""
    from repro.core.autotune import Autotuner
    from repro.core.costmodel import CostModel

    measured = params.get("mode", "analytic") == "measured"
    tuner = Autotuner(CostModel.from_named(params.get("calibration",
                                                      "tpu_v5e")),
                      measure=measured, top_k=2 if quick else 3)
    shapes = None
    if quick or measured:
        # small problems keep interpret-mode timing (and CI) tractable
        shapes = {
            "flash_attention": {"batch": 1, "seq_q": 128, "seq_kv": 128,
                                "heads": 2, "kv_heads": 1, "head_dim": 64},
            "paged_attention": {"batch": 2, "heads": 2, "kv_heads": 1,
                                "head_dim": 32, "ctx": 128},
            "ssm_scan": {"batch": 1, "seq": 64, "d_inner": 256,
                         "state_dim": 8},
            "wkv6": {"batch": 1, "seq": 64, "heads": 4, "head_dim": 32},
            "mxu_probe": {"m": 256, "k": 256, "n": 256},
        }[params["kernel"]]
    res = tuner.tune(params["kernel"], shapes, dtype=params["dtype"])
    out = {
        "best_config": dict(res.best),
        "default_config": dict(res.default),
        "predicted_best_s": res.predicted_best_s,
        "predicted_default_s": res.predicted_default_s,
        "predicted_speedup": res.predicted_speedup,
        "n_candidates": len(res.ranked),
        "cache_key": res.key,
    }
    if res.measured_best_s is not None:
        out["measured_best_s"] = res.measured_best_s
        if res.measured_speedup is not None:
            out["measured_speedup"] = res.measured_speedup
    return out


def run_paged_serve_cell(params: Dict[str, Any], quick: bool = False
                         ) -> Dict[str, Any]:
    """Serve one deterministic mixed-length trace through BOTH engines and
    compare: tokens/s, resident KV bytes, greedy-token equality, and the
    paged engine's preemption/leak accounting."""
    import time

    import jax
    import numpy as np

    from repro.configs import ARCHS, reduced
    from repro.models.zoo import build_model
    from repro.serve import PagedServingEngine, ServingEngine

    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    model = build_model(cfg)
    weights = model.init(jax.random.PRNGKey(0))
    n_req = 6 if quick else int(params.get("n_requests", 16))
    max_batch, max_len = 4, 64
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(1, 33))).astype(np.int32)
               for _ in range(n_req)]

    slot = ServingEngine(model, weights, max_batch=max_batch,
                         max_len=max_len)
    rids_s = [slot.submit(p, max_new_tokens=6) for p in prompts]
    t0 = time.perf_counter()
    s_stats = slot.run_until_done()
    slot_s = time.perf_counter() - t0

    bs = int(params["block_size"])
    pool = params.get("n_blocks")
    # default pool: ~60% of the slot-equivalent rectangle — the memory
    # saving the paged layout exists to bank
    n_blocks = int(pool) if pool else max(
        -(-max_len // bs), int(0.6 * max_batch * (-(-max_len // bs))))
    paged = PagedServingEngine(model, weights, max_batch=max_batch,
                               max_len=max_len, block_size=bs,
                               n_blocks=n_blocks,
                               chunk_size=int(params.get("chunk", 16)))
    rids_p = [paged.submit(p, max_new_tokens=6) for p in prompts]
    t0 = time.perf_counter()
    p_stats = paged.run_until_done(max_steps=20_000)
    paged_s = time.perf_counter() - t0

    identical = all(slot.done[a].tokens == paged.done[b].tokens
                    for a, b in zip(rids_s, rids_p))
    paged.allocator.check()
    return {
        "completed_slot": s_stats.completed,
        "completed_paged": p_stats.completed,
        "slot_tok_per_s": s_stats.decoded_tokens / max(slot_s, 1e-9),
        "paged_tok_per_s": p_stats.decoded_tokens / max(paged_s, 1e-9),
        "slot_kv_bytes": slot.kv_cache_bytes(),
        "paged_kv_bytes": paged.kv_cache_bytes(),
        "kv_bytes_ratio": paged.kv_cache_bytes() / slot.kv_cache_bytes(),
        "identical_tokens": identical,
        "preemptions": p_stats.preemptions,
        "prefill_chunks": p_stats.prefill_chunks,
        "peak_block_occupancy": p_stats.peak_blocks_in_use / n_blocks,
        "blocks_leaked": n_blocks - paged.allocator.n_free,
    }


def run_decode_hotpath_cell(params: Dict[str, Any], quick: bool = False
                            ) -> Dict[str, Any]:
    """Serve one deterministic trace through an engine's legacy blocking
    path (``fused=False``: fresh uploads, [B, vocab] logits synced,
    undonated cache) and through the fused hot path (on-device sampling,
    donated caches, pipelined steps) and compare: tokens/s, host syncs
    per step, resident KV bytes, greedy-token equality, plus the analytic
    cost model's predicted per-step byte savings."""
    import time

    import jax
    import numpy as np

    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeCell
    from repro.core.costmodel import analytic
    from repro.models.zoo import build_model
    from repro.serve import PagedServingEngine, ServingEngine

    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    model = build_model(cfg)
    weights = model.init(jax.random.PRNGKey(0))
    n_req = 6 if quick else int(params.get("n_requests", 16))
    max_batch, max_len = 4, 64
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(1, 33))).astype(np.int32)
               for _ in range(n_req)]

    def build(fused):
        if params["engine"] == "paged":
            return PagedServingEngine(model, weights, max_batch=max_batch,
                                      max_len=max_len, block_size=8,
                                      chunk_size=16, fused=fused)
        return ServingEngine(model, weights, max_batch=max_batch,
                             max_len=max_len, fused=fused)

    out: Dict[str, Any] = {"engine": params["engine"]}
    done = {}
    warmup = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
              for _ in range(2)]
    for label, fused in (("baseline", False), ("fused", True)):
        eng = build(fused)
        # warm the engine first: each instance jits/AOT-compiles its own
        # step closures, and a cold timed region would mostly measure the
        # compiler (and charge the fused path for its extra jitted fns),
        # not steady-state decode — the thing this artifact tracks
        for p in warmup:
            eng.submit(p, max_new_tokens=4)
        eng.run_until_done(max_steps=20_000)
        steps0, dec0 = eng.stats.steps, eng.stats.decoded_tokens
        syncs0 = eng.stats.host_syncs
        rids = [eng.submit(p, max_new_tokens=6) for p in prompts]
        t0 = time.perf_counter()
        stats = eng.run_until_done(max_steps=20_000)
        wall = time.perf_counter() - t0
        done[label] = [eng.done[r].tokens for r in rids]
        steps = stats.steps - steps0
        out[f"{label}_tok_per_s"] = ((stats.decoded_tokens - dec0)
                                     / max(wall, 1e-9))
        out[f"{label}_steps"] = steps
        out[f"{label}_syncs_per_step"] = ((stats.host_syncs - syncs0)
                                          / max(steps, 1))
        out[f"{label}_kv_bytes"] = eng.kv_cache_bytes()
    out["identical_tokens"] = done["baseline"] == done["fused"]
    out["speedup"] = out["fused_tok_per_s"] / max(out["baseline_tok_per_s"],
                                                  1e-9)
    # the cost model's view of what the fused path removed per step
    cell = ShapeCell("hotpath", "decode", max_len, max_batch)
    legacy_b = analytic.analytic_serve_bytes(cfg, cell, 1, n_model=1)
    fused_b = analytic.analytic_serve_bytes(cfg, cell, 1, n_model=1,
                                            donated=True)
    out["predicted_hbm_bytes_saved"] = legacy_b - fused_b
    out["predicted_boundary_bytes_saved"] = (
        analytic.decode_boundary_bytes(cfg, cell)
        - analytic.decode_boundary_bytes(cfg, cell, device_sampling=True))
    return out


def run_telemetry_replay_cell(params: Dict[str, Any], quick: bool = False
                              ) -> Dict[str, Any]:
    """Replay one telemetry acceptance scenario on the deterministic sim
    harness (``repro.serve.sim``) and record its evidence dict: the
    drift scenario must show exactly one recalibration restoring the
    windowed prediction error under the 10% gate; the overload scenario
    must show the token bucket holding the p99 SLO that an ungated run
    of the same burst violates.  Both must keep tokens byte-identical."""
    from repro.serve.telemetry.scenarios import (run_drift_scenario,
                                                 run_overload_scenario)

    if params["scenario"] == "drift":
        res = run_drift_scenario(drift_factor=float(params.get("factor",
                                                               2.0)))
    else:
        res = run_overload_scenario(load_factor=int(params.get("load", 2)))
    # the per-event dicts are nested detail; the flat fields are the table
    res.pop("events", None)
    return res


# ---------------------------------------------------------------------------
# grids
# ---------------------------------------------------------------------------

# mirrors harness.OPS / INT_OPS / FLOAT_ONLY without importing jax at
# registry-import time; the constraint keeps the product paper-legal
_ALU_OPS = ("add", "sub", "mul", "fma", "max", "min", "abs", "and", "xor",
            "popc", "clz", "div", "rem", "rsqrt", "sqrt", "exp", "log",
            "sin", "tanh", "sigmoid", "select")
_INT_OPS = {"and", "xor", "popc", "clz"}
_FLOAT_ONLY = {"rsqrt", "sqrt", "exp", "log", "sin", "tanh", "sigmoid",
               "div", "fma"}


def _alu_legal(params: Dict[str, Any]) -> bool:
    is_int = params["dtype"].startswith("int")
    if is_int and params["op"] in _FLOAT_ONLY:
        return False
    if not is_int and params["op"] in _INT_OPS:
        return False
    return True


REGISTRY: Dict[str, Experiment] = {}


def register(exp: Experiment) -> Experiment:
    if exp.name in REGISTRY:
        raise ValueError(f"experiment {exp.name!r} already registered")
    REGISTRY[exp.name] = exp
    return exp


def get(name: str) -> Experiment:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown experiment {name!r}; available: "
                       f"{', '.join(names())}") from None


def names() -> List[str]:
    return sorted(REGISTRY)


register(Experiment(
    name="alu_chain",
    description="per-op latency via chain-length regression, dependent vs "
                "independent (paper Tables I/II)",
    grid={"op": _ALU_OPS,
          "dtype": ("float32", "bfloat16", "int32"),
          "dependent": (True, False)},
    quick_grid={"op": ("add", "mul", "fma", "exp"),
                "dtype": ("float32",),
                "dependent": (True, False)},
    constraint=_alu_legal,
    runner=run_alu_cell,
    cost_per_cell_s=2.0,
    tags=("vpu", "latency"),
))

register(Experiment(
    name="memory_chase",
    description="memory-hierarchy pointer chase + streaming bandwidth over "
                "working-set sizes (paper Table IV / Fig. 2-3)",
    grid={"access": ("chase", "stream"),
          "size_kib": (16, 256, 4096, 65536)},
    quick_grid={"access": ("chase", "stream"),
                "size_kib": (16, 4096)},
    runner=run_chase_cell,
    cost_per_cell_s=3.0,
    tags=("memory", "latency"),
))

register(Experiment(
    name="mxu_shapes",
    description="matrix-unit latency/throughput per dtype x tile shape "
                "(paper Table III, the WMMA fragment sweep; int8 measures "
                "the bf16 path where no s8 dot exists)",
    grid={"dtype": ("bfloat16", "float32", "int8"),
          "shape": ((128, 128, 128), (256, 256, 256), (512, 512, 128)),
          "dependent": (True, False)},
    quick_grid={"dtype": ("float32",),
                "shape": ((128, 128, 128),),
                "dependent": (True, False)},
    runner=run_mxu_cell,
    cost_per_cell_s=4.0,
    tags=("mxu", "throughput"),
))

register(Experiment(
    name="roofline_calibration",
    description="achieved peaks (MXU TFLOP/s, HBM GB/s, dispatch overhead) "
                "that anchor the roofline/predictor calibration",
    grid={"term": ("mxu_peak_tflops", "hbm_stream_gbs",
                   "dispatch_overhead_us")},
    runner=run_roofline_cal_cell,
    cost_per_cell_s=5.0,
    tags=("roofline", "calibration"),
))

register(Experiment(
    name="autotune",
    description="cost-model-guided kernel autotuning: ranked launch "
                "configs per tunable Pallas kernel (analytic; 'measured' "
                "adds the top-K wall-time refinement stage)",
    grid={"kernel": ("flash_attention", "paged_attention", "ssm_scan",
                     "wkv6", "mxu_probe"),
          "dtype": ("bf16",),
          "mode": ("analytic", "measured")},
    quick_grid={"kernel": ("flash_attention", "paged_attention", "ssm_scan",
                           "wkv6", "mxu_probe"),
                "dtype": ("bf16",),
                "mode": ("analytic",)},
    runner=run_autotune_cell,
    cost_per_cell_s=6.0,
    tags=("autotune", "costmodel"),
))

register(Experiment(
    name="paged_serve",
    description="slot vs paged KV-cache serving on one deterministic "
                "mixed-length trace: tokens/s, resident KV bytes, greedy "
                "equality, preemption + block-leak accounting",
    grid={"block_size": (8, 16), "chunk": (16,)},
    quick_grid={"block_size": (8,), "chunk": (8,)},
    runner=run_paged_serve_cell,
    cost_per_cell_s=30.0,
    tags=("serve", "paging", "memory"),
))

register(Experiment(
    name="decode_hotpath",
    description="legacy blocking decode vs the fused hot path (on-device "
                "sampling, donated caches, pipelined steps) on one trace: "
                "tok/s, host syncs/step, KV bytes, greedy equality",
    grid={"engine": ("slot", "paged")},
    runner=run_decode_hotpath_cell,
    cost_per_cell_s=30.0,
    tags=("serve", "hotpath", "memory"),
))

register(Experiment(
    name="telemetry_replay",
    description="production-telemetry scenarios on the sim harness: "
                "injected cost-model drift -> one online recalibration "
                "(error back under the 10% gate), and burst overload "
                "under the SLO token bucket (p99 held, newest shed)",
    grid={"scenario": ("drift", "overload")},
    runner=run_telemetry_replay_cell,
    cost_per_cell_s=20.0,
    tags=("serve", "telemetry", "costmodel"),
))

register(Experiment(
    name="isa_mapping",
    description="source -> optimized instruction expansion per op class "
                "(paper Table V, the PTX->SASS map)",
    grid={"case": ISA_CASES},
    quick_grid={"case": ("add.f32", "softmax.f32", "matmul.f32", "scan8")},
    runner=run_isa_cell,
    cost_per_cell_s=0.5,
    tags=("isa",),
))


def run_decode_longctx_cell(params: Dict[str, Any], quick: bool = False
                            ) -> Dict[str, Any]:
    """Split-KV flash-decoding sweep: one long-context decode-attention
    call at ``num_splits`` vs the unsplit kernel vs the jnp oracle.

    Interpret mode executes grid cells sequentially, so raw wall time
    cannot show a parallelism win on CPU CI.  The measured proxy models
    what the grid *shape* buys on hardware: per-cell work is the wall
    time divided by the cells actually run, and a chip with ``n_cores``
    grid lanes needs ``ceil(cells / n_cores)`` sequential rounds — so
    ``proxy tok/s = B * cells / (wall * rounds)``.  More splits shrink
    per-cell work (fewer pages each) until the lanes fill; the analytic
    cost model must predict the same crossover (``predicted_best_splits``)
    from the census's ``grid_cells`` utilization term alone.  Greedy
    tokens (argmax through a fixed random readout) must be byte-identical
    across split, unsplit, and oracle in every cell.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.autotune.search import Autotuner
    from repro.core.autotune.space import get_tunable
    from repro.core.costmodel import CostModel
    from repro.kernels import ops
    from repro.kernels.ref import paged_attention_ref

    ctx, num_splits = int(params["ctx"]), int(params["num_splits"])
    # one long sequence, small batch: 4 grid cells unsplit, far below the
    # modeled lane count — the regime splits exist for.  Pages are kept
    # large enough (bs x D) that per-page streaming dominates the
    # interpreter's per-cell dispatch overhead, or the proxy would
    # understate what the grid shape buys.
    B, H, KH, D, bs = 1, 4, 2, 128, 32
    nb = -(-ctx // bs)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, D)) * 0.3, jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(B * nb, bs, KH, D)) * 0.3,
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(B * nb, bs, KH, D)) * 0.3,
                          jnp.float32)
    bt = jnp.asarray(rng.permutation(B * nb).reshape(B, nb).astype(np.int32))
    lens = jnp.full((B,), ctx, jnp.int32)
    readout = jnp.asarray(rng.normal(size=(H * D, 256)), jnp.float32)

    cm = CostModel.from_named("tpu_v5e")
    lanes = max(int(getattr(cm.hw, "n_cores", 1)), 1)

    def run(ns):
        # hbm=True: the production lowering — per-page DMA, so each cell
        # only pays for the pages its split reads.  The staged lowering
        # would copy the WHOLE pool into every grid cell under interpret
        # mode, burying the split signal in per-cell staging cost.
        return ops.paged_attention(q, k_pages, v_pages, bt, lens,
                                   num_splits=ns, hbm=True)

    def greedy(out):
        logits = out.reshape(B, H * D) @ readout
        return np.asarray(jnp.argmax(logits, axis=-1)).tolist()

    def wall_s(ns):
        jax.block_until_ready(run(ns))            # compile + warm
        iters = 2 if quick else 5
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(run(ns))
        return (time.perf_counter() - t0) / iters

    def proxy_tok_s(wall, ns):
        cells = B * H * max(ns, 1)
        rounds = -(-cells // lanes)
        return B * cells / max(wall * rounds, 1e-12)

    # analytic ranking over the split ladder at this cell's layout — the
    # cost model's predicted crossover, and what the tuning cache would
    # install for this context bucket
    tn = get_tunable("paged_attention")
    shapes = {"batch": B, "heads": H, "kv_heads": KH, "head_dim": D,
              "ctx": ctx}

    def predict_s(ns):
        census = dict(tn.census(shapes, {"block_size": bs,
                                         "num_splits": ns}, "f32"))
        census.pop("mxu_shape", None)
        return cm.predict(census, dtype="f32").step_s

    ladder = [s for s in (1, 2, 4, 8, 16) if s <= nb]
    pred = {s: predict_s(s) for s in ladder}
    predicted_best_splits = min(ladder, key=lambda s: (pred[s], s))

    # the real tuner ranks the same space through the cache-key path
    # (shape bucket includes ctx, so contexts tune independently)
    tuner = Autotuner(cm, dtype="f32")
    tuned = tuner.tune("paged_attention", shapes)

    w_this = wall_s(num_splits)
    w_unsplit = w_this if num_splits == 1 else wall_s(1)
    w_tuned = (w_this if predicted_best_splits == num_splits
               else wall_s(predicted_best_splits))
    out_this, out_unsplit = run(num_splits), run(1)
    oracle = paged_attention_ref(q, k_pages, v_pages, bt, lens)
    toks = greedy(out_this)
    identical = (toks == greedy(out_unsplit) == greedy(oracle))

    this_tok_s = proxy_tok_s(w_this, num_splits)
    unsplit_tok_s = proxy_tok_s(w_unsplit, 1)
    tuned_tok_s = proxy_tok_s(w_tuned, predicted_best_splits)
    return {
        "ctx": ctx, "num_splits": num_splits, "lanes": lanes,
        "wall_us": w_this * 1e6,
        "proxy_tok_s": this_tok_s,
        "unsplit_proxy_tok_s": unsplit_tok_s,
        "speedup": this_tok_s / max(unsplit_tok_s, 1e-12),
        "tuned_splits": predicted_best_splits,
        "tuned_proxy_tok_s": tuned_tok_s,
        "tuned_speedup": tuned_tok_s / max(unsplit_tok_s, 1e-12),
        "predicted_s": pred[num_splits] if num_splits in pred
        else predict_s(num_splits),
        "predicted_unsplit_s": pred[1],
        "predicted_speedup": pred[1] / max(
            pred.get(num_splits, predict_s(num_splits)), 1e-30),
        "predicted_best_splits": predicted_best_splits,
        "tuner_best_config": dict(tuned.best),
        "tuner_cache_key": tuned.key,
        "identical_tokens": bool(identical),
        "max_abs_err_vs_ref": float(jnp.max(jnp.abs(out_this - oracle))),
    }


register(Experiment(
    name="decode_longctx",
    description="split-KV flash-decoding: context length x split factor, "
                "measured lane-utilization proxy tok/s vs the unsplit "
                "kernel, analytic crossover prediction, greedy-token "
                "equality vs the oracle",
    grid={"ctx": (256, 1024, 4096), "num_splits": (1, 2, 4, 8)},
    quick_grid={"ctx": (128, 512), "num_splits": (1, 2, 4)},
    runner=run_decode_longctx_cell,
    cost_per_cell_s=15.0,
    tags=("serve", "kernels", "longctx"),
))

def run_traffic_scaling_cell(params: Dict[str, Any], quick: bool = False
                             ) -> Dict[str, Any]:
    """The cluster tier under offered load: one skewed trace (every
    ``period``-th request long, period = replica count, so round-robin
    piles the long ones onto one replica) served by an N-replica
    ``ServingCluster`` on REAL arrays under the parallel-replica virtual
    clock, once per placement policy.  Reports tok/s, p50/p99 latency,
    shed rate, reroute/preemption counts, token conservation, and the
    cost-model-chosen topology for the device budget — the artifact that
    has to show cost-aware placement beating round-robin."""
    import time

    import jax
    import numpy as np

    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeCell
    from repro.core.costmodel import CostModel
    from repro.models.zoo import build_model
    from repro.serve import PagedServingEngine
    from repro.serve.cluster import ServingCluster, serve_trace, skewed_trace
    from repro.serve.sim import SimClock
    from repro.sharding.plans import rank_cluster_topologies

    r = int(params["replicas"])
    load = float(params["load"])
    n_req = (4 * r if quick else 8 * r)
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    model = build_model(cfg)
    weights = model.init(jax.random.PRNGKey(0))
    cm = CostModel.from_named("tpu_v5e")
    max_batch, max_len, bs, chunk = 4, 64, 8, 16
    # per-replica pool: ~60% of the slot-equivalent rectangle, same ratio
    # as paged_serve — tight enough that a long-request pileup preempts
    n_blocks = max(-(-max_len // bs),
                   int(0.6 * max_batch * (-(-max_len // bs))))
    period = max(r, 2)

    def build_cluster(policy):
        clock = SimClock()
        cl = ServingCluster.build(
            model, weights, n_replicas=r, policy=policy, clock=clock,
            cost_model=cm, max_batch=max_batch, max_len=max_len,
            block_size=bs, n_blocks=n_blocks, chunk_size=chunk,
            shed_wait_s=float(params.get("shed_wait_s", 30.0)))
        return cl, clock

    # calibrate the arrival gap to this machine: warm one engine (each
    # engine instance compiles its own step closures), then price one
    # steady-state step with a second warmed instance
    interval_s = None
    rng = np.random.default_rng(0)
    warm_prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
                    for _ in range(2)]
    for _ in range(2):
        eng = PagedServingEngine(model, weights, max_batch=max_batch,
                                 max_len=max_len, block_size=bs,
                                 n_blocks=n_blocks, chunk_size=chunk)
        for p in warm_prompts:
            eng.submit(p, max_new_tokens=4)
        t0 = time.perf_counter()
        st = eng.run_until_done(max_steps=20_000)
        interval_s = max((time.perf_counter() - t0) / max(st.steps, 1),
                         1e-5)

    out: Dict[str, Any] = {
        "replicas": r, "load": load, "n_requests": n_req,
        "interval_s": interval_s, "n_blocks_per_replica": n_blocks,
    }
    trace = skewed_trace(n_req, vocab=cfg.vocab_size, period=period,
                         long_len=32, short_len=4, long_new=16, short_new=4,
                         interval_s=interval_s, load=load)
    tokens_by_policy: Dict[str, Dict[int, list]] = {}
    for key, policy in (("rr", "round_robin"), ("ca", "cost_aware")):
        cl, clock = build_cluster(policy)
        # warm every replica (per-instance jit) OUTSIDE the router so the
        # timed trace measures steady-state decode, then rewind the clock
        for eng in cl.replicas:
            for p in warm_prompts:
                eng.submit(p, max_new_tokens=4)
            eng.run_until_done(max_steps=20_000)
        clock.t = 0.0
        admitted = serve_trace(cl, trace, clock, min_dt=interval_s / 4,
                               max_ticks=50_000)
        wall = max(clock.t, 1e-9)
        toks = sum(len(q.tokens) for q in cl.done.values())
        lats = sorted(cl.done[c].finished_s - admitted[c] for c in cl.done)
        grab = lambda q: lats[int(q * (len(lats) - 1))] if lats else 0.0
        conserved = (len(cl.done) == len(admitted)
                     and all(len(q.tokens) == q.max_new_tokens
                             for q in cl.done.values()))
        if conserved:
            # drained-trace invariant: every per-request router dict
            # (_local/_origin/_moves) must be pruned, or a long-running
            # cluster leaks bookkeeping per request
            cl.router.assert_drained()
        tokens_by_policy[key] = {
            round(admitted[c] / (interval_s / load)): list(cl.done[c].tokens)
            for c in cl.done}           # trace index -> tokens
        out.update({
            f"{key}_tok_per_s": toks / wall,
            f"{key}_p50_s": grab(0.50),
            f"{key}_p99_s": grab(0.99),
            f"{key}_shed_rate": cl.stats.shed / max(len(trace), 1),
            f"{key}_completed": len(cl.done),
            f"{key}_reroutes": cl.stats.reroutes,
            f"{key}_preemptions": sum(e.stats.preemptions
                                      for e in cl.replicas),
            f"{key}_conserved": bool(conserved),
        })

    # greedy decode is deterministic per request, so the two policies must
    # produce byte-identical tokens for every trace index both admitted
    shared = set(tokens_by_policy["rr"]) & set(tokens_by_policy["ca"])
    out["identical_tokens"] = all(
        tokens_by_policy["rr"][i] == tokens_by_policy["ca"][i]
        for i in shared)
    if r == 1:
        # ...and at one replica the cluster must be byte-identical to a
        # bare paged engine fed the same prompts
        eng = PagedServingEngine(model, weights, max_batch=max_batch,
                                 max_len=max_len, block_size=bs,
                                 n_blocks=n_blocks, chunk_size=chunk)
        rids = [eng.submit(np.asarray(p, np.int32), max_new_tokens=new,
                           eos_id=eos) for _, p, new, eos in trace]
        eng.run_until_done(max_steps=50_000)
        bare = {i: list(eng.done[rid].tokens) for i, rid in enumerate(rids)}
        out["identical_tokens"] = out["identical_tokens"] and all(
            tokens_by_policy["ca"][i] == bare[i]
            for i in tokens_by_policy["ca"])
    out["speedup_tok_s"] = (out["ca_tok_per_s"]
                            / max(out["rr_tok_per_s"], 1e-9))
    out["p99_ratio"] = out["rr_p99_s"] / max(out["ca_p99_s"], 1e-9)

    # what the calibrated cost model would buy with an r-device budget
    cell = ShapeCell("cluster", "decode", max_len, max_batch)
    top = rank_cluster_topologies(cfg, cell, r, cm)[0]
    out["topology_replicas"] = top.n_replicas
    out["topology_data"] = top.plan.data
    out["topology_model"] = top.plan.model
    out["topology_pred_tok_s"] = top.predicted_tok_s
    return out


def run_sharded_decode_cell(params: Dict[str, Any], quick: bool = False
                            ) -> Dict[str, Any]:
    """Sharded intra-replica decode: the acceptance comparison plus the
    measured-vs-predicted step time per (data, model) factorization.

    Runs ``serve.sharded_check`` in a subprocess with a forced
    multi-device CPU host (the flag must precede jax init, so it cannot
    run in this process): a paged replica on each candidate mesh serves
    the 32-request acceptance trace and must be byte-identical to the
    single-device engine with the one-sync and donation invariants
    intact.  Reported per shape: measured wall-clock per step alongside
    ``rank_plans``' predicted step time — the measured CPU numbers
    validate the *mechanism*, the predictions carry the priced-TPU
    ordering the mesh choice is based on."""
    from repro.serve.sharded_check import parse_shapes, run_subprocess

    shapes = parse_shapes(params["shapes"])
    doc = run_subprocess(shapes, devices=int(params.get("devices", 8)),
                         n_req=8 if quick else 32)
    out: Dict[str, Any] = {
        "shapes": params["shapes"], "devices": doc["devices"],
        "n_req": doc["n_req"], "ref_step_s": doc["reference"]["step_s"],
        "identical_all": bool(doc["ok"]),
    }
    for s in doc["shapes"]:
        if s.get("skipped"):
            continue
        key = f"d{s['data']}m{s['model']}"
        out[f"{key}_step_s"] = s["step_s"]
        out[f"{key}_pred_step_s"] = s["predicted_step_s"]
        out[f"{key}_identical"] = bool(s["identical"])
        out[f"{key}_donated"] = bool(s["donated"])
        out[f"{key}_sync_ok"] = bool(s["sync_per_step_ok"])
        out[f"{key}_preemptions"] = s["preemptions"]
        out[f"{key}_compactions"] = s["compactions"]
    return out


register(Experiment(
    name="sharded_decode",
    description="sharded intra-replica decode: paged replicas on "
                "(data, model) meshes of a forced multi-device CPU host "
                "serve the acceptance trace byte-identically to the "
                "single-device engine, with measured vs cost-model-"
                "predicted step time per factorization",
    grid={"shapes": ("1x1,2x1,1x2,2x2",)},
    quick_grid={"shapes": ("1x1,1x2",)},
    runner=run_sharded_decode_cell,
    cost_per_cell_s=300.0,
    tags=("serve", "sharding", "costmodel"),
))


def run_chaos_serving_cell(params: Dict[str, Any], quick: bool = False
                           ) -> Dict[str, Any]:
    """One chaos drill: a seeded fault of ``params['fault']`` injected
    into a ``params['replicas']``-wide paged cluster under SimClock,
    with detection (heartbeats / straggler ceiling / integrity probe),
    router-level request recovery and restart-budget rejoin — then the
    recovery invariants checked against a fault-free twin of the same
    trace (see ``repro.serve.chaos.drill``).  ``ok`` summarizes the
    cell's gate: identical survivors, all requests accounted, zero lost
    tokens, zero leaked blocks, at least one fault actually detected —
    and, for ``crashloop``, the breaker quarantining the flapper."""
    from repro.serve.chaos.drill import run_chaos_drill
    fault = str(params["fault"])
    replicas = int(params["replicas"])
    out = run_chaos_drill(fault, replicas,
                          n_requests=8 if quick else 12)
    ok = (out["survivors_identical"] and out["all_accounted"]
          and out["tokens_lost"] == 0 and out["blocks_leaked"] == 0
          and out["failures"] >= 1)
    if fault == "crashloop":
        ok = ok and out["quarantined"]
    out["ok"] = bool(ok)
    return out


register(Experiment(
    name="chaos_serving",
    description="deterministic fault drills on the serving cluster: "
                "crash / hang / corrupt / crash-loop x replica count "
                "under SimClock — heartbeat+straggler+integrity "
                "detection, router request recovery with retry budget, "
                "brownout admission, restart-budget quarantine; gates "
                "byte-identical survivors, zero lost tokens, zero "
                "leaked blocks, drained router",
    grid={"fault": ("crash", "hang", "corrupt", "crashloop"),
          "replicas": (2, 3)},
    quick_grid={"fault": ("crash", "hang", "corrupt", "crashloop"),
                "replicas": (2,)},
    runner=run_chaos_serving_cell,
    cost_per_cell_s=30.0,
    tags=("serve", "cluster", "chaos"),
))


register(Experiment(
    name="traffic_scaling",
    description="multi-replica cluster under offered load x replica "
                "count: skewed trace served round-robin vs cost-aware "
                "placement on real arrays under the parallel-replica "
                "virtual clock — tok/s, p50/p99 latency, shed rate, "
                "reroutes, token conservation, chosen topology",
    grid={"replicas": (1, 2, 4), "load": (1.0, 2.0)},
    quick_grid={"replicas": (1, 2), "load": (2.0,)},
    runner=run_traffic_scaling_cell,
    cost_per_cell_s=60.0,
    tags=("serve", "cluster", "costmodel"),
))
