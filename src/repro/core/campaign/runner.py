"""The campaign scheduler: grid -> cells -> measured, persisted results.

Expands an experiment's (quick or full) grid, drops cells a previous run
already completed (resume-skip), checks backend compatibility, runs each
remaining cell through the experiment's runner, and records every
measurement through :class:`repro.core.campaign.results.ResultStore` —
flushed after each cell, so interruption costs at most one cell.

Cell failures are recorded (status=error) and the campaign continues; a
rerun retries failed cells but never re-measures successful ones unless
``force=True``.
"""
from __future__ import annotations

import time
import traceback
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Union

from repro.core.campaign import registry as reg
from repro.core.campaign.results import (STATUS_ERROR, STATUS_OK,
                                         ResultStore)
from repro.core.campaign.spec import Experiment

DEFAULT_RESULTS_DIR = Path("results") / "campaign"


@dataclass
class RunReport:
    """What one campaign invocation did (for CLIs and tests)."""
    experiment: str
    path: Optional[Path]
    total_cells: int = 0
    ran: int = 0
    skipped: int = 0
    failed: int = 0
    elapsed_s: float = 0.0
    cell_keys_run: list = field(default_factory=list)

    def summary(self) -> str:
        return (f"{self.experiment}: {self.ran} ran, {self.skipped} skipped "
                f"(already complete), {self.failed} failed, "
                f"{self.elapsed_s:.1f}s -> {self.path}")


def _current_backend() -> str:
    import jax
    return jax.default_backend()


def run(experiment: Union[str, Experiment], *,
        out_dir: Union[str, Path] = DEFAULT_RESULTS_DIR,
        quick: bool = False, force: bool = False,
        only: Optional[Dict[str, Any]] = None,
        store: Optional[ResultStore] = None,
        backend: Optional[str] = None,
        progress: Optional[Callable[[str], None]] = None) -> RunReport:
    """Run (or resume) one experiment campaign.

    ``only`` filters the grid to cells whose params match every given
    key/value (the CLI's ``--filter op=add``).  Passing ``store`` overrides
    the default ``<out_dir>/<name>.json`` location (used by tests and by
    ``tables.calibrate`` when it redirects results).
    """
    exp = reg.get(experiment) if isinstance(experiment, str) else experiment
    backend = backend or _current_backend()
    if not exp.supports_backend(backend):
        raise RuntimeError(
            f"experiment {exp.name!r} requires one of {exp.backends}, "
            f"current backend is {backend!r}")

    if store is None:
        store = ResultStore(Path(out_dir) / f"{exp.name}.json", exp.name,
                            backend=backend, quick=quick)
    doc_backend = store.doc.get("backend", "unknown")
    if doc_backend not in ("unknown", backend):
        if force:   # force re-measures everything, so relabel and proceed
            store.doc["backend"] = backend
        else:
            raise RuntimeError(
                f"{store.path} holds {doc_backend!r} measurements but the "
                f"current backend is {backend!r}; mixing backends in one "
                "result file would corrupt the calibration — rerun with "
                "--force to re-measure, or use a different --out-dir")
    report = RunReport(experiment=exp.name, path=store.path)
    say = progress or (lambda s: None)

    cells = exp.cells(quick=quick)
    if only:
        cells = [c for c in cells
                 if all(str(c.params.get(k)) == str(v)
                        for k, v in only.items())]
    report.total_cells = len(cells)
    # a quick run reuses any good cell; a full run only full-sweep cells
    # (quick mode shortens chains/shapes, so its numbers aren't full results)
    done = store.completed if quick else store.completed_full
    t0 = time.perf_counter()
    for cell in cells:
        if not force and cell.key in done:
            report.skipped += 1
            continue
        say(f"[{exp.name}] {cell.key}")
        t_cell = time.perf_counter()
        try:
            metrics = exp.runner(dict(cell.params), quick=quick)
            store.record(cell.key, dict(cell.params), metrics,
                         elapsed_s=time.perf_counter() - t_cell,
                         status=STATUS_OK, quick=quick)
            report.ran += 1
            report.cell_keys_run.append(cell.key)
        except Exception as e:  # record + continue: one bad cell must not
            store.record(cell.key, dict(cell.params), {},   # kill a campaign
                         elapsed_s=time.perf_counter() - t_cell,
                         status=STATUS_ERROR,
                         error=f"{type(e).__name__}: {e}\n"
                               f"{traceback.format_exc(limit=3)}",
                         quick=quick)
            report.failed += 1
            say(f"[{exp.name}] {cell.key} FAILED: {e}")
    report.elapsed_s = time.perf_counter() - t0
    store.write_csv()
    return report


def run_many(names, **kwargs) -> Dict[str, RunReport]:
    """Run several experiments back to back (the `calibrate` path)."""
    return {n: run(n, **kwargs) for n in names}
