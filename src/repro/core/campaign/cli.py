"""Command-line front end: ``python -m repro.core.campaign <cmd> ...``.

  list                         show registered experiments + cost estimates
  run <experiment> [...]       run/resume one campaign (or ``all``)
  report <result.json ...>     regenerate paper-style tables from files alone
  calibrate [...]              run the calibration campaigns and emit a
                               calibration table for the perf model
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
from pathlib import Path

from repro.core.campaign import registry as reg
from repro.core.campaign import report as report_mod
from repro.core.campaign import runner as runner_mod
from repro.core.campaign.results import load_results


def _parse_filters(pairs):
    out = {}
    for p in pairs or []:
        if "=" not in p:
            raise SystemExit(f"--filter expects key=value, got {p!r}")
        k, v = p.split("=", 1)
        out[k] = v
    return out


def cmd_list(args) -> int:
    quick = args.quick
    print(f"{'experiment':24s} {'cells':>6s} {'est_cost':>9s}  description")
    for name in reg.names():
        exp = reg.get(name)
        n = len(exp.cells(quick=quick))
        print(f"{name:24s} {n:6d} {exp.estimated_cost_s(quick):8.0f}s"
              f"  {exp.description}")
    return 0


def cmd_run(args) -> int:
    names = reg.names() if args.experiment == "all" else [args.experiment]
    for name in names:
        if name not in reg.REGISTRY:
            raise SystemExit(f"unknown experiment {name!r}; available: "
                             f"{', '.join(reg.names())} (or 'all')")
    rc = 0
    for name in names:
        rep = runner_mod.run(
            name, out_dir=args.out_dir, quick=args.quick, force=args.force,
            only=_parse_filters(args.filter),
            progress=print if args.verbose else None)
        print(rep.summary())
        rc = rc or (1 if rep.failed else 0)
    return rc


def cmd_report(args) -> int:
    report_mod.render_result_files(args.results)
    return 0


def cmd_calibrate(args) -> int:
    from repro.core.microbench import tables
    table = tables.calibrate(out_path=args.out, quick=args.quick,
                             results_dir=args.out_dir)
    if not args.out:
        json.dump(table, sys.stdout, indent=1)
        print()
    else:
        print(f"wrote {args.out} "
              f"({len(table['ops'])} op rows, {len(table['mxu'])} mxu rows)")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.campaign",
        description="unified microbenchmark campaign runner")
    sub = p.add_subparsers(dest="cmd", required=True)

    lp = sub.add_parser("list", help="show registered experiments")
    lp.add_argument("--quick", action="store_true",
                    help="size estimates for the --quick grids")
    lp.set_defaults(fn=cmd_list)

    rp = sub.add_parser("run", help="run/resume one experiment (or 'all')")
    rp.add_argument("experiment")
    rp.add_argument("--quick", action="store_true",
                    help="reduced grid + shorter sweeps (CI smoke mode)")
    rp.add_argument("--force", action="store_true",
                    help="re-measure cells even if already completed")
    rp.add_argument("--out-dir", default=str(runner_mod.DEFAULT_RESULTS_DIR),
                    help="result directory (default: results/campaign)")
    rp.add_argument("--filter", action="append", metavar="KEY=VALUE",
                    help="restrict the grid (repeatable), e.g. --filter op=add")
    rp.add_argument("--verbose", "-v", action="store_true")
    rp.set_defaults(fn=cmd_run)

    pp = sub.add_parser("report",
                        help="regenerate paper tables from result files")
    pp.add_argument("results", nargs="+", type=Path)
    pp.set_defaults(fn=cmd_report)

    cp = sub.add_parser("calibrate",
                        help="run calibration campaigns, emit a latency table")
    cp.add_argument("--quick", action="store_true")
    cp.add_argument("--out", default=None, help="calibration table path")
    cp.add_argument("--out-dir", default=str(runner_mod.DEFAULT_RESULTS_DIR))
    cp.set_defaults(fn=cmd_calibrate)
    return p


def main(argv=None) -> int:
    # die quietly when piped into `head`/`grep -q` instead of tracebacking
    if hasattr(signal, "SIGPIPE"):
        signal.signal(signal.SIGPIPE, signal.SIG_DFL)
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
