from repro.core.isa import hlo_census  # noqa
