"""Instruction-level census of compiled HLO — the TPU analogue of the paper's
dynamic SASS trace.

The paper verifies every PTX instruction's mapping to SASS *at runtime*
because the compiler may fuse/split/re-schedule.  On TPU the portable IR is
StableHLO and the "hardware ISA" is the post-SPMD, post-fusion optimized HLO;
this module parses ``compiled.as_text()`` into a per-instruction census:

  * matmul FLOPs (dot/convolution), with WHILE-LOOP TRIP COUNTS multiplied
    through (lax.scan lowers to while; XLA's HloCostAnalysis counts loop
    bodies once, which under-counts a 60-layer scanned transformer 60x).
    Trip counts come from XLA's own ``backend_config known_trip_count``;
  * HBM traffic estimate: post-fusion, each top-level op's operand+result
    bytes approximate its HBM footprint (fusion internals stay in
    VMEM/registers, so they are intentionally NOT counted);
  * collective wire bytes per op kind (all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute), with ring-algorithm
    (n-1)/n factors and replica-group sizes parsed from the op;
  * an op-kind histogram (the "ISA mapping" table of the paper).

Everything is derived from text parsing only — no device execution — so it
works identically for the 512-device dry-run artifacts.  Optimized HLO
references operands by NAME only, so a module-wide symbol table (op name ->
result type) resolves operand shapes.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+) = (.*)$")
_KIND_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_REPLICA_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPLICA_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    line: str

    @property
    def result_bytes(self) -> int:
        return shape_bytes(self.result_type)

    def operand_names(self) -> List[str]:
        lp = self.line.find(self.kind + "(")
        if lp < 0:
            return []
        start = lp + len(self.kind) + 1
        depth = 1
        for i in range(start, len(self.line)):
            ch = self.line[i]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = self.line[start:i]
                    break
        else:
            args = self.line[start:]
        return re.findall(r"%([\w\.\-]+)", args)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)
    is_fusion: bool = False


def _parse_op(line: str) -> Optional[Op]:
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    km = _KIND_RE.search(rhs)
    if not km:
        return None
    kind = km.group(1)
    result_type = rhs[:km.start()].strip()
    return Op(name, kind, result_type, line.strip())


def parse_module(text: str) -> Tuple[Dict[str, Computation], Dict[str, str]]:
    """Returns (computations, symbol table name->result type)."""
    comps: Dict[str, Computation] = {}
    symtab: Dict[str, str] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith(("//", "#")):
            continue
        if (not line.startswith((" ", "\t"))) and "->" in line \
                and stripped.endswith("{"):
            head = stripped
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.lstrip("%").split("(")[0].split(" ")[0].strip()
            if name:
                cur = Computation(name, is_fusion="fused" in name)
                comps[name] = cur
            continue
        op = _parse_op(line)
        if op and cur is not None:
            cur.ops.append(op)
            symtab[op.name] = op.result_type
    return comps, symtab


def _operand_bytes(op: Op, symtab) -> int:
    return sum(shape_bytes(symtab.get(n, "")) for n in op.operand_names())


def _dot_flops(op: Op, symtab) -> int:
    """2 * prod(result_dims) * contracted_size (batch dims cancel)."""
    res_elems = shape_elems(op.result_type)
    names = op.operand_names()
    if not names:
        return 0
    lhs_type = symtab.get(names[0], "")
    mdims = _SHAPE_RE.search(lhs_type)
    if not mdims:
        return 0
    lhs_dims = [int(d) for d in mdims.group(2).split(",") if d]
    mcontract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    csize = 1
    if mcontract and mcontract.group(1):
        for idx in mcontract.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                csize *= lhs_dims[i]
    return 2 * res_elems * csize


def _collective_group_size(line: str, default: int) -> int:
    m = _REPLICA_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _collective_wire_bytes(kind: str, op: Op, symtab,
                           n_devices: int) -> float:
    """Per-device wire bytes for one execution of a collective, assuming ring
    algorithms (the v5e ICI topology is a torus of rings)."""
    g = max(_collective_group_size(op.line, n_devices), 1)
    rb = op.result_bytes
    if kind == "all-reduce":
        return 2.0 * rb * (g - 1) / g
    if kind == "all-gather":
        return rb * (g - 1) / g
    if kind == "reduce-scatter":
        ob = _operand_bytes(op, symtab)
        return (ob if ob else rb * g) * (g - 1) / g
    if kind == "all-to-all":
        return rb * (g - 1) / g
    if kind in ("collective-permute", "collective-broadcast"):
        return float(rb)
    return 0.0


_MEM_SKIP = {
    # ops that don't move HBM bytes themselves (control / aliasing / tuples)
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "copy-start", "copy-done",
}


def _trip_counts_and_callers(comps):
    callers: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    trips: Dict[str, int] = {}
    for cname, comp in comps.items():
        for op in comp.ops:
            if op.kind == "while":
                mbody = re.search(r"body=%?([\w\.\-]+)", op.line)
                mcond = re.search(r"condition=%?([\w\.\-]+)", op.line)
                mtc = _TRIP_RE.search(op.line)
                trip = int(mtc.group(1)) if mtc else 1
                if mbody:
                    callers[mbody.group(1)].append((cname, float(trip)))
                    trips[mbody.group(1)] = trip
                if mcond:
                    callers[mcond.group(1)].append((cname, float(trip) + 1))
            elif op.kind in ("call", "conditional", "fusion"):
                for m in re.finditer(
                        r"(?:to_apply|branch_computations|calls)="
                        r"\{?%?([\w\.\-]+(?:, ?%?[\w\.\-]+)*)\}?", op.line):
                    for target in re.split(r",\s*%?", m.group(1)):
                        callers[target].append((cname, 1.0))
    return callers, trips


def census(text: str, n_devices: int = 1) -> Dict:
    """Full instruction census of an optimized HLO module.

    Returns dict with: flops, hbm_bytes, collective_bytes (per kind + total),
    op_histogram {kind: weighted count}, while_trips {computation: trip}.
    All numbers are PER DEVICE (SPMD modules are per-device programs).
    """
    comps, symtab = parse_module(text)
    callers, trips = _trip_counts_and_callers(comps)
    memo: Dict[str, float] = {}

    def resolve(name: str, depth=0) -> float:
        if name in memo:
            return memo[name]
        if depth > 60 or name not in comps:
            return 1.0
        sites = callers.get(name)
        if not sites:
            memo[name] = 1.0
            return 1.0
        memo[name] = 1.0  # break cycles
        total = 0.0
        for caller, weight in sites:
            total += weight * resolve(caller, depth + 1)
        memo[name] = max(total, 1.0)
        return memo[name]

    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    coll_adj = defaultdict(float)
    hist: Dict[str, float] = defaultdict(float)

    def _tpu_adjusted(kind: str, op: Op, wire: float) -> float:
        """XLA:CPU legalizes bf16 dots/gathers to f32, so the SPMD collective
        on their outputs is measured at f32 width; on the TPU target the same
        value is bf16.  Halve those (and only those) — identified by an f32
        result whose metadata op_name points at a dot/gather/scatter source.
        Optimizer/grad-accumulation reductions are genuinely f32 and keep
        full price."""
        if "f32[" not in op.result_type.replace(" ", ""):
            return wire
        m = re.search(r'op_name="([^"]*)"', op.line)
        src = m.group(1) if m else ""
        if any(t in src for t in ("dot_general", "/gather", "scatter-add",
                                  "_take")):
            return wire * 0.5
        return wire

    def _fusion_operand_bytes(op: Op) -> int:
        """Operand bytes of a fusion op, charging parameters that the fusion
        internally only SLICES/GATHERS at their sliced size (a scan body
        fused with its layer-stack dynamic-slice reads one layer per trip,
        not the whole stack)."""
        m = re.search(r"calls=%?([\w\.\-]+)", op.line)
        names = op.operand_names()
        if not m or m.group(1) not in comps:
            return sum(shape_bytes(symtab.get(n, "")) for n in names)
        fc = comps[m.group(1)]
        params = {}
        for fop in fc.ops:
            if fop.kind == "parameter":
                mi = re.search(r"parameter\((\d+)\)", fop.line)
                if mi:
                    params[int(mi.group(1))] = fop.name
        total = 0
        slicing = {"dynamic-slice", "gather", "slice",
                   "dynamic-update-slice"}
        for i, n in enumerate(names):
            full = shape_bytes(symtab.get(n, ""))
            pname = params.get(i)
            if pname is None:
                total += full
                continue
            consumers = [fop for fop in fc.ops
                         if pname in fop.operand_names()]
            if consumers and all(c.kind in slicing for c in consumers):
                total += sum(shape_bytes(c.result_type) for c in consumers)
            else:
                total += full
        return total

    for cname, comp in comps.items():
        if comp.is_fusion:
            continue  # internals are VMEM-resident; the fusion op is counted
        w = resolve(cname)
        for op in comp.ops:
            hist[op.kind] += w
            if op.kind in ("dot", "convolution"):
                flops += w * _dot_flops(op, symtab)
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in COLLECTIVES:
                wire = _collective_wire_bytes(base, op, symtab, n_devices)
                coll[base] += w * wire
                coll_adj[base] += w * _tpu_adjusted(base, op, wire)
            if op.kind not in _MEM_SKIP and not op.kind.endswith("-done"):
                names = op.operand_names()
                if op.kind == "fusion":
                    hbm += w * (_fusion_operand_bytes(op) + op.result_bytes)
                elif op.kind in ("dynamic-slice", "slice"):
                    # reads only the slice (scan reads one layer per trip)
                    hbm += w * 2 * op.result_bytes
                elif op.kind == "gather":
                    idx = shape_bytes(symtab.get(names[1], "")) \
                        if len(names) > 1 else 0
                    hbm += w * (2 * op.result_bytes + idx)
                elif op.kind in ("dynamic-update-slice",):
                    upd = shape_bytes(symtab.get(names[1], "")) \
                        if len(names) > 1 else 0
                    hbm += w * 2 * upd  # touches only the updated slice
                elif op.kind == "scatter":
                    upd = shape_bytes(symtab.get(names[-1], "")) \
                        if names else 0
                    hbm += w * (2 * upd + op.result_bytes)
                else:
                    hbm += w * (_operand_bytes(op, symtab) + op.result_bytes)

    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": dict(coll),
        "collective_bytes_total": float(sum(coll.values())),
        "collective_bytes_total_tpu": float(sum(coll_adj.values())),
        "op_histogram": dict(sorted(hist.items(), key=lambda kv: -kv[1])),
        "while_trips": trips,
        "n_computations": len(comps),
    }


def collective_table(text: str, n_devices: int = 1) -> List[Dict]:
    """Itemized collectives (op name, kind, group size, bytes)."""
    comps, symtab = parse_module(text)
    out = []
    for cname, comp in comps.items():
        for op in comp.ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in COLLECTIVES:
                out.append({
                    "computation": cname, "op": op.name, "kind": base,
                    "result_bytes": op.result_bytes,
                    "wire_bytes": _collective_wire_bytes(base, op, symtab,
                                                         n_devices),
                    "group": _collective_group_size(op.line, n_devices)})
    return out


def op_mapping_table(stablehlo_text: str, optimized_text: str) -> Dict:
    """The PTX->SASS analogue: op-kind histograms of the portable IR vs the
    optimized per-device program, plus the fusion ratio."""
    def hist_of(text, stable=False):
        h = defaultdict(int)
        if stable:
            for m in re.finditer(r"stablehlo\.(\w+)", text):
                h[m.group(1)] += 1
        else:
            comps, _ = parse_module(text)
            for c in comps.values():
                for op in c.ops:
                    h[op.kind] += 1
        return dict(sorted(h.items(), key=lambda kv: -kv[1]))

    src = hist_of(stablehlo_text, stable="stablehlo" in stablehlo_text)
    dst = hist_of(optimized_text)
    return {"stablehlo": src, "optimized": dst,
            "n_source_ops": sum(src.values()),
            "n_optimized_ops": sum(dst.values())}
