"""Unified cost-model subsystem: the paper's measured tables, operational.

Three explicit layers — instruction (per-op CPI, dependent/independent),
memory (hierarchy latencies + streaming bandwidth), MXU (shape/dtype
throughput surface) — normalized from any calibration source
(``calibration``), composed by :class:`CostModel` (``model``) behind one
``predict(census, spec)`` API, with analytic census/byte stand-ins for
never-compiled candidates (``analytic``, imported lazily — it needs jax).

CLI: ``python -m repro.core.costmodel --calibration ampere_a100 --demo``.
"""
from repro.core.costmodel.calibration import (CALIB_DIR, Calibration,  # noqa: F401
                                              InstructionEntry, MemoryLevel,
                                              MXUPoint, load_calibration)
from repro.core.costmodel.instruction import (HLO_TO_TABLE,  # noqa: F401
                                              InstructionLayer, IssueCost)
from repro.core.costmodel.memory import MemoryLayer  # noqa: F401
from repro.core.costmodel.model import (CostModel, Prediction,  # noqa: F401
                                        prediction_error_rows,
                                        prediction_error_summary,
                                        save_calibration,
                                        validate_against_paper)
from repro.core.costmodel.mxu import MXULayer  # noqa: F401
