"""The composed cost model: instruction + memory + MXU layers behind one
``CostModel.predict(census, spec)`` API.

This subsumes the old ``perfmodel.predictor`` (which hardcoded an HLO->table
mapping over a raw dict) and the per-term arithmetic of
``perfmodel.roofline``: given an instruction census of a compiled module
(``repro.core.isa.hlo_census``) and a normalized calibration, the predicted
per-device step time is

    t = max(compute, memory, collective) + issue_overhead

with compute priced by the MXU throughput surface, memory by the hierarchy
layer's streaming bandwidth, collectives by the hardware-spec ICI links and
the issue term by the per-op CPI table — including an explicit record of
census ops the table could NOT price (``Prediction.defaulted_ops``), so
model gaps are visible instead of silently costed as ``add``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Union

from repro.core.costmodel.calibration import (Calibration, canon_dtype,
                                              load_calibration)
from repro.core.costmodel.instruction import InstructionLayer, IssueCost
from repro.core.costmodel.memory import MemoryLayer
from repro.core.costmodel.mxu import MXULayer
from repro.core.perfmodel.hardware import SPECS, TPU_V5E, HardwareSpec

# calibration "hardware" strings -> HardwareSpec names
_HW_ALIASES = {
    "nvidia-a100-40g": "a100-40g",
    "tpu-v5e": "tpu-v5e",
}


@dataclass
class Prediction:
    """One priced step: the three roofline terms, the instruction-issue
    overhead, and the census-coverage record."""
    compute_s: float
    memory_s: float
    collective_s: float
    issue_overhead_s: float
    step_s: float
    bottleneck: str
    dtype: str = "bf16"
    hw: str = ""
    calibration: str = ""
    # census op kinds the instruction table could not price (kind -> count)
    defaulted_ops: Dict[str, float] = field(default_factory=dict)
    mapped_op_count: float = 0.0

    @property
    def defaulted_op_count(self) -> float:
        return float(sum(self.defaulted_ops.values()))

    def summary(self) -> str:
        return (f"step={self.step_s:.3e}s ({self.bottleneck}-bound; "
                f"compute={self.compute_s:.3e} memory={self.memory_s:.3e} "
                f"collective={self.collective_s:.3e} "
                f"issue={self.issue_overhead_s:.3e}) "
                f"defaulted_ops={self.defaulted_op_count:.0f}"
                f"/{self.defaulted_op_count + self.mapped_op_count:.0f}")

    def table_row(self) -> Dict[str, Any]:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "issue_overhead_s": self.issue_overhead_s,
            "step_s": self.step_s, "bottleneck": self.bottleneck,
            "defaulted_op_count": self.defaulted_op_count,
        }


def _resolve_hw(cal: Calibration,
                hw: Optional[HardwareSpec]) -> HardwareSpec:
    if hw is not None:
        return hw
    name = _HW_ALIASES.get(cal.hardware, cal.hardware)
    return SPECS.get(name, TPU_V5E)


class CostModel:
    """Calibrated three-layer performance model.

    Instruction (CPI table + issue cost), memory (bandwidth + per-level
    latency) and MXU (per-dtype peaks + measured tile points) layers over
    one :class:`Calibration`.  Instances are cheap, immutable-by-
    convention views of their calibration: the serving engines swap in a
    replacement live (``engine.set_cost_model``) when telemetry detects
    prediction drift, rather than mutating a model in place.
    """

    def __init__(self, cal: Calibration,
                 hw: Optional[HardwareSpec] = None,
                 issue_cycles: float = 12.0):
        self.cal = cal
        self.hw = _resolve_hw(cal, hw)
        self.instructions = InstructionLayer(cal, issue_cycles=issue_cycles)
        self.memory = MemoryLayer(cal, self.hw)
        self.mxu = MXULayer(cal, self.hw)

    # ----- constructors ------------------------------------------------------

    @classmethod
    def from_named(cls, name: "str | Path" = "tpu_v5e",
                   hw: Optional[HardwareSpec] = None) -> "CostModel":
        """Shipped calibration name, JSON path, or campaign results dir."""
        return cls(load_calibration(name), hw=hw)

    @classmethod
    def from_table(cls, table: Mapping[str, Any],
                   hw: Optional[HardwareSpec] = None,
                   name: str = "") -> "CostModel":
        """Any supported calibration-table dict (see ``Calibration``)."""
        return cls(Calibration.from_dict(dict(table), name=name), hw=hw)

    @classmethod
    def from_hardware(cls, hw: HardwareSpec) -> "CostModel":
        """Spec-only model (no measured tables): the pure roofline view."""
        cal = Calibration(name=hw.name, hardware=hw.name,
                          clock_hz=hw.clock_hz or 1e9,
                          bandwidth_bps=hw.hbm_bandwidth,
                          mxu_peaks={"bf16": hw.peak_flops_bf16,
                                     "f32": min(hw.peak_flops_f32,
                                                hw.peak_flops_bf16)})
        return cls(cal, hw=hw)

    # ----- prediction --------------------------------------------------------

    def predict(self, census: Mapping[str, Any],
                spec: Optional[HardwareSpec] = None, *,
                mem_bytes: Optional[float] = None,
                dtype: str = "bf16",
                dependent: bool = False,
                mxu_shape: Optional[tuple] = None) -> Prediction:
        """Price one per-device step from an instruction census.

        ``census`` is the dict from ``hlo_census.census`` (or an analytic
        stand-in with the same keys).  ``mem_bytes`` overrides the census
        HBM-byte estimate with an analytic lower bound when available;
        ``spec`` overrides the hardware the collective term prices against;
        ``mxu_shape`` routes the compute term through a specific measured
        (m,n,k) tile point when the calibration has one (the autotuner's
        per-candidate tile) instead of the dtype peak.
        """
        hw = spec or self.hw
        flops = float(census.get("flops", 0.0))
        compute_s = self.mxu.time_for_flops(flops, dtype=dtype,
                                            shape=mxu_shape)
        nbytes = float(mem_bytes if mem_bytes is not None
                       else census.get("hbm_bytes", 0.0))
        memory_s = self.memory.transfer_seconds(nbytes)
        # grid under-utilization: an analytic census may carry the launch
        # grid's cell count ("grid_cells"); with fewer independent cells
        # than the chip's grid lanes (hw.n_cores) the idle lanes cannot
        # stream, so the effective bandwidth shrinks by the utilization
        # ratio.  HLO censuses omit the key (cells = 0) and price
        # unchanged.  This is the term that makes split-KV flash-decoding
        # win at long context / small batch: more splits -> more cells ->
        # higher utilization, until the partial-row traffic dominates.
        cells = float(census.get("grid_cells", 0.0))
        lanes = float(getattr(hw, "n_cores", 1) or 1)
        if cells > 0.0 and cells < lanes:
            memory_s *= lanes / cells
        coll_b = float(census.get("collective_bytes_total_tpu",
                                  census.get("collective_bytes_total", 0.0)))
        coll_bw = hw.ici_link_bandwidth * max(hw.ici_links, 1)
        collective_s = coll_b / coll_bw if coll_bw else 0.0
        issue: IssueCost = self.instructions.price_histogram(
            census.get("op_histogram", {}) or {}, dtype=canon_dtype(dtype),
            dependent=dependent)
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
        bottleneck = max(terms, key=terms.get)
        return Prediction(
            compute_s=compute_s, memory_s=memory_s,
            collective_s=collective_s, issue_overhead_s=issue.seconds,
            step_s=max(terms.values()) + issue.seconds,
            bottleneck=bottleneck, dtype=dtype, hw=hw.name,
            calibration=self.cal.name,
            defaulted_ops=dict(issue.defaulted_ops),
            mapped_op_count=issue.mapped_count)

    def predict_compiled(self, compiled_text: str, n_devices: int = 1,
                         **kw) -> Prediction:
        """Census a compiled HLO module's text and price it."""
        from repro.core.isa.hlo_census import census as run_census
        return self.predict(run_census(compiled_text, n_devices), **kw)

    def predict_fn(self, fn, *args, n_devices: int = 1, **kw) -> Prediction:
        """Lower+compile a jax callable on example args and price it.

        NOTE: this pays one AOT compile that jit's dispatch cache does NOT
        reuse.  Callers on a hot path should compile once themselves with
        ``jax.jit(fn).lower(*args).compile()``, price the executable via
        ``predict_compiled(compiled.as_text())``, and then CALL that same
        executable (what ``train.loop`` and ``serve.engine`` do)."""
        import jax
        jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
        text = jitted.lower(*args).compile().as_text()
        return self.predict_compiled(text, n_devices=n_devices, **kw)


# ---------------------------------------------------------------------------
# validation: round-trip the calibration through the layers (the
# prediction-error fixture) + the paper's own consistency relations
# ---------------------------------------------------------------------------

def prediction_error_rows(model: CostModel) -> List[Dict[str, Any]]:
    """Predict every recorded calibration row back through the layer stack
    and report the relative error — the loader/normalization round-trip the
    acceptance fixture checks (must stay within 10%).

    Rows: {name, predicted, recorded, unit, err_pct}.
    """
    rows: List[Dict[str, Any]] = []
    cal = model.cal

    def add(name, predicted, recorded, unit):
        err = (abs(predicted - recorded) / abs(recorded) * 100.0
               if recorded else (100.0 if predicted else 0.0))
        rows.append({"name": name, "predicted": float(predicted),
                     "recorded": float(recorded), "unit": unit,
                     "err_pct": float(err)})

    for e in cal.instructions.values():
        got = model.instructions.cycles(e.op, e.dtype, dependent=True)
        add(f"instr/{e.source_key or e.key}.dep", got or 0.0,
            e.dependent_cycles, "cycles")
        got = model.instructions.cycles(e.op, e.dtype, dependent=False)
        add(f"instr/{e.source_key or e.key}.ind", got or 0.0,
            e.independent_cycles, "cycles")
    for lvl in cal.memory_levels:
        add(f"memory/{lvl.source_key or lvl.name}",
            model.memory.access_latency_ns(lvl.capacity_bytes),
            lvl.latency_ns, "ns")
    if cal.bandwidth_bps:
        gib = 2**30
        add("memory/stream_1GiB",
            model.memory.transfer_seconds(gib), gib / cal.bandwidth_bps, "s")
    for p in cal.mxu_points:
        if p.flops_per_s <= 0 or p.shape is None:
            continue
        got = model.mxu.throughput(p.dtype, p.shape, dependent=p.dependent)
        add(f"mxu/{p.source_key or p.dtype}", got, p.flops_per_s, "FLOP/s")
    return rows


def prediction_error_summary(rows: List[Dict[str, Any]]) -> Dict[str, Any]:
    errs = [r["err_pct"] for r in rows]
    return {"rows": len(rows),
            "max_err_pct": max(errs, default=0.0),
            "mean_err_pct": sum(errs) / len(errs) if errs else 0.0}


def validate_against_paper(table: Mapping[str, Any]) -> Dict[str, bool]:
    """The paper's own consistency relations over the raw A100 table:
    SASS expansion x per-SASS cycles == WMMA cycles; dependent CPI >=
    independent CPI; >=3-chain convergence (run as unit tests)."""
    checks: Dict[str, bool] = {}
    tc = table["tensor_core"]
    for k, v in tc.items():
        n = int(v["sass"].split("*")[0])
        checks[f"tc:{k}"] = (n * v["sass_cycles_each"] == v["cycles"]) or \
            (v["cycles"] <= n * v["sass_cycles_each"] + 8)
    for k, v in table["dependent_vs_independent"].items():
        checks[f"dep>=ind:{k}"] = v["dependent"] >= v["independent"]
    conv = table["cpi_convergence"]
    checks["chain_convergence"] = \
        conv["1"] >= conv["2"] >= conv["3"] == conv["4"]
    return checks


def save_calibration(cal: Calibration,
                     out_path: Union[str, Path]) -> Path:
    """Persist a calibration in the canonical round-trip format, defaulting
    artifacts under ``results/`` (output hygiene: generated JSON is never
    tracked)."""
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(cal.to_dict(), indent=1))
    return out
