"""Instruction layer: price an HLO op histogram with per-op CPI tables.

This is the paper's Tables I/II applied as a simulator input: every
top-level op in the compiled module costs at least an issue slot, and ops
whose table row is known cost their measured CPI (dependent-chain cycles by
default — the conservative latency number; pass ``dependent=False`` for the
throughput view of wide independent streams).

HLO kinds with NO genuine arithmetic counterpart in the table (layout ops,
data movement, RNG, ...) are NOT silently priced as ``add`` — they are
tracked as *defaulted* and surfaced on the returned breakdown so census
gaps stay visible (the old ``predictor._HLO_TO_TABLE`` silently mapped ~20
such kinds to ``add``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.costmodel.calibration import Calibration, InstructionEntry

# HLO op kind -> table op: only kinds with a real arithmetic counterpart.
# Everything else is defaulted (priced at the issue-slot floor) and REPORTED.
HLO_TO_TABLE: Dict[str, str] = {
    "add": "add", "subtract": "sub", "multiply": "mul", "divide": "div",
    "maximum": "max", "minimum": "min", "abs": "abs", "negate": "sub",
    "and": "and", "or": "and", "xor": "xor", "not": "and",
    "exponential": "exp", "exponential-minus-one": "exp",
    "log": "log", "log-plus-one": "log", "tanh": "tanh",
    "rsqrt": "rsqrt", "sqrt": "sqrt", "cbrt": "rsqrt",
    "sine": "sin", "cosine": "sin", "logistic": "sigmoid",
    "power": "exp", "remainder": "rem", "atan2": "tanh", "erf": "tanh",
    "select": "select", "clamp": "select", "sign": "select",
    "compare": "compare", "is-finite": "compare",
    "shift-left": "shift", "shift-right-logical": "shift",
    "shift-right-arithmetic": "shift", "popcnt": "popc", "clz": "clz",
    "fusion": "fma", "map": "fma",
}

# table-op fallback chain when a calibration lacks a row (e.g. the v5e table
# has no 'compare'/'shift'; the nearest same-pipeline op prices it instead)
_OP_FALLBACK = {"compare": "select", "shift": "and", "sub": "add",
                "rem": "div"}

# kinds priced by the MXU layer's compute term: they still take an issue
# slot here but are NOT census gaps (no CPI row expected)
_MXU_PRICED = {"dot", "convolution"}


@dataclass
class IssueCost:
    """Breakdown of one histogram pricing pass."""
    seconds: float
    cycles: float
    mapped_cycles: float
    defaulted_cycles: float
    # HLO kind -> weighted count that fell through to the issue-slot floor
    defaulted_ops: Dict[str, float] = field(default_factory=dict)
    mapped_ops: Dict[str, float] = field(default_factory=dict)

    @property
    def defaulted_count(self) -> float:
        return float(sum(self.defaulted_ops.values()))

    @property
    def mapped_count(self) -> float:
        return float(sum(self.mapped_ops.values()))


class InstructionLayer:
    """Per-op CPI lookups over a normalized calibration."""

    def __init__(self, cal: Calibration, issue_cycles: float = 12.0):
        self.entries: Dict[str, InstructionEntry] = dict(cal.instructions)
        self.clock_hz = cal.clock_hz or 1e9
        self.issue_cycles = issue_cycles
        self._by_op: Dict[str, InstructionEntry] = {}
        for e in cal.instructions.values():
            # per-op fallback row, f32 preferred
            if e.op not in self._by_op or e.dtype == "f32":
                self._by_op[e.op] = e

    def entry(self, op: str, dtype: str = "f32"
              ) -> Optional[InstructionEntry]:
        e = self.entries.get(f"{op}.{dtype}") or self._by_op.get(op)
        if e is None and op in _OP_FALLBACK:
            return self.entry(_OP_FALLBACK[op], dtype)
        return e

    def cycles(self, op: str, dtype: str = "f32",
               dependent: bool = True) -> Optional[float]:
        e = self.entry(op, dtype)
        if e is None:
            return None
        return e.dependent_cycles if dependent else e.independent_cycles

    def seconds(self, op: str, dtype: str = "f32",
                dependent: bool = True) -> Optional[float]:
        c = self.cycles(op, dtype, dependent)
        return None if c is None else c / self.clock_hz

    def price_histogram(self, op_histogram: Dict[str, float],
                        dtype: str = "f32",
                        dependent: bool = True) -> IssueCost:
        """Total issue cost of an op-kind histogram (census
        ``op_histogram``).  Mapped kinds cost ``max(issue floor, CPI)``;
        unmapped kinds cost the issue floor and are recorded as defaulted."""
        mapped_cyc = defaulted_cyc = 0.0
        defaulted: Dict[str, float] = {}
        mapped: Dict[str, float] = {}
        for kind, count in op_histogram.items():
            table_op = HLO_TO_TABLE.get(kind)
            cpi = self.cycles(table_op, dtype, dependent) \
                if table_op else None
            if cpi is None and kind in _MXU_PRICED:
                cpi = self.issue_cycles   # compute term owns the real cost
            if cpi is None:
                defaulted[kind] = defaulted.get(kind, 0.0) + count
                defaulted_cyc += count * self.issue_cycles
            else:
                mapped[kind] = mapped.get(kind, 0.0) + count
                mapped_cyc += count * max(self.issue_cycles, cpi)
        total = mapped_cyc + defaulted_cyc
        return IssueCost(seconds=total / self.clock_hz, cycles=total,
                         mapped_cycles=mapped_cyc,
                         defaulted_cycles=defaulted_cyc,
                         defaulted_ops=defaulted, mapped_ops=mapped)
