"""Analytic (first-principles) census/byte models for steps that were never
compiled — the inputs plan ranking and the dry-run roofline feed into
``CostModel.predict`` when no HLO text exists for a candidate.

The byte models moved here from ``perfmodel.roofline`` (which now imports
them back for compatibility) and gained an explicit ``n_model`` parameter so
sharding-plan candidates with different model-parallel widths price
differently instead of assuming the production 16-way split.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.models.zoo import count_active_params, count_params


def _param_bytes(cfg) -> int:
    return count_params(cfg) * 4          # f32 master weights


def cache_bytes(cfg, cell) -> float:
    """Decode-state bytes for one shape cell (KV / SSM / RWKV / MLA)."""
    B, S, L = cell.global_batch, cell.seq_len, cfg.n_layers
    if cfg.rwkv:
        H = cfg.d_model // cfg.rwkv.head_dim
        return L * B * (H * cfg.rwkv.head_dim ** 2 * 4 + 2 * cfg.d_model * 2)
    if cfg.mla:
        return L * B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
    kv = L * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    if cfg.ssm:   # hybrid: + per-layer ssm state
        kv += L * B * cfg.d_model * cfg.ssm.state_dim * 4
    if cfg.encdec:
        kv = cfg.encdec.n_dec_layers * B * S * cfg.n_kv_heads \
            * cfg.head_dim * 2 * 2 * 2   # self + cross
    return kv


def analytic_train_bytes(cfg, cell, n_devices: int, accum: int,
                         n_model: int = 16) -> float:
    """Per-device HBM bytes for one train step (lower-bound model)."""
    P = _param_bytes(cfg)
    n_model = max(min(n_model, n_devices), 1)
    n_data = max(n_devices // n_model, 1)
    P_dev = P / n_devices                 # FSDP+TP fully sharded storage
    P_stream = P / n_model                # gathered weights a device consumes
    tokens_dev = cell.global_batch * cell.seq_len / n_data
    d = cfg.d_model
    L = cfg.n_layers
    # forward + recompute + backward each stream the (gathered) weights once,
    # in bf16 compute copies (half the f32 master bytes)
    weights = 3 * accum * P_stream * 0.5
    # gradient accumulation buffer read+write per microstep (f32, sharded)
    grads = 2 * accum * (P / n_devices) * 4 / 4
    # optimizer: read p,m,v + write p,m,v (f32, sharded)
    opt = 6 * P_dev
    # activation checkpoints: write fwd, read bwd (bf16) - one carry per layer
    acts = 2 * L * tokens_dev * d * 2
    # logits written+read in f32 (vocab sharded over model axis)
    logits = 2 * tokens_dev * cfg.vocab_size / n_model * 4
    return weights + grads + opt + acts + logits


def decode_step_token_bytes(cfg, cell) -> float:
    """KV bytes one decode step *writes*: each sequence's single new
    token per layer — the only cache traffic a donated in-place update
    adds on top of the context read."""
    import dataclasses
    return cache_bytes(cfg, dataclasses.replace(cell, seq_len=1))


def decode_boundary_bytes(cfg, cell, device_sampling: bool = False) -> float:
    """Bytes a decode step hands back across the jit/step boundary to the
    host program.  The legacy path materializes the full ``[B, vocab]``
    f32 logit matrix as a step output for host-side eager sampling —
    an HBM round-trip plus an extra eager argmax dispatch and a forced
    sync per token (on host-memory backends it is literally the host
    transfer).  With sampling fused into the step, only the ``[2, B]``
    int32 token echo crosses (outputs AND echoed inputs in one buffer,
    so prefill first-tokens need no transfer of their own)."""
    B = cell.global_batch
    if device_sampling:
        return 2.0 * B * 4.0
    return B * cfg.vocab_size * 4.0


def analytic_serve_bytes(cfg, cell, n_devices: int, n_model: int = 16,
                         donated: bool = False) -> float:
    """Per-device HBM bytes for one serve step (prefill or decode).

    ``donated`` models the fused hot path's in-place cache update: an
    undonated functional step reads the whole decode cache AND writes a
    complete second copy (2x cache bytes); a donated step reads the
    context but writes only each sequence's new token slice.  The
    default (False) is the legacy engines' traffic — what the shipped
    golden predictions were recorded against."""
    P = _param_bytes(cfg)
    n_model = max(min(n_model, n_devices), 1)
    P_stream = P / n_model * 2 / 4        # bf16 weights, TP sharded
    if cfg.moe and cell.kind == "decode":
        # decode touches only active experts' weights
        act_frac = count_active_params(cfg) / count_params(cfg)
        P_stream *= act_frac
    if cell.kind == "prefill":
        n_data = max(n_devices // n_model, 1)
        tokens_dev = cell.global_batch * cell.seq_len / n_data
        d = cfg.d_model
        acts = 2 * cfg.n_layers * tokens_dev * d * 2
        cache = cache_bytes(cfg, cell) / n_devices
        return P_stream + acts + cache
    if donated:
        # decode, fused: read the context once, write one token per seq
        cache = (cache_bytes(cfg, cell)
                 + decode_step_token_bytes(cfg, cell)) / n_devices
    else:
        # decode, legacy: read the whole cache + materialize a second one
        cache = 2 * cache_bytes(cfg, cell) / n_devices
    return P_stream + cache


def analytic_route_bytes(cfg, prompt_len: int,
                         filled_tokens: int = 0) -> float:
    """Bytes one inter-replica route (or re-route) of a request moves or
    abandons — what the cluster router's cost-aware placement charges a
    candidate replica on top of its queue.

    Two terms:

    * the prompt token ids cross the datacenter fabric to the target
      host (4 B int32 each) — the only traffic a FRESH placement pays,
      which is why first placement is near-free;
    * any KV already materialized on the source replica is thrown away
      and re-written on the target: the filled prefix's cache bytes, the
      prefill replay's write traffic.  Re-routing a half-prefilled
      eviction victim therefore competes against its local front-requeue
      (which replays the same prefix but moves no tokens) — exactly the
      tradeoff ``serve.cluster.policy.CostAwarePolicy.reroute`` prices.
    """
    tok_bytes = 4.0 * max(int(prompt_len), 0)
    filled = min(max(int(filled_tokens), 0), max(int(prompt_len), 0))
    if filled == 0:
        return tok_bytes
    from repro.configs.base import ShapeCell
    cell = ShapeCell("route", "prefill", filled, 1)
    return tok_bytes + cache_bytes(cfg, cell)


def analytic_step_bytes(cfg, cell, n_devices: int, accum: int = 1,
                        n_model: int = 16, donated: bool = False) -> float:
    if cell.kind == "train":
        return analytic_train_bytes(cfg, cell, n_devices, accum, n_model)
    return analytic_serve_bytes(cfg, cell, n_devices, n_model,
                                donated=donated)


# rough top-level-op count per transformer layer in an optimized module
# (fusion-dominated; anchors the issue-overhead term of analytic censuses)
_OPS_PER_LAYER = {"fusion": 30.0, "dot": 6.0, "dynamic-update-slice": 2.0,
                  "transpose": 2.0, "reshape": 4.0, "copy": 1.0}


def analytic_census(cfg, cell, n_devices: int, n_model: int = 16,
                    accum: int = 1, donated: bool = False,
                    device_sampling: bool = False) -> Dict[str, Any]:
    """A census-shaped dict (flops / hbm_bytes / collective bytes /
    op_histogram) for a candidate sharding plan, from first principles.

    Collective model (ring algorithms over the batch/model axes):
      * FSDP weight gather fwd+bwd plus gradient reduce-scatter over the
        data axis: 3 x (P/n_model) bf16 bytes x (d-1)/d;
      * TP activation combines over the model axis: 2 collectives/layer of
        per-device token activations x (m-1)/m.

    ``donated`` / ``device_sampling`` price the fused decode hot path:
    donation removes the second-cache materialization from ``hbm_bytes``
    (write only the new token slice), and on-device sampling shrinks
    ``boundary_bytes`` from the ``[B, vocab]`` f32 logit matrix handed
    to host-side sampling down to the ``[2, B]`` int32 token echo.  Both
    default to the legacy engines' traffic so recorded golden
    predictions are unchanged.
    """
    n_model = max(min(n_model, n_devices), 1)
    n_data = max(n_devices // n_model, 1)
    P = count_params(cfg)
    P_active = count_active_params(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    tokens_dev = tokens / n_data
    if cell.kind == "train":
        flops_global = 6.0 * P_active * tokens * accum
    else:
        flops_global = 2.0 * P_active * tokens
    flops_dev = flops_global / n_devices

    wire = 0.0
    if n_data > 1:
        gathers = 3 if cell.kind == "train" else 1
        wire += gathers * (P * 2 / n_model) * (n_data - 1) / n_data
    if n_model > 1:
        passes = 3 * accum if cell.kind == "train" else 1
        wire += passes * 2 * cfg.n_layers * tokens_dev * cfg.d_model * 2 \
            * (n_model - 1) / n_model

    layers_weight = cfg.n_layers * (accum * 3 if cell.kind == "train" else 1)
    hist = {k: v * layers_weight for k, v in _OPS_PER_LAYER.items()}
    if n_data > 1 or n_model > 1:
        hist["all-reduce"] = 2.0 * cfg.n_layers
        hist["all-gather"] = float(cfg.n_layers)

    out = {
        "flops": flops_dev,
        "hbm_bytes": analytic_step_bytes(cfg, cell, n_devices, accum,
                                         n_model, donated=donated),
        "collective_bytes_total": wire,
        "op_histogram": hist,
        "model_flops_global": flops_global,
    }
    if cell.kind == "decode":
        # what crosses the step boundary to the host program (informational:
        # the roofline terms do not price it, but predicted-vs-measured
        # step comparisons and the decode_hotpath experiment read it)
        out["boundary_bytes"] = decode_boundary_bytes(
            cfg, cell, device_sampling=device_sampling)
    return out
