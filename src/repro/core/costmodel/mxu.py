"""MXU layer: a shape/dtype throughput surface for matrix-unit compute.

Replaces the single ``peak_flops_bf16`` scalar of the old roofline with the
paper's Table III view: measured throughput per (dtype, tile shape) point —
WMMA fragments on the paper's A100, MXU tile probes from the ``mxu_shapes``
campaign here — with hardware-spec peaks as the envelope only when the
calibration measured nothing at all.

A dtype the calibration never measured resolves through RELATIVE rates
against the layer's own reference dtype — never by jumping to a different
scale (chip peak vs per-instruction rate) — so the ordering invariant the
paper establishes (f32 no faster than bf16/f16 on the matrix unit) holds
for any calibration mix.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.costmodel.calibration import Calibration, MXUPoint, canon_dtype
from repro.core.perfmodel.hardware import HardwareSpec

# matrix-unit rate of each dtype relative to bf16 (Ampere Table III /
# datasheet ratios; used only when a dtype has no measured point or peak)
_RELATIVE_RATE = {"bf16": 1.0, "f16": 1.0, "tf32": 0.5, "f32": 0.5,
                  "f64": 1.0 / 16.0, "s8": 2.0}


class MXULayer:
    def __init__(self, cal: Calibration, hw: Optional[HardwareSpec] = None):
        self.points: Dict[Tuple[str, Optional[Tuple[int, int, int]], bool],
                          MXUPoint] = {}
        for p in cal.mxu_points:
            self.points[(p.dtype, p.shape, p.dependent)] = p
        self.peaks: Dict[str, float] = dict(cal.mxu_peaks)
        self.spec_peaks: Dict[str, float] = {}
        if hw is not None:
            self.spec_peaks["bf16"] = hw.peak_flops_bf16
            if hw.peak_flops_f32:
                self.spec_peaks["f32"] = min(hw.peak_flops_f32,
                                             hw.peak_flops_bf16)
        self.clock_hz = cal.clock_hz or 1e9

    def _best_point(self, dtype: Optional[str] = None,
                    dependent: Optional[bool] = None) -> float:
        best = 0.0
        for (pdt, _, pdep), p in self.points.items():
            if dtype is not None and pdt != dtype:
                continue
            if dependent is not None and pdep != dependent:
                continue
            best = max(best, p.flops_per_s)
        return best

    def _ref(self) -> Tuple[str, float]:
        """Reference (dtype, FLOP/s) for relative-rate resolution — always
        from the calibration's own scale when it measured anything."""
        for dt in ("bf16", "f16"):
            if self.peaks.get(dt, 0.0) > 0:
                return dt, self.peaks[dt]
            best = self._best_point(dt)
            if best > 0:
                return dt, best
        if self.peaks and max(self.peaks.values()) > 0:
            dt = max(self.peaks, key=self.peaks.get)
            return dt, self.peaks[dt]
        any_best = 0.0
        any_dt = "bf16"
        for (pdt, _, _), p in self.points.items():
            if p.flops_per_s > any_best:
                any_best, any_dt = p.flops_per_s, pdt
        if any_best > 0:
            return any_dt, any_best
        return "bf16", self.spec_peaks.get("bf16", 1e12)

    def throughput(self, dtype: str = "bf16",
                   shape: Optional[Tuple[int, int, int]] = None,
                   dependent: bool = False) -> float:
        """Effective FLOP/s for a dtype (and optionally an exact tile shape).

        Resolution: exact measured point -> calibration peak -> best
        measured point for the dtype -> relative rate vs the calibration's
        reference dtype.  Guaranteed > 0.
        """
        dt = canon_dtype(dtype)
        if shape is not None:
            p = self.points.get((dt, tuple(shape), dependent)) \
                or self.points.get((dt, tuple(shape), not dependent))
            if p is not None and p.flops_per_s > 0:
                return p.flops_per_s
        if self.peaks.get(dt, 0.0) > 0:   # degenerate 0-rate rows fall past
            return self.peaks[dt]
        best = self._best_point(dt, dependent)
        if best <= 0:
            best = self._best_point(dt)
        if best > 0:
            return best
        ref_dt, ref = self._ref()
        rel = _RELATIVE_RATE.get(dt, 1.0) / _RELATIVE_RATE.get(ref_dt, 1.0)
        return max(ref * rel, 1.0)

    def time_for_flops(self, flops: float, dtype: str = "bf16",
                       shape: Optional[Tuple[int, int, int]] = None) -> float:
        return float(flops) / self.throughput(dtype, shape)

    def tile_latency_s(self, dtype: str,
                       shape: Tuple[int, int, int]) -> Optional[float]:
        """Latency of ONE dependent tile op, if measured (Table III column)."""
        p = self.points.get((canon_dtype(dtype), tuple(shape), True))
        if p is None:
            return None
        if p.cycles is not None:
            return p.cycles / self.clock_hz
        fl = 2.0 * shape[0] * shape[1] * shape[2]
        return fl / p.flops_per_s if p.flops_per_s else None
