"""Calibration loading/normalization for the unified cost model.

Three on-disk formats feed the model, all produced by this repo:

  * the paper transcription  (``ampere_a100.json``: SASS ``instructions`` +
    ``dependent_vs_independent`` + ``tensor_core`` WMMA rows, Tables I-V);
  * the deployment-target table (``tpu_v5e.json``: ``vpu`` CPIs + ``mxu``
    peaks + ``memory`` latencies/bandwidth);
  * campaign-derived tables (``report.calibration_from_results``: measured
    ``ops``/``memory``/``mxu`` sections straight from result files).

``Calibration.from_dict`` normalizes any of them into ONE canonical shape —
per-op instruction entries with the paper's dependent/independent split, a
memory-hierarchy level list with per-level latency plus streaming bandwidth,
and an MXU throughput surface over (dtype, tile shape) — which the three
layers in ``instruction.py`` / ``memory.py`` / ``mxu.py`` consume.
``to_dict``/``from_dict`` round-trip losslessly (the canonical schema), so a
calibration can be persisted and reloaded without drift.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

CALIB_DIR = Path(__file__).resolve().parents[1] / "calibration"

CANONICAL_KIND = "costmodel_calibration"
CANONICAL_VERSION = 1

# dtype spellings seen across the three formats -> canonical short names
_DTYPE_CANON = {
    "float32": "f32", "f32": "f32", "bfloat16": "bf16", "bf16": "bf16",
    "float16": "f16", "f16": "f16", "f16x2": "f16", "float64": "f64",
    "f64": "f64", "tf32": "tf32", "int32": "s32", "s32": "s32",
    "int8": "s8", "s8": "s8", "u32": "s32", "b32": "s32", "int": "s32",
}


def canon_dtype(dt: str) -> str:
    return _DTYPE_CANON.get(dt, dt)


# SASS opcode (the paper's Table II rows) -> (generic op, canonical dtype).
# Memory instructions (LDG/LDS) route to the memory layer instead.
_SASS_TO_OP = {
    "FADD.f32": ("add", "f32"), "FMUL.f32": ("mul", "f32"),
    "FFMA.f32": ("fma", "f32"), "FADD.f16x2": ("add", "f16"),
    "HFMA2.f16x2": ("fma", "f16"), "DADD.f64": ("add", "f64"),
    "DMUL.f64": ("mul", "f64"), "DFMA.f64": ("fma", "f64"),
    "IADD3.s32": ("add", "s32"), "IMAD.s32": ("fma", "s32"),
    "LOP3.b32": ("and", "s32"), "SHF.b32": ("shift", "s32"),
    "POPC.b32": ("popc", "s32"), "FLO.u32": ("clz", "s32"),
    "ISETP.s32": ("compare", "s32"), "SEL.b32": ("select", "s32"),
    "MUFU.RCP.f32": ("div", "f32"), "MUFU.RSQ.f32": ("rsqrt", "f32"),
    "MUFU.SQRT.f32": ("sqrt", "f32"), "MUFU.EX2.f32": ("exp", "f32"),
    "MUFU.LG2.f32": ("log", "f32"), "MUFU.SIN.f32": ("sin", "f32"),
    "MUFU.TANH.f32": ("tanh", "f32"),
}

# memory-access SASS rows -> (level name, assumed capacity).  The paper
# reports latencies, not sizes; capacities are the A100 datasheet values.
_SASS_MEMORY = {
    "LDS": ("smem", 164 * 2**10),
    "LDG.E.ca": ("l1", 192 * 2**10),
    "LDG.E.cg": ("l2", 40 * 2**20),
}


@dataclass
class InstructionEntry:
    """One per-op latency row: the paper's Table II dependent/independent
    split, in cycles at the calibration's clock."""
    op: str
    dtype: str
    dependent_cycles: float
    independent_cycles: float
    pipeline: str = ""
    source_key: str = ""      # the raw-table key this row came from

    @property
    def key(self) -> str:
        return f"{self.op}.{self.dtype}"


@dataclass
class MemoryLevel:
    """One rung of the hierarchy ladder (Table IV row)."""
    name: str
    capacity_bytes: float
    latency_ns: float
    source_key: str = ""


@dataclass
class MXUPoint:
    """One measured (dtype, tile shape) throughput point (Table III row)."""
    dtype: str
    shape: Optional[Tuple[int, int, int]]
    flops_per_s: float
    cycles: Optional[float] = None
    dependent: bool = False
    source_key: str = ""


@dataclass
class Calibration:
    """The normalized measured-table bundle every cost-model layer reads.

    Pure data with a lossless ``to_dict``/``from_dict`` round-trip — the
    property downstream consumers build on: tables ship as JSON, campaign
    results convert in (``report.calibration_from_results``), and online
    recalibration (``serve.telemetry.recalibrate.rescale_calibration``)
    is a copy-scale-rebuild that never mutates the source instance.
    """
    name: str
    hardware: str
    clock_hz: float
    instructions: Dict[str, InstructionEntry] = field(default_factory=dict)
    memory_levels: List[MemoryLevel] = field(default_factory=list)
    bandwidth_bps: Optional[float] = None      # streaming bytes/s
    mxu_points: List[MXUPoint] = field(default_factory=list)
    mxu_peaks: Dict[str, float] = field(default_factory=dict)  # dtype->FLOP/s
    source: str = ""
    raw: Dict[str, Any] = field(default_factory=dict, repr=False)

    # ----- canonical round-trip ----------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": CANONICAL_KIND,
            "version": CANONICAL_VERSION,
            "name": self.name,
            "hardware": self.hardware,
            "clock_hz": self.clock_hz,
            "source": self.source,
            "instructions": {
                k: dataclasses.asdict(e)
                for k, e in sorted(self.instructions.items())},
            "memory_levels": [dataclasses.asdict(l)
                              for l in self.memory_levels],
            "bandwidth_bps": self.bandwidth_bps,
            "mxu_points": [
                {**dataclasses.asdict(p),
                 "shape": list(p.shape) if p.shape else None}
                for p in self.mxu_points],
            "mxu_peaks": dict(sorted(self.mxu_peaks.items())),
        }

    @classmethod
    def from_dict(cls, doc: Dict[str, Any], name: str = "") -> "Calibration":
        """Normalize any supported table format (see module doc)."""
        if doc.get("kind") == CANONICAL_KIND:
            return cls._from_canonical(doc)
        if "instructions" in doc and "tensor_core" in doc:
            return cls._from_paper_table(doc, name)
        if "ops" in doc:
            return cls._from_campaign_table(doc, name)
        if "vpu" in doc:
            return cls._from_target_table(doc, name)
        raise ValueError(
            "unrecognized calibration format: expected one of "
            f"{CANONICAL_KIND!r}, a paper table ('instructions'+"
            "'tensor_core'), a campaign table ('ops'), or a target table "
            "('vpu')")

    # ----- format-specific normalizers ---------------------------------------

    @classmethod
    def _from_canonical(cls, doc) -> "Calibration":
        return cls(
            name=doc.get("name", ""),
            hardware=doc.get("hardware", ""),
            clock_hz=float(doc.get("clock_hz") or 1e9),
            instructions={k: InstructionEntry(**e)
                          for k, e in doc.get("instructions", {}).items()},
            memory_levels=[MemoryLevel(**l)
                           for l in doc.get("memory_levels", [])],
            bandwidth_bps=doc.get("bandwidth_bps"),
            mxu_points=[MXUPoint(**{**p, "shape": tuple(p["shape"])
                                    if p.get("shape") else None})
                        for p in doc.get("mxu_points", [])],
            mxu_peaks={k: float(v)
                       for k, v in doc.get("mxu_peaks", {}).items()},
            source=doc.get("source", ""),
            raw=doc,
        )

    @classmethod
    def _from_paper_table(cls, doc, name) -> "Calibration":
        """ampere_a100.json: the transcribed Tables I-V."""
        clock = float(doc.get("clock_mhz", 1000)) * 1e6
        cal = cls(name=name or doc.get("hardware", "paper"),
                  hardware=doc.get("hardware", ""), clock_hz=clock,
                  source=doc.get("source", ""), raw=doc)
        dep_ind = doc.get("dependent_vs_independent", {})
        for key, row in doc.get("instructions", {}).items():
            if key in _SASS_MEMORY:
                lname, cap = _SASS_MEMORY[key]
                cal.memory_levels.append(MemoryLevel(
                    name=lname, capacity_bytes=cap,
                    latency_ns=row["latency_cycles"] / clock * 1e9,
                    source_key=key))
                continue
            if key not in _SASS_TO_OP:
                continue
            op, dt = _SASS_TO_OP[key]
            lat = float(row["latency_cycles"])
            di = dep_ind.get(key, {})
            cal.instructions[f"{op}.{dt}"] = InstructionEntry(
                op=op, dtype=dt,
                dependent_cycles=float(di.get("dependent", lat)),
                independent_cycles=float(di.get("independent", lat)),
                pipeline=row.get("pipeline", ""), source_key=key)
        for key, row in doc.get("tensor_core", {}).items():
            # "wmma.m16n16k16.f16" -> shape + dtype; flops = 2*m*n*k
            parts = key.split(".")
            shape = _parse_mnk(parts[1]) if len(parts) > 1 else None
            dt = canon_dtype(parts[-1])
            cycles = float(row["cycles"])
            fl = 2.0 * shape[0] * shape[1] * shape[2] if shape else 0.0
            cal.mxu_points.append(MXUPoint(
                dtype=dt, shape=shape, cycles=cycles,
                flops_per_s=fl / (cycles / clock) if cycles else 0.0,
                dependent=True, source_key=key))
        cal.memory_levels.sort(key=lambda l: l.capacity_bytes)
        return cal

    @classmethod
    def _from_target_table(cls, doc, name) -> "Calibration":
        """tpu_v5e.json: design-estimate CPIs + MXU peaks + memory constants."""
        clock = float(doc.get("clock_mhz", 1000)) * 1e6
        cal = cls(name=name or doc.get("hardware", "target"),
                  hardware=doc.get("hardware", ""), clock_hz=clock,
                  source=doc.get("source", ""), raw=doc)
        for key, row in doc.get("vpu", {}).items():
            op, dt = key.rsplit(".", 1)
            dt = canon_dtype(dt)
            cpi = float(row["cpi"])
            cal.instructions[f"{op}.{dt}"] = InstructionEntry(
                op=op, dtype=dt, dependent_cycles=cpi,
                independent_cycles=cpi, source_key=key)
        for key, row in doc.get("mxu", {}).items():
            dt = canon_dtype(key.split(".")[0])
            peak = float(row["peak_tflops"]) * 1e12
            cal.mxu_peaks[dt] = peak
            tile = row.get("tile")
            shape = (tile[0], tile[1], tile[1]) if tile else None
            cal.mxu_points.append(MXUPoint(
                dtype=dt, shape=shape, flops_per_s=peak, source_key=key))
        mem = doc.get("memory", {})
        if "vmem_mib" in mem:
            cal.memory_levels.append(MemoryLevel(
                "vmem", mem["vmem_mib"] * 2**20,
                mem.get("vmem_latency_ns", 30.0), source_key="vmem"))
        if "hbm_gib" in mem:
            cal.memory_levels.append(MemoryLevel(
                "hbm", mem["hbm_gib"] * 2**30,
                mem.get("hbm_latency_ns", 500.0), source_key="hbm"))
        if "hbm_bandwidth_gbs" in mem:
            cal.bandwidth_bps = mem["hbm_bandwidth_gbs"] * 1e9
        return cal

    @classmethod
    def _from_campaign_table(cls, doc, name) -> "Calibration":
        """report.calibration_from_results output: measured campaign table."""
        clock = (float(doc["clock_mhz"]) * 1e6 if "clock_mhz" in doc
                 else float(doc.get("clock_hz") or 1e9))
        cal = cls(name=name or doc.get("hardware", "measured"),
                  hardware=doc.get("hardware", ""), clock_hz=clock,
                  source=doc.get("source", ""), raw=doc)
        # ops: "add.float32.dep" / "add.float32.ind" pairs -> one entry
        pending: Dict[str, Dict[str, float]] = {}
        for key, row in doc.get("ops", {}).items():
            base, tag = key.rsplit(".", 1)
            cycles = row["per_op_ns"] * 1e-9 * clock
            pending.setdefault(base, {})[tag] = cycles
        for base, tags in pending.items():
            op, dt = base.rsplit(".", 1)
            dt = canon_dtype(dt)
            dep = tags.get("dep", tags.get("ind", 0.0))
            ind = tags.get("ind", dep)
            cal.instructions[f"{op}.{dt}"] = InstructionEntry(
                op=op, dtype=dt, dependent_cycles=dep,
                independent_cycles=ind, source_key=base)
        for key, row in doc.get("memory", {}).items():
            ws = float(key)
            cal.memory_levels.append(MemoryLevel(
                name=f"ws_{int(ws) // 1024}KiB", capacity_bytes=ws,
                latency_ns=row["per_hop_ns"], source_key=key))
        cal.memory_levels.sort(key=lambda l: l.capacity_bytes)
        streams = [row["gbps"] * 1e9
                   for row in doc.get("memory_streaming", {}).values()]
        roof = doc.get("roofline", {})
        if "hbm_stream_gbs" in roof:
            streams.append(roof["hbm_stream_gbs"]["value"] * 1e9)
        if streams:
            cal.bandwidth_bps = max(streams)
        for key, row in doc.get("mxu", {}).items():
            # "float32.m128n128k128.dep"
            parts = key.split(".")
            dt = canon_dtype(parts[0])
            shape = _parse_mnk(parts[1]) if len(parts) > 2 else None
            dep = parts[-1] == "dep"
            cal.mxu_points.append(MXUPoint(
                dtype=dt, shape=shape, flops_per_s=row["tflops"] * 1e12,
                dependent=dep, source_key=key))
        if "mxu_peak_tflops" in roof:
            best = roof["mxu_peak_tflops"]["value"] * 1e12
            # the roofline probe measures the f32 path on this harness
            cal.mxu_peaks.setdefault("f32", best)
        for p in cal.mxu_points:
            if not p.dependent and p.flops_per_s > 0:   # skip failed probes
                cur = cal.mxu_peaks.get(p.dtype, 0.0)
                cal.mxu_peaks[p.dtype] = max(cur, p.flops_per_s)
        return cal


def _parse_mnk(token: str) -> Optional[Tuple[int, int, int]]:
    """'m16n16k16' -> (16, 16, 16)."""
    import re
    m = re.fullmatch(r"m(\d+)n(\d+)k(\d+)", token)
    return (int(m.group(1)), int(m.group(2)), int(m.group(3))) if m else None


def load_calibration(name_or_path: "str | Path") -> Calibration:
    """Resolve a calibration by shipped name (``ampere_a100``, ``tpu_v5e``),
    JSON file path, or campaign results directory."""
    p = Path(name_or_path)
    if p.is_dir():
        from repro.core.microbench.tables import table_from_results
        return Calibration.from_dict(table_from_results(p), name=str(p))
    if not p.suffix:
        shipped = CALIB_DIR / f"{p.name}.json"
        if shipped.exists():
            p = shipped
    if not p.exists():
        raise FileNotFoundError(
            f"no calibration {str(name_or_path)!r}: not a shipped name "
            f"({', '.join(sorted(q.stem for q in CALIB_DIR.glob('*.json')))}),"
            " file path, or campaign results directory")
    return Calibration.from_dict(json.loads(p.read_text()), name=p.stem)
