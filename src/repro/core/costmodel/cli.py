"""Cost-model CLI: ``python -m repro.core.costmodel ...``.

  --calibration ampere_a100          shipped name, JSON path, or campaign
                                     results directory
  --census <module>                  price a compiled module: a file holding
                                     optimized HLO text, or a JSON artifact
                                     with a "census" key (dry-run record) or
                                     census-shaped keys
  --prediction-error                 round-trip every calibration row through
                                     the layers and print the error table
  --demo                             price a canned census — shows the
                                     defaulted-op reporting
  --export PATH                      write the normalized calibration in the
                                     canonical round-trip format
  --hw NAME                          hardware spec override (tpu-v5e, a100-40g)

Everything here is measurement-free: the CLI only loads tables and prices
censuses — no kernels run and nothing compiles — so it answers in
milliseconds (the CI smoke path).
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
from pathlib import Path

from repro.core.costmodel.model import (CostModel, prediction_error_rows,
                                        prediction_error_summary,
                                        save_calibration)
from repro.core.perfmodel.hardware import SPECS

DEFAULT_OUT_DIR = Path("results") / "costmodel"

# a canned census (tiny decode-ish step) so `--demo` needs no compiled
# module: exercises mapped ops, defaulted ops and every predicted term
DEMO_CENSUS = {
    "flops": 4.2e9,
    "hbm_bytes": 1.3e8,
    "collective_bytes_total": 2.0e6,
    "op_histogram": {
        "fusion": 120.0, "dot": 24.0, "add": 40.0, "multiply": 32.0,
        "tanh": 8.0, "exponential": 8.0, "select": 6.0,
        # kinds with no table row -> must show up as defaulted
        "transpose": 10.0, "reshape": 18.0, "copy": 6.0, "iota": 2.0,
        "dynamic-update-slice": 4.0,
    },
}


def _load_census(path: Path, n_devices: int = 1) -> dict:
    text = path.read_text()
    stripped = text.lstrip()
    if stripped.startswith("{"):
        # JSON artifacts already carry per-device numbers; n_devices only
        # applies when parsing raw HLO text below
        doc = json.loads(text)
        if "census" in doc:
            return doc["census"]
        if "op_histogram" in doc or "flops" in doc:
            return doc
        raise SystemExit(f"{path}: JSON has neither a 'census' record nor "
                         "census-shaped keys (flops/op_histogram)")
    # otherwise: optimized-HLO text -> run the census parser on it
    from repro.core.isa.hlo_census import census
    return census(text, n_devices=n_devices)


def _print_prediction(pred) -> None:
    print(f"calibration={pred.calibration} hw={pred.hw} dtype={pred.dtype}")
    for term in ("compute_s", "memory_s", "collective_s",
                 "issue_overhead_s", "step_s"):
        print(f"  {term:18s} {getattr(pred, term):.6e}")
    print(f"  bottleneck         {pred.bottleneck}")
    print(f"  mapped_ops         {pred.mapped_op_count:.0f}")
    print(f"  defaulted_ops      {pred.defaulted_op_count:.0f}")
    for kind, count in sorted(pred.defaulted_ops.items(),
                              key=lambda kv: -kv[1]):
        print(f"    defaulted/{kind:24s} {count:.0f}")


def _print_error_table(model: CostModel) -> int:
    rows = prediction_error_rows(model)
    print("name,predicted,recorded,unit,err_pct")
    for r in rows:
        print(f"prederr/{r['name']},{r['predicted']:.6g},"
              f"{r['recorded']:.6g},{r['unit']},{r['err_pct']:.2f}")
    s = prediction_error_summary(rows)
    print(f"prederr/summary,0,0,,rows={s['rows']};"
          f"max_err_pct={s['max_err_pct']:.2f};"
          f"mean_err_pct={s['mean_err_pct']:.2f}")
    return 0 if s["max_err_pct"] <= 10.0 else 1


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.costmodel",
        description="calibrated instruction/memory/MXU cost model")
    p.add_argument("--calibration", default="tpu_v5e",
                   help="shipped name (ampere_a100, tpu_v5e), JSON path, or "
                        "campaign results dir (default: tpu_v5e)")
    p.add_argument("--census", metavar="MODULE", default=None,
                   help="price this module: HLO text file or JSON artifact")
    p.add_argument("--prediction-error", action="store_true",
                   help="print the calibration round-trip error table")
    p.add_argument("--demo", action="store_true",
                   help="price a canned census (defaulted-op smoke)")
    p.add_argument("--export", metavar="PATH", default=None,
                   help="write the normalized calibration (canonical "
                        f"format) — e.g. {DEFAULT_OUT_DIR}/cal.json")
    p.add_argument("--hw", default=None, choices=sorted(SPECS),
                   help="hardware spec override for collective/peak terms")
    p.add_argument("--dtype", default="bf16",
                   help="MXU compute dtype for the census terms")
    p.add_argument("--n-devices", type=int, default=1)
    return p


def main(argv=None) -> int:
    if hasattr(signal, "SIGPIPE"):   # die quietly when piped into `head`
        signal.signal(signal.SIGPIPE, signal.SIG_DFL)
    args = build_parser().parse_args(argv)
    hw = SPECS[args.hw] if args.hw else None
    model = CostModel.from_named(args.calibration, hw=hw)

    did = rc = 0
    if args.export:
        out = save_calibration(model.cal, args.export)
        print(f"wrote {out} ({len(model.cal.instructions)} instruction rows, "
              f"{len(model.cal.memory_levels)} memory levels, "
              f"{len(model.cal.mxu_points)} mxu points)")
        did = 1
    if args.prediction_error:
        rc |= _print_error_table(model)
        did = 1
    if args.census:
        cens = _load_census(Path(args.census), n_devices=args.n_devices)
        _print_prediction(model.predict(
            cens, dtype=args.dtype))
        did = 1
    if args.demo:
        _print_prediction(model.predict(DEMO_CENSUS, dtype=args.dtype))
        did = 1
    if not did:
        cal = model.cal
        print(f"calibration {cal.name} (hardware={cal.hardware!r}, "
              f"clock={cal.clock_hz / 1e6:.0f} MHz): "
              f"{len(cal.instructions)} instruction rows, "
              f"{len(cal.memory_levels)} memory levels, "
              f"{len(cal.mxu_points)} mxu points, "
              f"bandwidth={model.memory.bandwidth_bps / 1e9:.0f} GB/s")
        print("use --census/--demo/--prediction-error/--export "
              "(see --help)", file=sys.stderr)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
