"""Memory layer: hierarchy access latencies + streaming bandwidth.

Replaces the flat ``hbm_bandwidth``-only view of the old perf model with
the paper's Table IV shape: a ladder of memory levels (smem/L1/L2 on the
paper's A100; VMEM/HBM on the v5e target; measured working-set rungs from
the pointer-chase campaign), each with a per-access latency, plus the
contrasting streaming bandwidth for bulk traffic.

``transfer_seconds`` prices bulk byte movement (the roofline memory term);
``access_latency_ns`` answers the latency question the chase campaign
measures: how long one dependent access takes at a given working-set size.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.costmodel.calibration import Calibration, MemoryLevel
from repro.core.perfmodel.hardware import HardwareSpec


class MemoryLayer:
    def __init__(self, cal: Calibration, hw: Optional[HardwareSpec] = None):
        self.levels: List[MemoryLevel] = sorted(
            cal.memory_levels, key=lambda l: l.capacity_bytes)
        self.clock_hz = cal.clock_hz or 1e9
        # measured streaming bandwidth, else the hardware-spec constant
        self.bandwidth_bps = float(
            cal.bandwidth_bps or (hw.hbm_bandwidth if hw else 0.0) or 819e9)

    def level_for(self, working_set_bytes: float) -> Optional[MemoryLevel]:
        """Smallest level that holds the working set (else the last one —
        past the last rung everything is backing-store resident)."""
        if not self.levels:
            return None
        for lvl in self.levels:
            if working_set_bytes <= lvl.capacity_bytes:
                return lvl
        return self.levels[-1]

    def access_latency_ns(self, working_set_bytes: float) -> float:
        lvl = self.level_for(working_set_bytes)
        return lvl.latency_ns if lvl else 0.0

    def access_latency_cycles(self, working_set_bytes: float) -> float:
        return self.access_latency_ns(working_set_bytes) * 1e-9 \
            * self.clock_hz

    def transfer_seconds(self, nbytes: float) -> float:
        """Bulk-traffic time at streaming bandwidth (roofline memory term)."""
        return float(nbytes) / self.bandwidth_bps
