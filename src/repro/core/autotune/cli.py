"""Autotuner CLI: ``python -m repro.core.autotune <command> ...``.

  tune    search launch configs for one/all tunable kernels and persist
          the winners into the cache (analytic by default — runs on CPU
          with no accelerator and is fully deterministic; ``--measure``
          adds the top-K measured refinement stage)
  show    list cache entries (optionally one kernel's); rc=1 when a
          ``--kernel`` filter matches nothing — the CI round-trip check
  export  write the full cache document (canonical JSON) to a path

Common flags: ``--cache`` (default results/autotune/cache.json),
``--calibration`` (shipped name, JSON path, or campaign results dir),
``--dtype``, ``--shape axis=N`` (repeatable).

The analytic path never imports jax: loading tables and pricing censuses
answers in milliseconds (the CI smoke path).
"""
from __future__ import annotations

import argparse
import json
import signal
import sys

from repro.core.autotune.cache import DEFAULT_CACHE_PATH, TuningCache
from repro.core.autotune.search import Autotuner
from repro.core.autotune.space import get_tunable, tunable_names


def _parse_shapes(pairs):
    out = {}
    for p in pairs or ():
        if "=" not in p:
            raise SystemExit(f"--shape wants axis=N, got {p!r}")
        k, v = p.split("=", 1)
        out[k] = int(v)
    return out


def _add_common(p):
    p.add_argument("--cache", default=str(DEFAULT_CACHE_PATH),
                   help=f"cache file (default {DEFAULT_CACHE_PATH})")
    p.add_argument("--kernel", action="append", default=None,
                   help="tunable kernel name (repeatable; default: all of "
                        f"{', '.join(tunable_names())})")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.autotune",
        description="cost-model-guided kernel autotuner")
    sub = p.add_subparsers(dest="command", required=True)

    t = sub.add_parser("tune", help="search + persist tuned configs")
    _add_common(t)
    t.add_argument("--calibration", default="tpu_v5e",
                   help="shipped name, JSON path, or campaign results dir "
                        "(default: tpu_v5e)")
    t.add_argument("--dtype", default="bf16")
    t.add_argument("--shape", action="append", metavar="AXIS=N",
                   help="problem-shape override (repeatable; applies to "
                        "every tuned kernel that has the axis)")
    t.add_argument("--top-k", type=int, default=3,
                   help="candidates refined by measurement (default 3)")
    g = t.add_mutually_exclusive_group()
    g.add_argument("--analytic-only", action="store_true",
                   help="rank with the cost model only (the default; the "
                        "flag exists so CI invocations are explicit)")
    g.add_argument("--measure", action="store_true",
                   help="refine the top-K with measured timings "
                        "(microbench harness; interpret mode off-TPU)")

    s = sub.add_parser("show", help="list cache entries")
    _add_common(s)

    e = sub.add_parser("export", help="write the cache document to a path")
    _add_common(e)
    e.add_argument("out", help="output JSON path")
    return p


def _cmd_tune(args) -> int:
    from repro.core.costmodel import CostModel
    cache = TuningCache(args.cache)
    tuner = Autotuner(CostModel.from_named(args.calibration), cache,
                      dtype=args.dtype, measure=bool(args.measure),
                      top_k=args.top_k)
    shapes = _parse_shapes(args.shape)
    kernels = args.kernel or tunable_names()
    tunables = {name: get_tunable(name) for name in kernels}  # fail early
    known = {k for tn in tunables.values() for k in tn.shape_keys}
    unknown = sorted(set(shapes) - known)
    if unknown:
        # a typo'd axis must not silently tune the default shapes
        raise SystemExit(
            f"--shape axes {', '.join(unknown)} not used by "
            f"kernel(s) {', '.join(kernels)}; known axes: "
            f"{', '.join(sorted(known))}")
    for name, tn in tunables.items():
        use = {k: v for k, v in shapes.items() if k in tn.shape_keys}
        res = tuner.tune(name, use or None)
        print(res.summary())
        for row in res.ranked[:5]:
            print(f"    {json.dumps(row['config'], sort_keys=True):48s} "
                  f"predicted={row['predicted_s']:.3e}s "
                  f"({row['bottleneck']}-bound)"
                  + (f" measured={row['measured_s']:.3e}s"
                     if "measured_s" in row else ""))
    print(f"cache: {cache.path} ({len(cache)} entries)")
    return 0


def _cmd_show(args) -> int:
    cache = TuningCache(args.cache)
    kernels = args.kernel
    shown = 0
    for key, entry in cache.items():
        if kernels and entry.get("kernel") not in kernels:
            continue
        shown += 1
        print(f"{key}")
        print(f"    config={json.dumps(entry['config'], sort_keys=True)} "
              f"source={entry.get('source', '?')} "
              f"predicted={entry.get('predicted_s', 0.0):.3e}s "
              f"(default {entry.get('predicted_default_s', 0.0):.3e}s, "
              f"x{entry.get('predicted_speedup', 0.0):.2f})")
    print(f"{shown} entr{'y' if shown == 1 else 'ies'} in {cache.path}")
    if kernels and shown == 0:
        print(f"no entries for kernel(s) {', '.join(kernels)}",
              file=sys.stderr)
        return 1
    return 0


def _cmd_export(args) -> int:
    cache = TuningCache(args.cache)
    out = cache.export(args.out)
    print(f"wrote {out} ({len(cache)} entries)")
    return 0


def main(argv=None) -> int:
    if hasattr(signal, "SIGPIPE"):   # die quietly when piped into `head`
        signal.signal(signal.SIGPIPE, signal.SIG_DFL)
    args = build_parser().parse_args(argv)
    return {"tune": _cmd_tune, "show": _cmd_show,
            "export": _cmd_export}[args.command](args)


if __name__ == "__main__":
    raise SystemExit(main())
