"""The search space: tunable-kernel registry, candidate generation, and
analytic per-candidate censuses.

One :class:`Tunable` per tunable Pallas kernel (``repro.kernels``):

  * ``flash_attention`` — block_q x block_k x accumulator dtype
  * ``ssm_scan``        — channel tile (block_d)
  * ``wkv6``            — heads-per-grid-cell (block_h, a grid factorization)
  * ``mxu_probe``       — output tile (block_m, block_n)

``candidates`` enumerates MXU-aligned configurations and prunes them
against the hardware constraints carried by the loaded calibration (the
VMEM budget; tile alignment comes from the enumeration itself), always
keeping the default config so a ranking can never be empty.  ``census``
builds the census-shaped dict :meth:`CostModel.predict` prices — pure
arithmetic, no jax, no device — in which the launch config shows up as
issue-overhead (grid cells x inner-loop ops) and as the MXU tile shape,
while FLOPs and HBM bytes stay config-invariant: exactly the trade the
paper's tables let a model arbitrate (bigger tiles amortize issue cost
until the VMEM ladder cuts them off).

Everything here is deterministic: same shapes + same calibration ->
same candidate list in the same order.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

# MXU-aligned block-size ladder (the v5e systolic array is 128x128; 8 is
# the VPU sublane quantum, kept so tiny test shapes still get >1 candidate)
_BLOCK_LADDER = (8, 16, 32, 64, 128, 256, 512)

# fraction of VMEM a kernel instance may claim (scratch/double-buffer slack)
VMEM_FILL = 0.9
DEFAULT_VMEM_BYTES = 128 * 2**20


def vmem_budget_bytes(cal=None, hw=None) -> float:
    """The VMEM capacity candidates are pruned against: the calibration's
    measured 'vmem' rung if present, else the hardware spec, else 128 MiB."""
    if cal is not None:
        for lvl in getattr(cal, "memory_levels", ()):
            if lvl.name == "vmem":
                return float(lvl.capacity_bytes) * VMEM_FILL
    if hw is not None and getattr(hw, "vmem_bytes", 0.0):
        return float(hw.vmem_bytes) * VMEM_FILL
    return DEFAULT_VMEM_BYTES * VMEM_FILL


def _dtype_bytes(dtype: str) -> int:
    return {"f32": 4, "float32": 4, "bf16": 2, "bfloat16": 2, "f16": 2,
            "float16": 2, "s8": 1, "int8": 1}.get(dtype, 4)


def divisor_clamp(value: int, n: int) -> int:
    """Largest launchable block for a divisor-constrained axis: min-clamp
    to the problem size, then fall back to a common divisor when it does
    not divide.  THE one implementation — the kernels (ssm_scan, wkv6, the
    mxu_probe dispatch wrapper) and the candidate clamping both call it,
    so pricing always describes the block that actually launches."""
    v = max(min(int(value), n), 1)
    return v if n % v == 0 else math.gcd(v, n)


def _blocks_upto(limit: int) -> List[int]:
    """Ladder values clamped to the problem size, deduped, ascending."""
    out = sorted({min(b, limit) for b in _BLOCK_LADDER})
    return out or [limit]


def _divisors_from_ladder(n: int) -> List[int]:
    out = sorted({math.gcd(min(b, n), n) for b in _BLOCK_LADDER})
    return [d for d in out if d >= 1]


@dataclass(frozen=True)
class Tunable:
    """One tunable kernel: its default problem/launch shapes, the candidate
    enumerator, the analytic census, and the VMEM footprint model."""
    name: str
    shape_keys: Tuple[str, ...]
    default_shapes: Dict[str, int]
    default_config: Dict[str, Any]
    enumerate_fn: Callable[[Dict[str, int], str], List[Dict[str, Any]]]
    census_fn: Callable[[Dict[str, int], Dict[str, Any], str],
                        Dict[str, Any]]
    vmem_fn: Callable[[Dict[str, int], Dict[str, Any], str], float]

    def normalize_shapes(self, shapes: Optional[Mapping[str, int]]
                         ) -> Dict[str, int]:
        out = dict(self.default_shapes)
        for k, v in (shapes or {}).items():
            if k not in self.shape_keys:
                raise KeyError(
                    f"{self.name}: unknown shape key {k!r} "
                    f"(expected {', '.join(self.shape_keys)})")
            out[k] = int(v)
        return out

    def candidates(self, shapes: Mapping[str, int], dtype: str = "bf16",
                   budget_bytes: Optional[float] = None,
                   allow_low_precision: bool = False
                   ) -> List[Dict[str, Any]]:
        """Enumerate aligned configs, prune over-budget ones, dedupe on the
        effective (clamped) values, and guarantee the default survives.
        ``allow_low_precision`` opens reduced-precision axes (the bf16
        flash-attention accumulator) — off by default so tuning never
        trades numerics for speed without an explicit opt-in."""
        shapes = self.normalize_shapes(shapes)
        budget = budget_bytes if budget_bytes is not None \
            else DEFAULT_VMEM_BYTES * VMEM_FILL
        seen, out = set(), []
        for cand in self.enumerate_fn(shapes, dtype, allow_low_precision):
            # clamp BEFORE deduping: enumeration is shape-agnostic, so two
            # distinct raw candidates (e.g. block_size 256 and 512 at
            # ctx=128) can clamp to the same launched config — deduping on
            # the raw values used to let those duplicates through
            cand = _clamp_config(self.name, shapes,
                                 {**self.default_config, **cand})
            key = tuple(sorted(cand.items()))
            if key in seen:
                continue
            seen.add(key)
            if self.vmem_fn(shapes, cand, dtype) > budget:
                continue
            out.append(cand)
        default = self.effective_default(shapes)
        if not any(c == default for c in out):
            # the default must always be rankable (it is what launches
            # when no tuning entry exists), even past the budget
            out.insert(0, default)
        return out

    def effective_default(self, shapes: Mapping[str, int]) -> Dict[str, Any]:
        """The default config with the same clamping the kernel applies, so
        default-vs-tuned comparisons price what actually launches."""
        shapes = self.normalize_shapes(shapes)
        return _clamp_config(self.name, shapes, self.default_config)

    def census(self, shapes: Mapping[str, int], config: Mapping[str, Any],
               dtype: str = "bf16") -> Dict[str, Any]:
        shapes = self.normalize_shapes(shapes)
        cfg = _clamp_config(self.name, shapes,
                            {**self.default_config, **dict(config)})
        return self.census_fn(shapes, cfg, dtype)

    def vmem_bytes(self, shapes: Mapping[str, int],
                   config: Mapping[str, Any], dtype: str = "bf16") -> float:
        shapes = self.normalize_shapes(shapes)
        cfg = _clamp_config(self.name, shapes,
                            {**self.default_config, **dict(config)})
        return self.vmem_fn(shapes, cfg, dtype)


def _clamp_config(kernel: str, shapes: Mapping[str, int],
                  config: Dict[str, Any]) -> Dict[str, Any]:
    """Mirror the kernels' own clamping (min-with-problem, divisor fallback)
    so candidate dedup and pricing see the launched values."""
    c = dict(config)
    if kernel == "flash_attention":
        # pads ragged tails, so a plain min-clamp matches the kernel
        c["block_q"] = max(min(int(c["block_q"]), shapes["seq_q"]), 1)
        c["block_k"] = max(min(int(c["block_k"]), shapes["seq_kv"]), 1)
    elif kernel == "paged_attention":
        # pages pad the context tail; any size up to the context launches
        c["block_size"] = max(min(int(c["block_size"]), shapes["ctx"]), 1)
        # a split must cover >= 1 page (ops.paged_attention clamps to the
        # table width, which is ceil(ctx / block_size) here)
        nb = -(-shapes["ctx"] // c["block_size"])
        c["num_splits"] = max(min(int(c.get("num_splits", 1)), nb), 1)
    elif kernel == "ssm_scan":
        c["block_d"] = divisor_clamp(c["block_d"], shapes["d_inner"])
    elif kernel == "wkv6":
        c["block_h"] = divisor_clamp(c["block_h"], shapes["heads"])
    elif kernel == "mxu_probe":
        c["block_m"] = divisor_clamp(c["block_m"], shapes["m"])
        c["block_n"] = divisor_clamp(c["block_n"], shapes["n"])
    return c


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def _fa_enumerate(shapes, dtype, allow_low_precision=False):
    acc_dtypes = ("f32", "bf16") if allow_low_precision else ("f32",)
    out = []
    for bq in _blocks_upto(shapes["seq_q"]):
        for bk in _blocks_upto(shapes["seq_kv"]):
            for acc in acc_dtypes:
                out.append({"block_q": bq, "block_k": bk, "acc_dtype": acc})
    return out


def _fa_vmem(shapes, cfg, dtype):
    it = _dtype_bytes(dtype)
    acc_it = _dtype_bytes(cfg.get("acc_dtype", "f32"))
    D = shapes["head_dim"]
    skv = -(-shapes["seq_kv"] // cfg["block_k"]) * cfg["block_k"]
    bq = cfg["block_q"]
    kv = 2 * skv * D * it                  # whole K/V panel resident
    q_o = bq * D * (4 + it)                # q in f32 + output block
    state = bq * (D + 2) * acc_it          # acc + (m, l)
    scores = bq * cfg["block_k"] * 4       # s/p transient
    return kv + q_o + state + scores


def _fa_census(shapes, cfg, dtype):
    B, H, KH = shapes["batch"], shapes["heads"], shapes["kv_heads"]
    Sq, Skv, D = shapes["seq_q"], shapes["seq_kv"], shapes["head_dim"]
    bq, bk = cfg["block_q"], cfg["block_k"]
    it = _dtype_bytes(dtype)
    nq = -(-Sq // bq)
    nk = -(-Skv // bk)
    cells = B * H * nq
    flops = 4.0 * B * H * Sq * Skv * D
    hbm = 2.0 * B * Sq * H * D * it + 2.0 * B * KH * Skv * D * it
    per_cell = {"dot": 2.0 * nk, "exponential": 2.0 * nk,
                "maximum": 2.0 * nk, "multiply": 3.0 * nk,
                "add": 2.0 * nk, "select": 1.0 * nk, "fusion": 1.0}
    hist = {k: v * cells for k, v in per_cell.items()}
    return {"flops": flops, "hbm_bytes": hbm, "op_histogram": hist,
            "mxu_shape": (bq, bk, D)}


# ---------------------------------------------------------------------------
# paged_attention (decode through a block table; the tunable axis is the
# KV page size — a cache-LAYOUT parameter the paged serving engine reads
# from the tuning cache when it sizes its block pool)
# ---------------------------------------------------------------------------

# split-KV flash-decoding factors; pruned so every split covers >= 1 page
_SPLIT_LADDER = (1, 2, 4, 8, 16)


def _pa_enumerate(shapes, dtype, allow_low_precision=False):
    out = []
    for bs in _blocks_upto(shapes["ctx"]):
        nb = -(-shapes["ctx"] // bs)
        for s in _SPLIT_LADDER:
            if s > nb:
                continue
            out.append({"block_size": bs, "num_splits": s})
    return out


def _pa_vmem(shapes, cfg, dtype):
    it = _dtype_bytes(dtype)
    D, bs = shapes["head_dim"], cfg["block_size"]
    ctx = shapes["ctx"]
    ns = int(cfg.get("num_splits", 1))
    # the HBM-resident lowering's working set: K and V pages land in a
    # TWO-slot VMEM scratch each (double buffering — page j+1's DMA is
    # in flight while page j is consumed), never the staged pool.  The
    # split form keeps the same two-slot scratch PER CELL; what grows
    # with num_splits is the partial-row buffer the merge pass reads.
    kv = 2 * 2 * bs * D * it               # 2 K-page + 2 V-page slots
    q_o = D * (4 + it)                     # q in f32 + output row
    state = (D + 2) * 4                    # acc + (m, l), f32
    scores = bs * 4                        # s/p transient
    table = -(-ctx // bs) * 4              # the block-table row
    partials = (ns * (D + 2) * 4) if ns > 1 else 0  # merge working set
    return kv + q_o + state + scores + table + partials


def _pa_census(shapes, cfg, dtype):
    """The two trades the cost model arbitrates.  Block size: small pages
    read fewer padded tail bytes (less fragmentation amplification) but
    pay more per-page issue/gather overhead; large pages amortize issue
    cost but round every context up to a coarser multiple.  Split factor:
    more splits multiply the grid's independent cells (``grid_cells`` —
    the utilization term ``CostModel.predict`` scales bandwidth by) at
    the price of re-reading q per split and writing + re-reading the
    f32 partial (m, l, acc) rows in the merge pass."""
    B, H, KH = shapes["batch"], shapes["heads"], shapes["kv_heads"]
    D, ctx, bs = shapes["head_dim"], shapes["ctx"], cfg["block_size"]
    it = _dtype_bytes(dtype)
    ns = int(cfg.get("num_splits", 1))
    nb = -(-ctx // bs)
    pps = -(-nb // ns)                     # pages per split
    cells = B * H * ns
    flops = 4.0 * B * H * ctx * D
    # K/V reads are page-granular (the padded tail is read, not the exact
    # ctx) and partitioned across splits, so total page bytes don't grow;
    # q is re-read once per split; one table read per page
    hbm = 2.0 * B * KH * nb * bs * D * it + (ns + 1.0) * B * H * D * it \
        + B * nb * 4.0
    if ns > 1:
        # partial (m, l, acc) rows: written by pass 1, read by the merge
        hbm += 2.0 * B * H * ns * (D + 2) * 4.0
    per_cell = {"dot": 2.0 * pps, "exponential": 2.0 * pps,
                "maximum": 2.0 * pps, "multiply": 3.0 * pps,
                "add": 2.0 * pps, "dynamic-slice": 2.0 * pps, "fusion": 1.0}
    hist = {k: v * cells for k, v in per_cell.items()}
    if ns > 1:
        # the log-sum-exp merge pass (one fused rescale over [B,H,ns])
        merge = B * H * ns
        for k, v in (("exponential", 1.0), ("maximum", 1.0),
                     ("multiply", 2.0), ("add", 2.0)):
            hist[k] = hist.get(k, 0.0) + v * merge
        hist["fusion"] = hist.get("fusion", 0.0) + 1.0
    return {"flops": flops, "hbm_bytes": hbm, "op_histogram": hist,
            "grid_cells": float(cells)}


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------

def _ssm_enumerate(shapes, dtype, allow_low_precision=False):
    return [{"block_d": bd} for bd in _divisors_from_ladder(
        shapes["d_inner"])]


def _ssm_vmem(shapes, cfg, dtype):
    it = _dtype_bytes(dtype)
    S, N, bd = shapes["seq"], shapes["state_dim"], cfg["block_d"]
    streams = S * (2 * bd + 2 * N) * it    # x, dt, B, C panels
    out = S * bd * it
    state = bd * N * (4 + 4)               # h carry + dA transient (f32)
    return streams + out + state


def _ssm_census(shapes, cfg, dtype):
    B, S = shapes["batch"], shapes["seq"]
    Di, N, bd = shapes["d_inner"], shapes["state_dim"], cfg["block_d"]
    it = _dtype_bytes(dtype)
    cells = B * (-(-Di // bd))
    flops = 6.0 * B * S * Di * N
    hbm = (3.0 * B * S * Di + 2.0 * B * S * N) * it + 4.0 * Di * N
    per_cell_step = {"exponential": 1.0, "multiply": 4.0, "add": 2.0,
                     "dot": 1.0}
    hist = {k: v * cells * S for k, v in per_cell_step.items()}
    hist["fusion"] = float(cells)
    return {"flops": flops, "hbm_bytes": hbm, "op_histogram": hist}


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

def _wkv_enumerate(shapes, dtype, allow_low_precision=False):
    return [{"block_h": bh} for bh in _divisors_from_ladder(shapes["heads"])]


def _wkv_vmem(shapes, cfg, dtype):
    it = _dtype_bytes(dtype)
    S, N, bh = shapes["seq"], shapes["head_dim"], cfg["block_h"]
    streams = 4 * S * bh * N * it          # r, k, v, w panels
    out = S * bh * N * it
    state = bh * N * N * (4 + 4)           # S carry + kv transient (f32)
    return streams + out + state


def _wkv_census(shapes, cfg, dtype):
    B, S = shapes["batch"], shapes["seq"]
    H, N, bh = shapes["heads"], shapes["head_dim"], cfg["block_h"]
    it = _dtype_bytes(dtype)
    cells = B * (-(-H // bh))
    flops = 6.0 * B * S * H * N * N
    hbm = 5.0 * B * S * H * N * it + H * N * it
    per_cell_step = {"multiply": 4.0, "add": 2.0, "dot": 1.0}
    hist = {k: v * cells * S for k, v in per_cell_step.items()}
    hist["fusion"] = float(cells)
    return {"flops": flops, "hbm_bytes": hbm, "op_histogram": hist}


# ---------------------------------------------------------------------------
# mxu_probe
# ---------------------------------------------------------------------------

def _mxu_enumerate(shapes, dtype, allow_low_precision=False):
    out = []
    for bm in _divisors_from_ladder(shapes["m"]):
        for bn in _divisors_from_ladder(shapes["n"]):
            out.append({"block_m": bm, "block_n": bn})
    return out


def _mxu_vmem(shapes, cfg, dtype):
    it = _dtype_bytes(dtype)
    K = shapes["k"]
    bm, bn = cfg["block_m"], cfg["block_n"]
    return (bm * K + K * bn) * it + bm * bn * (it + 4)


def _mxu_census(shapes, cfg, dtype):
    M, K, N = shapes["m"], shapes["k"], shapes["n"]
    bm, bn = cfg["block_m"], cfg["block_n"]
    it = _dtype_bytes(dtype)
    cells = (-(-M // bm)) * (-(-N // bn))
    flops = 2.0 * M * K * N
    # each grid cell re-reads its A-row and B-column panels
    hbm = (cells * (bm * K + K * bn) + M * N) * it
    hist = {"dot": float(cells), "multiply": float(cells),
            "fusion": float(cells)}
    return {"flops": flops, "hbm_bytes": hbm, "op_histogram": hist,
            "mxu_shape": (bm, bn, K)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

TUNABLES: Dict[str, Tunable] = {
    t.name: t for t in (
        Tunable(
            name="flash_attention",
            shape_keys=("batch", "seq_q", "seq_kv", "heads", "kv_heads",
                        "head_dim"),
            default_shapes={"batch": 4, "seq_q": 1024, "seq_kv": 1024,
                            "heads": 8, "kv_heads": 2, "head_dim": 128},
            default_config={"block_q": 128, "block_k": 128,
                            "acc_dtype": "f32"},
            enumerate_fn=_fa_enumerate,
            census_fn=_fa_census,
            vmem_fn=_fa_vmem,
        ),
        Tunable(
            name="paged_attention",
            shape_keys=("batch", "heads", "kv_heads", "head_dim", "ctx"),
            default_shapes={"batch": 8, "heads": 8, "kv_heads": 2,
                            "head_dim": 128, "ctx": 2048},
            default_config={"block_size": 16, "num_splits": 1},
            enumerate_fn=_pa_enumerate,
            census_fn=_pa_census,
            vmem_fn=_pa_vmem,
        ),
        Tunable(
            name="ssm_scan",
            shape_keys=("batch", "seq", "d_inner", "state_dim"),
            default_shapes={"batch": 4, "seq": 512, "d_inner": 2048,
                            "state_dim": 16},
            default_config={"block_d": 256},
            enumerate_fn=_ssm_enumerate,
            census_fn=_ssm_census,
            vmem_fn=_ssm_vmem,
        ),
        Tunable(
            name="wkv6",
            shape_keys=("batch", "seq", "heads", "head_dim"),
            default_shapes={"batch": 4, "seq": 512, "heads": 32,
                            "head_dim": 64},
            default_config={"block_h": 1},
            enumerate_fn=_wkv_enumerate,
            census_fn=_wkv_census,
            vmem_fn=_wkv_vmem,
        ),
        Tunable(
            name="mxu_probe",
            shape_keys=("m", "k", "n"),
            default_shapes={"m": 512, "k": 512, "n": 512},
            default_config={"block_m": 128, "block_n": 128},
            enumerate_fn=_mxu_enumerate,
            census_fn=_mxu_census,
            vmem_fn=_mxu_vmem,
        ),
    )
}


def get_tunable(kernel: str) -> Tunable:
    try:
        return TUNABLES[kernel]
    except KeyError:
        raise KeyError(f"unknown tunable kernel {kernel!r}; available: "
                       f"{', '.join(sorted(TUNABLES))}") from None


def tunable_names() -> List[str]:
    return sorted(TUNABLES)


def shape_bucket(shapes: Mapping[str, int]) -> str:
    """Canonical shape-bucket key: every axis rounded UP to a power of two
    (nearby problem sizes share one tuning entry), axes sorted by name."""
    parts = []
    for k in sorted(shapes):
        v = max(int(shapes[k]), 1)
        parts.append(f"{k}{1 << (v - 1).bit_length()}")
    return "_".join(parts)
