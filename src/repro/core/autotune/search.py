"""The two-stage search: analytic ranking, optional measured refinement.

Stage 1 prices every surviving candidate with ``CostModel.predict`` over
the tunable's analytic census — deterministic, device-free, milliseconds —
and ranks ascending by predicted step time (ties broken by the canonical
JSON of the config, so the ranking is total and reproducible).  Stage 2,
when measurement is enabled, times the top-K candidates with the
microbenchmark harness (``microbench.harness.time_fn`` over the public
kernel entry points in ``repro.kernels``) and lets the median wall time
pick the winner — the paper's measure-don't-guess discipline applied to
the model's own shortlist.

Winners persist through :class:`TuningCache` keyed by ``(kernel,
shape-bucket, dtype, device_kind, calibration_id)``; ``lookup`` is the
read side the kernel dispatch path (``repro.kernels.ops``) consults.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional

from repro.core.autotune.cache import TuningCache, entry_key
from repro.core.autotune.space import (Tunable, get_tunable, shape_bucket,
                                       tunable_names, vmem_budget_bytes)
from repro.core.costmodel.calibration import canon_dtype

# ranked-table rows kept inside a cache entry (full rankings can be long)
_ENTRY_ROWS = 8


@dataclass
class TuneResult:
    """One kernel's tuning outcome: the full ranked table plus the pick."""
    kernel: str
    shapes: Dict[str, int]
    dtype: str
    key: str
    ranked: List[Dict[str, Any]]          # {config, predicted_s, ...} rows
    best: Dict[str, Any]                  # winning config
    default: Dict[str, Any]               # effective default config
    predicted_best_s: float
    predicted_default_s: float
    measured_best_s: Optional[float] = None
    measured_default_s: Optional[float] = None
    source: str = "analytic"              # analytic | measured

    @property
    def predicted_speedup(self) -> float:
        """Default-over-best predicted step time (>= 1 when tuning helps)."""
        return self.predicted_default_s / max(self.predicted_best_s, 1e-30)

    @property
    def measured_speedup(self) -> Optional[float]:
        if self.measured_best_s is None or self.measured_default_s is None:
            return None
        return self.measured_default_s / max(self.measured_best_s, 1e-30)

    def summary(self) -> str:
        cfg = json.dumps(self.best, sort_keys=True)
        s = (f"{self.kernel}: best={cfg} "
             f"predicted={self.predicted_best_s:.3e}s "
             f"(default {self.predicted_default_s:.3e}s, "
             f"x{self.predicted_speedup:.2f})")
        if self.measured_best_s is not None:
            s += f" measured={self.measured_best_s:.3e}s"
            if self.measured_speedup is not None:
                s += f" (x{self.measured_speedup:.2f} measured)"
        return s


_HIT_KEYS_KEPT = 64


@dataclass
class AutotuneStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    tunes: int = 0
    # most recent hit keys only (bounded: a serving process does one
    # lookup per tuned kernel call and must not accumulate forever)
    hit_keys: List[str] = field(default_factory=list)

    def record_hit(self, key: str) -> None:
        self.hits += 1
        self.hit_keys.append(key)
        if len(self.hit_keys) > _HIT_KEYS_KEPT:
            del self.hit_keys[:-_HIT_KEYS_KEPT]

    def as_dict(self) -> Dict[str, int]:
        return {"lookups": self.lookups, "hits": self.hits,
                "misses": self.misses, "tunes": self.tunes}


class Autotuner:
    """Cost-model-guided kernel autotuner with a persistent cache.

    ``cost_model`` defaults to the shipped ``tpu_v5e`` calibration;
    ``cache=None`` means a private in-memory cache (pass a
    :class:`TuningCache` to persist/share).  ``measure=True`` turns on
    stage-2 refinement (needs a backend jax can run kernels on —
    interpret mode off-TPU, so it works anywhere, slowly).
    """

    def __init__(self, cost_model=None, cache: Optional[TuningCache] = None,
                 *, dtype: str = "bf16", measure: bool = False,
                 top_k: int = 3, device_kind: Optional[str] = None,
                 measure_iters: int = 5, measure_warmup: int = 2,
                 allow_low_precision: bool = False):
        if cost_model is None:
            from repro.core.costmodel import CostModel
            cost_model = CostModel.from_named("tpu_v5e")
        self.cost_model = cost_model
        self.cache = cache if cache is not None else TuningCache(None)
        self.dtype = canon_dtype(dtype)
        self.measure = measure
        self.top_k = top_k
        # opt-in: search reduced-precision axes (bf16 flash accumulator)
        self.allow_low_precision = allow_low_precision
        self.measure_iters = measure_iters
        self.measure_warmup = measure_warmup
        self.device_kind = device_kind or self._default_device_kind()
        self.stats = AutotuneStats()

    def _default_device_kind(self) -> str:
        """Analytic tunings are keyed by the modeled hardware (deterministic
        with no device); measured tunings by the real device kind."""
        if self.measure:
            import jax
            d = jax.devices()[0]
            return f"{d.platform}-{getattr(d, 'device_kind', d.platform)}" \
                .replace("|", "/")
        return f"analytic-{self.cost_model.hw.name}"

    # ----- keys --------------------------------------------------------------

    def key_for(self, kernel: str, shapes: Mapping[str, int],
                dtype: Optional[str] = None) -> str:
        tn = get_tunable(kernel)
        return entry_key(kernel, shape_bucket(tn.normalize_shapes(shapes)),
                         canon_dtype(dtype or self.dtype),
                         self.device_kind, self.cost_model.cal.name or "?")

    # ----- read side (the kernel dispatch path) ------------------------------

    def lookup(self, kernel: str, shapes: Mapping[str, int],
               dtype: Optional[str] = None) -> Optional[Dict[str, Any]]:
        """Cache-hit config for a concrete problem, else None.  Never
        tunes implicitly — dispatch must stay O(dict probe).  A kernel
        with no tunable entry resolves to None; a malformed shape dict for
        a KNOWN tunable still raises (a typo'd axis must not become a
        permanent silent miss)."""
        from repro.core.autotune.space import TUNABLES
        if kernel not in TUNABLES:
            return None
        key = self.key_for(kernel, shapes, dtype)
        self.stats.lookups += 1
        entry = self.cache.get(key)
        if entry is None:
            self.stats.misses += 1
            return None
        self.stats.record_hit(key)
        return dict(entry["config"])

    def config_for(self, kernel: str, shapes: Mapping[str, int],
                   dtype: Optional[str] = None) -> Dict[str, Any]:
        """Tuned config when cached, else the kernel's effective default."""
        got = self.lookup(kernel, shapes, dtype)
        if got is not None:
            return got
        return get_tunable(kernel).effective_default(shapes)

    # ----- the search --------------------------------------------------------

    def tune(self, kernel: str, shapes: Optional[Mapping[str, int]] = None,
             dtype: Optional[str] = None) -> TuneResult:
        """Search one kernel's launch space and persist the winner.

        Whether the top-K gets measured is fixed at construction
        (``measure=``), NOT per call: the cache's device_kind key component
        is derived from it, and a per-call override would store
        wall-time-measured winners under the analytic key (or vice versa)
        — exactly the cross-device leakage the key exists to prevent."""
        tn = get_tunable(kernel)
        shapes_n = tn.normalize_shapes(shapes)
        dt = canon_dtype(dtype or self.dtype)
        do_measure = self.measure

        budget = vmem_budget_bytes(self.cost_model.cal, self.cost_model.hw)
        ranked = self._rank(tn, shapes_n, dt, budget)

        default = tn.effective_default(shapes_n)
        default_row = next(r for r in ranked if r["config"] == default)

        best_row = ranked[0]
        measured_best = measured_default = None
        source = "analytic"
        if do_measure:
            shortlist = ranked[:max(self.top_k, 1)]
            if not any(r["config"] == default for r in shortlist):
                shortlist = shortlist + [default_row]
            for row in shortlist:
                row["measured_s"] = self._measure(tn, shapes_n, dt,
                                                  row["config"])
            best_row = min(shortlist, key=lambda r: r["measured_s"])
            measured_best = best_row["measured_s"]
            measured_default = default_row.get("measured_s")
            source = "measured"

        key = self.key_for(kernel, shapes_n, dt)
        result = TuneResult(
            kernel=kernel, shapes=shapes_n, dtype=dt, key=key,
            ranked=ranked, best=dict(best_row["config"]), default=default,
            predicted_best_s=best_row["predicted_s"],
            predicted_default_s=default_row["predicted_s"],
            measured_best_s=measured_best,
            measured_default_s=measured_default, source=source)
        self.cache.put(key, self._entry(result))
        self.stats.tunes += 1
        return result

    def tune_all(self, kernels: Optional[List[str]] = None,
                 shapes: Optional[Mapping[str, Mapping[str, int]]] = None,
                 dtype: Optional[str] = None) -> Dict[str, TuneResult]:
        """Tune every (or the named) tunable kernel; per-kernel shape
        overrides come from ``shapes[kernel]``."""
        out = {}
        for name in (kernels or tunable_names()):
            out[name] = self.tune(name, (shapes or {}).get(name),
                                  dtype=dtype)
        return out

    # ----- internals ---------------------------------------------------------

    def _rank(self, tn: Tunable, shapes: Dict[str, int], dtype: str,
              budget: float) -> List[Dict[str, Any]]:
        rows = []
        for cand in tn.candidates(
                shapes, dtype, budget,
                allow_low_precision=self.allow_low_precision):
            census = dict(tn.census(shapes, cand, dtype))
            mxu_shape = census.pop("mxu_shape", None)
            pred = self.cost_model.predict(census, dtype=dtype,
                                           mxu_shape=mxu_shape)
            rows.append({
                "config": dict(cand),
                "predicted_s": pred.step_s,
                "bottleneck": pred.bottleneck,
                "issue_overhead_s": pred.issue_overhead_s,
                "vmem_bytes": tn.vmem_bytes(shapes, cand, dtype),
            })
        # total, reproducible order: time then canonical config JSON
        rows.sort(key=lambda r: (r["predicted_s"],
                                 json.dumps(r["config"], sort_keys=True)))
        return rows

    def _measure(self, tn: Tunable, shapes: Dict[str, int], dtype: str,
                 config: Dict[str, Any]) -> float:
        from repro.core.microbench.harness import time_fn
        fn, args = _example_call(tn.name, shapes, dtype, config)
        return time_fn(fn, *args, iters=self.measure_iters,
                       warmup=self.measure_warmup)

    def _entry(self, res: TuneResult) -> Dict[str, Any]:
        return {
            "kernel": res.kernel,
            "shapes": dict(res.shapes),
            "dtype": res.dtype,
            "device_kind": self.device_kind,
            "calibration_id": self.cost_model.cal.name or "?",
            "config": dict(res.best),
            "default_config": dict(res.default),
            "predicted_s": res.predicted_best_s,
            "predicted_default_s": res.predicted_default_s,
            "measured_s": res.measured_best_s,
            "measured_default_s": res.measured_default_s,
            "predicted_speedup": res.predicted_speedup,
            "source": res.source,
            "n_candidates": len(res.ranked),
            "candidates": [
                {k: v for k, v in row.items()}
                for row in res.ranked[:_ENTRY_ROWS]],
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }


# ---------------------------------------------------------------------------
# measured-stage example calls (jax imported here only — the analytic path
# never touches it)
# ---------------------------------------------------------------------------

def _example_call(kernel: str, shapes: Dict[str, int], dtype: str,
                  config: Dict[str, Any]):
    import functools

    import jax.numpy as jnp
    import numpy as np

    from repro import kernels as K

    jdt = {"f32": jnp.float32, "bf16": jnp.bfloat16,
           "f16": jnp.float16}.get(dtype, jnp.float32)
    rng = np.random.default_rng(0)
    n = lambda *s: jnp.asarray(rng.normal(size=s) * 0.3, jdt)

    if kernel == "flash_attention":
        B, Sq, Skv = shapes["batch"], shapes["seq_q"], shapes["seq_kv"]
        H, KH, D = shapes["heads"], shapes["kv_heads"], shapes["head_dim"]
        args = (n(B, Sq, H, D), n(B, Skv, KH, D), n(B, Skv, KH, D))
        return functools.partial(K.flash_attention, config=config), args
    if kernel == "paged_attention":
        B, H = shapes["batch"], shapes["heads"]
        KH, D, ctx = shapes["kv_heads"], shapes["head_dim"], shapes["ctx"]
        bs = int(config["block_size"])
        nb = -(-ctx // bs)                  # dense per-sequence page runs
        k_pages = n(B * nb, bs, KH, D)
        v_pages = n(B * nb, bs, KH, D)
        bt = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
        ctx_lens = jnp.full((B,), ctx, jnp.int32)
        # block_size is baked into the page layout above; num_splits is a
        # launch parameter and must reach the dispatch wrapper to be
        # measured
        return (functools.partial(K.paged_attention, config=config),
                (n(B, H, D), k_pages, v_pages, bt, ctx_lens))
    if kernel == "ssm_scan":
        B, S = shapes["batch"], shapes["seq"]
        Di, N = shapes["d_inner"], shapes["state_dim"]
        args = (n(B, S, Di),
                jnp.asarray(rng.uniform(1e-3, 0.1, (B, S, Di)), jdt),
                n(B, S, N), n(B, S, N),
                -jnp.abs(jnp.asarray(rng.normal(size=(Di, N)), jnp.float32)))
        return functools.partial(K.ssm_scan, config=config), args
    if kernel == "wkv6":
        B, S = shapes["batch"], shapes["seq"]
        H, N = shapes["heads"], shapes["head_dim"]
        args = (n(B, S, H, N), n(B, S, H, N), n(B, S, H, N),
                jnp.asarray(rng.uniform(0.7, 0.999, (B, S, H, N)), jdt),
                n(H, N))
        return functools.partial(K.wkv6, config=config), args
    if kernel == "mxu_probe":
        M, Kk, N = shapes["m"], shapes["k"], shapes["n"]
        args = (n(M, Kk), n(Kk, N))
        return functools.partial(K.mxu_probe, chain=1, config=config), args
    raise KeyError(f"no example call for kernel {kernel!r}")
