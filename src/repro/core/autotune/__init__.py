"""Cost-model-guided kernel autotuner with a persistent tuning cache.

Closes the paper's loop — measure (campaign tables) -> model (costmodel)
-> **tune**: launch configurations for the tunable Pallas kernels
(``flash_attention``, ``ssm_scan``, ``wkv6``, ``mxu_probe``) are
enumerated MXU-aligned, pruned against the calibration's hardware
constraints, ranked analytically with ``CostModel.predict`` (no device
needed, fully deterministic) and optionally refined with measured
timings; winners persist in a schema-versioned JSON cache keyed by
``(kernel, shape-bucket, dtype, device_kind, calibration_id)``.

The dispatch side is a process-global handle: ``install`` an
:class:`Autotuner` (the serving engine and the train loop do this when
given one) and every ``repro.kernels`` wrapper called with ``tuned=True``
resolves its launch config through :func:`tuned_config`.

This ``__init__`` is lazy (PEP 562): the kernels' dispatch layer imports
``repro.core.autotune.space`` (pure stdlib — launch defaults, divisor
clamping, censuses) without pulling ``search``/``cache``/``costmodel``
into every kernel import; those load on first attribute access.

CLI: ``python -m repro.core.autotune tune --analytic-only --kernel
flash_attention`` (then ``show`` / ``export``) — runs cost-model-only on
CPU CI.
"""
from __future__ import annotations

import importlib
from contextlib import contextmanager
from typing import Any, Dict, Mapping, Optional

# public name -> defining submodule (resolved on first access)
_EXPORTS = {
    "Autotuner": "search", "AutotuneStats": "search", "TuneResult": "search",
    "TuningCache": "cache", "DEFAULT_CACHE_PATH": "cache",
    "entry_key": "cache", "split_key": "cache",
    "TUNABLES": "space", "Tunable": "space", "get_tunable": "space",
    "shape_bucket": "space", "tunable_names": "space",
    "vmem_budget_bytes": "space", "divisor_clamp": "space",
}
_SUBMODULES = ("cache", "cli", "search", "space")


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module(
            f"repro.core.autotune.{_EXPORTS[name]}")
        return getattr(mod, name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.core.autotune.{name}")
    raise AttributeError(
        f"module 'repro.core.autotune' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS) | set(_SUBMODULES))


# the process-global dispatch handle (None = every tuned=True lookup is a
# no-op and kernels fall back to their MXU-aligned defaults)
_ACTIVE = None


def install(tuner) -> Optional[Any]:
    """Make ``tuner`` the process-global autotuner; returns the previous
    one so callers can restore it (``train.loop`` does)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, tuner
    return prev


def active():
    return _ACTIVE


@contextmanager
def using(tuner):
    """Scoped :func:`install`."""
    prev = install(tuner)
    try:
        yield tuner
    finally:
        install(prev)


def tuned_config(kernel: str, shapes: Mapping[str, int],
                 dtype: str = "bf16") -> Optional[Dict[str, Any]]:
    """The kernel-dispatch lookup: the installed autotuner's cached config
    for this problem, or None (kernel not tunable / no handle / no entry)."""
    if _ACTIVE is None:
        return None
    return _ACTIVE.lookup(kernel, shapes, dtype)
