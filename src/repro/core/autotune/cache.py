"""Schema-versioned persistent tuning cache.

One JSON document (default ``results/autotune/cache.json``) holding one
entry per tuning key ``(kernel, shape-bucket, dtype, device_kind,
calibration_id)``.  Entries carry the winning config, the predicted
default/best step times, the optional measured refinement, and the top of
the ranked candidate table, so ``show``/``export`` can replay a tuning
decision without re-searching.

Writes are atomic (tmp + rename, the ``campaign.results`` discipline) and
the document round-trips losslessly: ``load`` of a ``save`` reproduces the
entry map exactly.  JSON that is not a cache (no ``kind`` tag) is refused
loudly — pointing ``--cache`` at some other artifact must never silently
overwrite it — as are newer schema versions; older versions keep their
metadata and start with an empty entry map.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Any, Dict, Iterator, Mapping, Optional, Tuple

SCHEMA_KIND = "autotune_cache"
SCHEMA_VERSION = 1

DEFAULT_CACHE_PATH = Path("results") / "autotune" / "cache.json"

_KEY_SEP = "|"


def entry_key(kernel: str, shape_bucket: str, dtype: str,
              device_kind: str, calibration_id: str) -> str:
    """The canonical cache key.  All five components are part of it: a
    cache tuned against one calibration (or device) never leaks configs
    onto another."""
    parts = (kernel, shape_bucket, dtype, device_kind, calibration_id)
    for p in parts:
        if _KEY_SEP in p:
            raise ValueError(f"cache key component {p!r} contains "
                             f"{_KEY_SEP!r}")
    return _KEY_SEP.join(parts)


def split_key(key: str) -> Tuple[str, str, str, str, str]:
    parts = key.split(_KEY_SEP)
    if len(parts) != 5:
        raise ValueError(f"malformed cache key {key!r}")
    return tuple(parts)  # type: ignore[return-value]


def new_document() -> Dict[str, Any]:
    return {"kind": SCHEMA_KIND, "version": SCHEMA_VERSION,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"), "entries": {}}


def validate(doc: Dict[str, Any]) -> Dict[str, Any]:
    if not isinstance(doc, dict):
        raise ValueError("autotune cache must be a JSON object")
    if doc.get("kind") != SCHEMA_KIND:
        # refusing kind-less JSON is what keeps `--cache <some-other-
        # artifact>.json` a loud error instead of a silent overwrite
        raise ValueError(f"not an autotune cache (kind={doc.get('kind')!r}, "
                         f"expected {SCHEMA_KIND!r})")
    version = doc.get("version", 0)
    if version > SCHEMA_VERSION:
        raise ValueError(
            f"autotune cache schema v{version} is newer than supported "
            f"v{SCHEMA_VERSION}; upgrade the repo to read this file")
    if version < SCHEMA_VERSION:
        # older minor versions carry no entries this code can trust; the
        # metadata survives and tuning re-fills the map
        doc = {**new_document(), "created": doc.get("created", "")}
    if not isinstance(doc.get("entries"), dict):
        raise ValueError("autotune cache 'entries' must be an object")
    for key, rec in doc["entries"].items():
        split_key(key)
        if "config" not in rec:
            raise ValueError(f"cache entry {key!r} missing 'config'")
    return doc


class TuningCache:
    """Entry store for tuned kernel configs.

    ``path=None`` keeps the cache purely in memory (tests, throwaway
    searches); with a path, every ``put`` persists atomically and a fresh
    ``TuningCache(path)`` sees exactly what was written.
    """

    def __init__(self, path: "os.PathLike | str | None" = DEFAULT_CACHE_PATH):
        self.path = Path(path) if path is not None else None
        if self.path is not None and self.path.exists():
            self.doc = validate(json.loads(self.path.read_text()))
        else:
            self.doc = new_document()

    # ----- core map ----------------------------------------------------------

    @property
    def entries(self) -> Dict[str, Dict[str, Any]]:
        return self.doc["entries"]

    def __len__(self) -> int:
        return len(self.entries)

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        return self.entries.get(key)

    def put(self, key: str, entry: Mapping[str, Any],
            flush: bool = True) -> None:
        split_key(key)   # refuse malformed keys at write time
        self.entries[key] = dict(entry)
        if flush:
            self.flush()

    def items(self, kernel: Optional[str] = None
              ) -> Iterator[Tuple[str, Dict[str, Any]]]:
        for key in sorted(self.entries):
            if kernel is None or split_key(key)[0] == kernel:
                yield key, self.entries[key]

    # ----- persistence -------------------------------------------------------

    def flush(self) -> None:
        """Atomic write; a no-op for in-memory caches."""
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(self.doc, indent=1, sort_keys=True))
        os.replace(tmp, self.path)

    def export(self, out_path: "os.PathLike | str") -> Path:
        """Write the full document (canonical, sorted) to ``out_path``."""
        out = Path(out_path)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(validate(self.doc), indent=1,
                                  sort_keys=True))
        return out
