"""Three-term roofline over dry-run artifacts — now a thin adapter over
``repro.core.costmodel``.

  compute    = census_FLOPs            / MXU-layer throughput   (per device)
  memory     = HBM bytes               / memory-layer bandwidth (per device)
  collective = collective wire bytes   / (links x link bw)      (per device)

FLOPs and collective bytes come from ``repro.core.isa.hlo_census`` (while-
loop trip counts multiplied through).  For the MEMORY term two estimates are
reported:

  * ``mem_census``   - every top-level HLO op's operand+result bytes.  An
    UPPER bound: XLA:CPU (the dry-run backend) fuses less than XLA:TPU, so
    op-boundary tensors that would stay in VMEM on TPU are counted as HBM
    round-trips here.
  * ``mem_analytic`` - a LOWER bound from first principles (moved to
    ``repro.core.costmodel.analytic``): parameter/optimizer-state streaming,
    activation checkpoints, KV-cache traffic, logits.  This is the roofline
    memory term; the census value bounds the error from above.

The bottleneck is whichever term dominates; MODEL_FLOPS/HLO_FLOPs measures
how much compiled compute is "useful" (remat, head-padding and dispatch
waste show up here).  The term arithmetic itself is ``CostModel.predict``
over a spec-only calibration; ``Roofline.step_s`` stays the pure
max-of-terms (no issue overhead) the dry-run tables always reported.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs import SHAPE_CELLS, get_config
# re-exported for compatibility: the byte models moved to costmodel.analytic
from repro.core.costmodel.analytic import (_param_bytes,  # noqa: F401
                                           analytic_serve_bytes,
                                           analytic_train_bytes, cache_bytes)
from repro.core.costmodel.model import CostModel
from repro.core.perfmodel.hardware import SPECS, TPU_V5E, HardwareSpec  # noqa: F401

_cache_bytes = cache_bytes   # old private name, still imported elsewhere


@dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    compute_s: float
    memory_s: float
    memory_census_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    step_s: float
    hw: str = "tpu-v5e"

    def table_row(self) -> Dict:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_census_s": self.memory_census_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "step_s": self.step_s,
        }


def from_dryrun(result: Dict, hw: HardwareSpec = TPU_V5E) -> Roofline:
    """Build the roofline from a dry-run JSON record via the cost model."""
    cfg = get_config(result["arch"])
    cell = SHAPE_CELLS[result["cell"]]
    n_dev = result["n_devices"]
    cens = result["census"]

    model = CostModel.from_hardware(hw)
    if cell.kind == "train":
        mem_b = analytic_train_bytes(cfg, cell, n_dev,
                                     result.get("accum_steps", 1))
    else:
        mem_b = analytic_serve_bytes(cfg, cell, n_dev)
    pred = model.predict(cens, spec=hw, mem_bytes=mem_b, dtype="bf16")
    memory_census_s = model.memory.transfer_seconds(cens["hbm_bytes"])

    model_flops_dev = result["model_flops_global"] / n_dev
    useful = model_flops_dev / max(cens["flops"], 1.0)
    step_s = max(pred.compute_s, pred.memory_s, pred.collective_s)
    return Roofline(
        arch=result["arch"], cell=result["cell"], mesh=result["mesh"],
        compute_s=pred.compute_s, memory_s=pred.memory_s,
        memory_census_s=memory_census_s, collective_s=pred.collective_s,
        bottleneck=pred.bottleneck, model_flops=model_flops_dev,
        hlo_flops=cens["flops"], useful_ratio=useful,
        step_s=step_s, hw=hw.name)


def roofline_fraction(r: Roofline, hw: HardwareSpec = TPU_V5E) -> float:
    """Fraction of the hardware roofline achieved: the ratio of the ideal
    model-FLOPs time to the modelled step time (a dry-run MFU analogue)."""
    ideal = r.model_flops / hw.peak_flops_bf16
    return ideal / max(r.step_s, 1e-12)
