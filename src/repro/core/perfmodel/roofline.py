"""Three-term roofline model over dry-run artifacts.

  compute    = census_FLOPs            / peak_FLOP/s          (per device)
  memory     = HBM bytes               / HBM bandwidth        (per device)
  collective = collective wire bytes   / (links x link bw)    (per device)

FLOPs and collective bytes come from `repro.core.isa.hlo_census` (while-loop
trip counts multiplied through).  For the MEMORY term two estimates are
reported:

  * ``mem_census``   - every top-level HLO op's operand+result bytes.  An
    UPPER bound: XLA:CPU (the dry-run backend) fuses less than XLA:TPU, so
    op-boundary tensors that would stay in VMEM on TPU are counted as HBM
    round-trips here.
  * ``mem_analytic`` - a LOWER bound from first principles: parameter/
    optimizer-state streaming, activation checkpoints, KV-cache traffic,
    logits.  This is the roofline memory term; the census value bounds the
    error from above.

The bottleneck is whichever term dominates; MODEL_FLOPS/HLO_FLOPs measures
how much compiled compute is "useful" (remat, head-padding and dispatch
waste show up here).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs import SHAPE_CELLS, get_config
from repro.core.perfmodel.hardware import SPECS, TPU_V5E, HardwareSpec
from repro.models.zoo import count_active_params, count_params


def _param_bytes(cfg) -> int:
    return count_params(cfg) * 4          # f32 master weights


def analytic_train_bytes(cfg, cell, n_devices: int, accum: int) -> float:
    """Per-device HBM bytes for one train step (lower-bound model)."""
    P = _param_bytes(cfg)
    n_model = 16
    n_data = n_devices // n_model
    P_dev = P / n_devices                 # FSDP+TP fully sharded storage
    P_stream = P / n_model                # gathered weights a device consumes
    tokens_dev = cell.global_batch * cell.seq_len / n_data
    d = cfg.d_model
    L = cfg.n_layers
    # forward + recompute + backward each stream the (gathered) weights once,
    # in bf16 compute copies (half the f32 master bytes)
    weights = 3 * accum * P_stream * 0.5
    # gradient accumulation buffer read+write per microstep (f32, sharded)
    grads = 2 * accum * (P / n_devices) * 4 / 4
    # optimizer: read p,m,v + write p,m,v (f32, sharded)
    opt = 6 * P_dev
    # activation checkpoints: write fwd, read bwd (bf16) - one carry per layer
    acts = 2 * L * tokens_dev * d * 2
    # logits written+read in f32 (vocab sharded over model axis)
    logits = 2 * tokens_dev * cfg.vocab_size / n_model * 4
    return weights + grads + opt + acts + logits


def analytic_serve_bytes(cfg, cell, n_devices: int) -> float:
    """Per-device HBM bytes for one serve step (prefill or decode)."""
    P = _param_bytes(cfg)
    n_model = 16
    P_stream = P / n_model * 2 / 4        # bf16 weights, TP sharded
    if cfg.moe and cell.kind == "decode":
        # decode touches only active experts' weights
        act_frac = count_active_params(cfg) / count_params(cfg)
        P_stream *= act_frac
    if cell.kind == "prefill":
        n_data = n_devices // n_model
        tokens_dev = cell.global_batch * cell.seq_len / n_data
        d = cfg.d_model
        acts = 2 * cfg.n_layers * tokens_dev * d * 2
        cache = _cache_bytes(cfg, cell) / n_devices
        return P_stream + acts + cache
    # decode: read the whole cache + stream weights once
    cache = 2 * _cache_bytes(cfg, cell) / n_devices
    return P_stream + cache


def _cache_bytes(cfg, cell) -> float:
    B, S, L = cell.global_batch, cell.seq_len, cfg.n_layers
    if cfg.rwkv:
        H = cfg.d_model // cfg.rwkv.head_dim
        return L * B * (H * cfg.rwkv.head_dim ** 2 * 4 + 2 * cfg.d_model * 2)
    if cfg.mla:
        return L * B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
    kv = L * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    if cfg.ssm:   # hybrid: + per-layer ssm state
        kv += L * B * cfg.d_model * cfg.ssm.state_dim * 4
    if cfg.encdec:
        kv = cfg.encdec.n_dec_layers * B * S * cfg.n_kv_heads \
            * cfg.head_dim * 2 * 2 * 2   # self + cross
    return kv


@dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    compute_s: float
    memory_s: float
    memory_census_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    step_s: float
    hw: str = "tpu-v5e"

    def table_row(self) -> Dict:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_census_s": self.memory_census_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "step_s": self.step_s,
        }


def from_dryrun(result: Dict, hw: HardwareSpec = TPU_V5E) -> Roofline:
    """Build the roofline from a dry-run JSON record."""
    cfg = get_config(result["arch"])
    cell = SHAPE_CELLS[result["cell"]]
    n_dev = result["n_devices"]
    cens = result["census"]

    flops_dev = cens["flops"]
    compute_s = flops_dev / hw.peak_flops_bf16

    if cell.kind == "train":
        mem_b = analytic_train_bytes(cfg, cell, n_dev,
                                     result.get("accum_steps", 1))
    else:
        mem_b = analytic_serve_bytes(cfg, cell, n_dev)
    memory_s = mem_b / hw.hbm_bandwidth
    memory_census_s = cens["hbm_bytes"] / hw.hbm_bandwidth

    # prefer the TPU-width-adjusted wire bytes (XLA:CPU legalizes bf16 dots
    # to f32, inflating the measured collective width 2x vs the TPU target)
    coll_b = cens.get("collective_bytes_total_tpu",
                      cens["collective_bytes_total"])
    coll_bw = hw.ici_link_bandwidth * hw.ici_links
    collective_s = coll_b / coll_bw

    model_flops_dev = result["model_flops_global"] / n_dev
    useful = model_flops_dev / max(flops_dev, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        arch=result["arch"], cell=result["cell"], mesh=result["mesh"],
        compute_s=compute_s, memory_s=memory_s,
        memory_census_s=memory_census_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops_dev,
        hlo_flops=flops_dev, useful_ratio=useful,
        step_s=max(terms.values()), hw=hw.name)


def roofline_fraction(r: Roofline, hw: HardwareSpec = TPU_V5E) -> float:
    """Fraction of the hardware roofline achieved: the ratio of the ideal
    model-FLOPs time to the modelled step time (a dry-run MFU analogue)."""
    ideal = r.model_flops / hw.peak_flops_bf16
    return ideal / max(r.step_s, 1e-12)
