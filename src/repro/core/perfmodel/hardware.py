"""Hardware specifications for the roofline / latency models.

TPU v5e is the deployment target (per-task hardware constants); the
NVIDIA A100 spec carries the paper's published numbers so the calibrated
tables can be cross-validated against the paper itself.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float          # per chip, FLOP/s
    peak_flops_f32: float
    hbm_bandwidth: float            # bytes/s per chip
    hbm_bytes: float                # capacity per chip
    ici_link_bandwidth: float       # bytes/s per link (one direction)
    ici_links: int                  # links per chip participating in a ring
    vmem_bytes: float = 0.0         # on-chip scratch (VMEM / L2+smem)
    mxu_shape: tuple = (128, 128)   # systolic array (TPU) / TC tile (GPU)
    clock_hz: float = 0.0
    # independent grid-execution lanes (TensorCore/SM count): a kernel
    # whose grid has fewer cells than this cannot reach peak bandwidth —
    # the under-utilization term split-KV decoding exists to fix
    n_cores: int = 1
    notes: str = ""


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,         # MXU f32 at half bf16 rate
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 2**30,
    ici_link_bandwidth=50e9,        # ~50 GB/s per link (task constant)
    ici_links=4,                    # 2D torus: 4 links/chip
    vmem_bytes=128 * 2**20,
    mxu_shape=(128, 128),
    clock_hz=940e6,
    n_cores=16,                     # modeled parallel grid lanes per chip
    notes="16GB HBM, 2D ring/torus ICI; one v5e pod = 16x16 = 256 chips",
)

A100_40G = HardwareSpec(
    name="a100-40g",
    peak_flops_bf16=312e12,         # TC dense bf16
    peak_flops_f32=19.5e12,         # CUDA-core fp32
    hbm_bandwidth=1555e9,
    hbm_bytes=40 * 2**30,
    ici_link_bandwidth=25e9,        # NVLink3 per direction per link
    ici_links=12,
    vmem_bytes=40 * 2**20,          # L2
    mxu_shape=(16, 8, 16),          # HMMA.16816 SASS tile (the paper, Tab.III)
    clock_hz=1410e6,
    n_cores=108,                    # SMs (the paper, Sec. II)
    notes="the paper's device (Tesla A100); Tables II-V calibrate this spec",
)

SPECS: Dict[str, HardwareSpec] = {s.name: s for s in (TPU_V5E, A100_40G)}
