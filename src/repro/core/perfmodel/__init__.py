from repro.core.perfmodel import hardware, predictor, roofline  # noqa
