"""Hardware specs + legacy predictor/roofline shims.

``predictor`` and ``roofline`` now delegate to ``repro.core.costmodel`` and
are loaded lazily (PEP 562) so the costmodel <-> perfmodel.hardware import
graph stays acyclic.
"""
import importlib

from repro.core.perfmodel import hardware  # noqa: F401

_LAZY = ("predictor", "roofline")


def __getattr__(name):
    if name in _LAZY:
        return importlib.import_module(f"repro.core.perfmodel.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
