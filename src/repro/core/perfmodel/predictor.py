"""Latency-table-driven performance prediction (the PPT-GPU analogue).

The paper's motivation: simulators predict kernel time from per-instruction
latency tables.  Here, given (a) an instruction census of a compiled module
(`repro.core.isa.hlo_census`) and (b) a hardware latency table
(`repro.core.calibration/*.json`), predict the per-device step time as

    t = max(compute, memory, collective) + issue_overhead

where `issue_overhead` prices the non-matmul instruction stream with the
per-op latencies from the table — the term instruction-latency papers exist
to calibrate.  For MXU-dominated programs the overhead is negligible; for
the RWKV6/Mamba recurrences (element-wise VPU chains, thousands of scanned
iterations) it is NOT, which is precisely the paper's point about needing
per-instruction data, not just peak-FLOPs specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.perfmodel.hardware import SPECS, TPU_V5E, HardwareSpec

# HLO op kind -> (table op, elementwise?) mapping: which latency-table entry
# prices each non-matmul HLO instruction (the ISA-mapping table, inverted).
_HLO_TO_TABLE = {
    "add": "add", "subtract": "sub", "multiply": "mul", "divide": "div",
    "maximum": "max", "minimum": "min", "abs": "abs", "negate": "sub",
    "and": "and", "or": "and", "xor": "xor", "not": "and",
    "exponential": "exp", "log": "log", "tanh": "tanh", "rsqrt": "rsqrt",
    "sqrt": "sqrt", "sine": "sin", "cosine": "sin", "logistic": "sigmoid",
    "select": "select", "compare": "select", "convert": "add",
    "reduce": "add", "reduce-window": "add", "broadcast": "add",
    "iota": "add", "reverse": "add", "transpose": "add", "reshape": "add",
    "concatenate": "add", "pad": "add", "slice": "add", "fusion": "fma",
    "dynamic-slice": "add", "dynamic-update-slice": "add", "gather": "add",
    "scatter": "add", "copy": "add", "rng": "add", "clamp": "select",
    "power": "exp", "remainder": "rem", "sign": "select", "floor": "add",
    "ceil": "add", "round-nearest-even": "add", "is-finite": "select",
    "exponential-minus-one": "exp", "log-plus-one": "log", "cbrt": "rsqrt",
    "atan2": "tanh", "erf": "tanh", "map": "fma", "sort": "select",
}


@dataclass
class Prediction:
    compute_s: float
    memory_s: float
    collective_s: float
    issue_overhead_s: float
    step_s: float
    bottleneck: str


def issue_overhead(op_histogram: Dict[str, float], table: Dict,
                   hw: HardwareSpec = TPU_V5E,
                   per_op_issue_cycles: float = 12.0) -> float:
    """Price the instruction stream: every top-level HLO op costs at least an
    issue slot; transcendental-class ops cost their table CPI.  This is the
    paper's Table V applied as a simulator input."""
    vpu = table.get("vpu", {})
    clock = hw.clock_hz or 1e9
    total_cycles = 0.0
    for kind, count in op_histogram.items():
        mapped = _HLO_TO_TABLE.get(kind)
        cpi = per_op_issue_cycles
        if mapped:
            ent = vpu.get(f"{mapped}.f32")
            if ent:
                cpi = max(per_op_issue_cycles, ent["cpi"] * 1.0)
        total_cycles += count * cpi
    return total_cycles / clock


def predict(census: Dict, mem_bytes_analytic: float, table: Dict,
            hw: HardwareSpec = TPU_V5E) -> Prediction:
    compute = census["flops"] / hw.peak_flops_bf16
    memory = mem_bytes_analytic / hw.hbm_bandwidth
    coll = census["collective_bytes_total"] / (hw.ici_link_bandwidth
                                               * hw.ici_links)
    issue = issue_overhead(census.get("op_histogram", {}), table, hw)
    terms = {"compute": compute, "memory": memory, "collective": coll}
    bott = max(terms, key=terms.get)
    return Prediction(compute_s=compute, memory_s=memory, collective_s=coll,
                      issue_overhead_s=issue,
                      step_s=max(terms.values()) + issue, bottleneck=bott)


def validate_against_paper(table: Dict) -> Dict:
    """Cross-check the shipped A100 calibration: the paper's own consistency
    relations (SASS expansion x per-SASS cycles == WMMA cycles; dependent
    CPI >= independent CPI; >=3-chain convergence) — run as unit tests."""
    checks = {}
    tc = table["tensor_core"]
    for k, v in tc.items():
        n = int(v["sass"].split("*")[0])
        checks[f"tc:{k}"] = (n * v["sass_cycles_each"] == v["cycles"]) or \
            (v["cycles"] <= n * v["sass_cycles_each"] + 8)
    for k, v in table["dependent_vs_independent"].items():
        checks[f"dep>=ind:{k}"] = v["dependent"] >= v["independent"]
    conv = table["cpi_convergence"]
    checks["chain_convergence"] = conv["1"] >= conv["2"] >= conv["3"] == conv["4"]
    return checks
