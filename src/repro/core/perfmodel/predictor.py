"""COMPAT SHIM over ``repro.core.costmodel`` — the prediction stack moved.

The table-driven predictor now lives in ``repro.core.costmodel``: a
normalized calibration (``Calibration.from_dict`` accepts the raw table
dicts this module used to take) feeding three explicit layers behind
``CostModel.predict``.  This module keeps the old entry points alive for
callers that still import ``perfmodel.predictor``; new code should use the
cost model directly:

    from repro.core.costmodel import CostModel
    CostModel.from_named("tpu_v5e").predict(census)
"""
from __future__ import annotations

from typing import Dict

from repro.core.costmodel.calibration import Calibration
from repro.core.costmodel.instruction import HLO_TO_TABLE as _HLO_TO_TABLE  # noqa: F401
from repro.core.costmodel.model import (CostModel, Prediction,  # noqa: F401
                                        validate_against_paper)
from repro.core.perfmodel.hardware import SPECS, TPU_V5E, HardwareSpec  # noqa: F401


def _model_for(table: Dict, hw: HardwareSpec) -> CostModel:
    cal = Calibration.from_dict(dict(table),
                                name=table.get("hardware", ""))
    # the old predictor priced CPI cycles at the TARGET hardware's clock
    # (tables carry CPIs normalized at their own assumed clock)
    if hw is not None and hw.clock_hz:
        cal.clock_hz = hw.clock_hz
    return CostModel(cal, hw=hw)


def issue_overhead(op_histogram: Dict[str, float], table: Dict,
                   hw: HardwareSpec = TPU_V5E,
                   per_op_issue_cycles: float = 12.0) -> float:
    """Old signature: price an instruction stream from a raw table dict."""
    model = _model_for(table, hw)
    model.instructions.issue_cycles = per_op_issue_cycles
    return model.instructions.price_histogram(op_histogram).seconds


def predict(census: Dict, mem_bytes_analytic: float, table: Dict,
            hw: HardwareSpec = TPU_V5E) -> Prediction:
    """Old signature: predict a step from a census + raw table dict."""
    model = _model_for(table, hw)
    return model.predict(census, spec=hw, mem_bytes=mem_bytes_analytic)
