"""Memory-hierarchy pointer-chase (the paper's §IV-B, Fig. 2/3, Table IV).

A random-cycle index array forces serially-dependent loads, exactly like the
paper's linked-list chase; sweeping the working-set size walks the levels of
the memory hierarchy.  On the CPU container this resolves L1/L2/DRAM (a
methodology demonstration); on TPU the working-set sweep resolves VMEM-
resident vs HBM-resident arrays (TPU has no hardware caches to bypass, so
the paper's `.cv/.cg/.ca` operator sweep becomes a memory-SPACE sweep —
see `repro.kernels.microbench_chase` for the in-kernel VMEM variant).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.microbench.harness import fit_latency, time_fn


def _random_cycle(n: int, seed: int = 0) -> np.ndarray:
    """A single n-cycle permutation: chase visits every slot exactly once."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    nxt = np.empty(n, np.int32)
    nxt[order[:-1]] = order[1:]
    nxt[order[-1]] = order[0]
    return nxt


def _chase_fn(hops: int):
    def f(arr, start):
        def body(_, i):
            return arr[i]
        return jax.lax.fori_loop(0, hops, body, start)
    return jax.jit(f)


@dataclass
class ChaseResult:
    working_set_bytes: int
    hops: List[int]
    times_s: List[float]
    overhead_s: float
    per_hop_s: float

    def per_hop_cycles(self, clock_hz: float) -> float:
        return self.per_hop_s * clock_hz


def run_chase(working_set_bytes: int, hop_counts: Sequence[int] = (256, 1024,
              4096), seed: int = 0) -> ChaseResult:
    n = max(working_set_bytes // 4, 16)
    arr = jnp.asarray(_random_cycle(n, seed))
    start = jnp.asarray(0, jnp.int32)
    times = []
    for h in hop_counts:
        f = _chase_fn(int(h))
        times.append(time_fn(f, arr, start, iters=20))
    a, b = fit_latency(hop_counts, times)
    return ChaseResult(working_set_bytes=working_set_bytes,
                       hops=list(map(int, hop_counts)), times_s=times,
                       overhead_s=max(a, 0.0), per_hop_s=max(b, 0.0))


def hierarchy_sweep(sizes=(16 * 2**10, 256 * 2**10, 4 * 2**20, 64 * 2**20)
                    ) -> List[ChaseResult]:
    return [run_chase(s) for s in sizes]


def streaming_bandwidth(size_bytes: int = 64 * 2**20) -> float:
    """Sequential-read bandwidth (the contrast to the chase's latency)."""
    n = size_bytes // 4
    x = jnp.arange(n, dtype=jnp.float32)
    f = jax.jit(lambda v: jnp.sum(v))
    t = time_fn(f, x, iters=20)
    return size_bytes / t
