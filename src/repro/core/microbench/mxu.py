"""Matrix-unit probes (the paper's §IV-C Tensor Core WMMA study).

The paper measures, per (dtype x fragment shape), the WMMA instruction's
latency and throughput and the PTX->SASS expansion (one m16n16k16 WMMA = two
HMMA.16816).  The TPU analogue: per (dtype x tile shape), the latency and
throughput of an MXU matmul, and the StableHLO dot -> fused-HLO expansion
seen in the compiled module.  The shape sweep uses multiples/fractions of
the 128x128 systolic array (the hardware tile) the way the paper sweeps
m16n16k16 / m8n32k16 / m32n8k16 fragments.

A dependent chain (C <- A@C) measures LATENCY; a batch of independent
matmuls measures THROUGHPUT — the same dependent/independent split the
paper applies to scalar instructions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.microbench.harness import fit_latency, time_fn


@dataclass
class MXUResult:
    dtype: str
    shape: Tuple[int, int, int]          # (m, n, k)
    dependent: bool
    per_op_s: float
    overhead_s: float
    flops: float
    tflops: float


def _dep_chain(k: int, preferred=None):
    def f(a, c):
        y = c
        for _ in range(k):
            y = jax.lax.dot(a, y, precision=None,
                            preferred_element_type=preferred)
            y = (y * 0.001).astype(c.dtype)
        return y
    return jax.jit(f)


def _indep_batch(k: int, preferred=None):
    def f(a, cs):
        return jnp.stack([
            jax.lax.dot(a, cs[i], preferred_element_type=preferred)
            for i in range(k)])
    return jax.jit(f)


def run_mxu(dtype="bfloat16", shape=(128, 128, 128), dependent=True,
            lengths: Sequence[int] = (1, 2, 4, 8)) -> MXUResult:
    m, n, k = shape
    dt = jnp.dtype(dtype)
    preferred = jnp.float32 if dt != jnp.float32 else None
    a = (jnp.ones((m, k), jnp.float32) * 0.01).astype(dt)
    times = []
    for L in lengths:
        if dependent:
            c = (jnp.ones((k, n), jnp.float32) * 0.01).astype(dt)
            f = _dep_chain(int(L), preferred)
            times.append(time_fn(f, a, c, iters=10))
        else:
            cs = (jnp.ones((int(L), k, n), jnp.float32) * 0.01).astype(dt)
            f = _indep_batch(int(L), preferred)
            times.append(time_fn(f, a, cs, iters=10))
    ov, per = fit_latency(lengths, times)
    flops = 2.0 * m * n * k
    return MXUResult(dtype=str(dt.name), shape=(m, n, k), dependent=dependent,
                     per_op_s=max(per, 1e-12), overhead_s=max(ov, 0.0),
                     flops=flops, tflops=flops / max(per, 1e-12) / 1e12)


def shape_sweep(dtypes=("bfloat16", "float32"),
                shapes=((128, 128, 128), (256, 256, 256), (512, 512, 512),
                        (128, 128, 512), (512, 512, 128))) -> List[MXUResult]:
    out = []
    for dt in dtypes:
        for s in shapes:
            for dep in (True, False):
                out.append(run_mxu(dt, s, dep))
    return out
