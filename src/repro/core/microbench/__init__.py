from repro.core.microbench import harness, memory, mxu, tables  # noqa
