"""Latency tables: load calibration data, run the microbench suite, persist
refreshed tables (the paper's deliverable is exactly such a table)."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, Optional

import jax

CALIB_DIR = Path(__file__).resolve().parents[2] / "core" / "calibration"


def load_table(name: str) -> Dict:
    return json.loads((CALIB_DIR / f"{name}.json").read_text())


def ampere_table() -> Dict:
    return load_table("ampere_a100")


def v5e_table() -> Dict:
    return load_table("tpu_v5e")


def calibrate(out_path: Optional[Path] = None, quick: bool = True) -> Dict:
    """Run the full microbench suite on the CURRENT backend and emit a table
    in the calibration format.  On a real TPU this refreshes tpu_v5e.json;
    on CPU it demonstrates the methodology (documented in the table)."""
    from repro.core.microbench import harness, memory, mxu

    backend = jax.default_backend()
    dtypes = ("float32", "int32") if quick else ("float32", "bfloat16",
                                                 "int32")
    lengths = (4, 16, 64) if quick else (4, 16, 64, 256)
    chain = harness.default_suite(dtypes=dtypes, lengths=lengths)
    chases = memory.hierarchy_sweep(
        sizes=(16 * 2**10, 4 * 2**20) if quick
        else (16 * 2**10, 256 * 2**10, 4 * 2**20, 64 * 2**20))
    mxus = mxu.shape_sweep(
        dtypes=("float32",) if quick else ("bfloat16", "float32"),
        shapes=((128, 128, 128), (256, 256, 256)) if quick else None
        or ((128, 128, 128), (256, 256, 256)))

    table = {
        "hardware": backend,
        "source": f"repro.core.microbench run at {time.strftime('%F %T')}",
        "methodology": "chain-length regression (paper Fig.1/Table I), "
                       "dependent vs independent (Table II), pointer chase "
                       "(Fig.2, Table IV), matrix-unit probes (Table III)",
        "ops": {
            f"{r.op}.{r.dtype}.{'dep' if r.dependent else 'ind'}": {
                "per_op_ns": r.per_op_s * 1e9,
                "overhead_ns": r.overhead_s * 1e9,
                "cpi_curve": r.cpi_curve,
            } for r in chain
        },
        "memory": {
            str(r.working_set_bytes): {
                "per_hop_ns": r.per_hop_s * 1e9,
                "overhead_ns": r.overhead_s * 1e9,
            } for r in chases
        },
        "mxu": {
            f"{r.dtype}.m{r.shape[0]}n{r.shape[1]}k{r.shape[2]}."
            f"{'dep' if r.dependent else 'ind'}": {
                "per_op_us": r.per_op_s * 1e6,
                "tflops": r.tflops,
            } for r in mxus
        },
    }
    if out_path:
        Path(out_path).write_text(json.dumps(table, indent=1))
    return table
