"""Latency tables: load shipped calibrations, run the measurement campaigns,
persist refreshed tables (the paper's deliverable is exactly such a table).

Measurement is delegated to the campaign runner (``repro.core.campaign``):
``calibrate`` runs the four calibration experiments through the scheduler —
so a partially-finished calibration resumes instead of restarting — and
converts the persisted, schema-versioned results into the calibration-table
format the cost model (``repro.core.costmodel``) consumes; its loaders
normalize any of these tables into the instruction/memory/MXU layers.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, Optional

CALIB_DIR = Path(__file__).resolve().parents[2] / "core" / "calibration"

# the experiments whose results make up a calibration table
CALIBRATION_EXPERIMENTS = ("alu_chain", "memory_chase", "mxu_shapes",
                           "roofline_calibration")


def load_table(name: str) -> Dict:
    return json.loads((CALIB_DIR / f"{name}.json").read_text())


def ampere_table() -> Dict:
    """The paper's own A100 numbers (Tables II-V), shipped with the repo."""
    return load_table("ampere_a100")


def v5e_table() -> Dict:
    """The TPU v5e deployment-target table."""
    return load_table("tpu_v5e")


def table_from_results(results_dir: Path | str,
                       experiments: Iterable[str] = CALIBRATION_EXPERIMENTS,
                       clock_hz: Optional[float] = None) -> Dict:
    """Build a calibration table from campaign result files alone — no
    re-measurement.  This is how measured tables feed the predictor."""
    from repro.core.campaign import report as campaign_report
    from repro.core.campaign.results import load_results_dir

    docs = load_results_dir(results_dir, experiments)
    if not docs:
        raise FileNotFoundError(
            f"no campaign results for {tuple(experiments)} in {results_dir}; "
            "run `python -m repro.core.campaign run all` first")
    return campaign_report.calibration_from_results(docs, clock_hz=clock_hz)


def calibrate(out_path: Optional[Path] = None, quick: bool = True,
              results_dir: Optional[Path | str] = None) -> Dict:
    """Run the calibration campaigns on the CURRENT backend and emit a table
    in the calibration format.  On a real TPU this refreshes tpu_v5e.json;
    on CPU it characterizes the host (the methodology demonstration).

    Campaign results persist under ``results_dir`` (default
    ``results/campaign``); already-measured cells are skipped on rerun, so
    an interrupted calibration resumes where it stopped.
    """
    from repro.core.campaign import report as campaign_report
    from repro.core.campaign import runner as campaign_runner
    from repro.core.campaign.results import load_results

    results_dir = Path(results_dir or campaign_runner.DEFAULT_RESULTS_DIR)
    reports = campaign_runner.run_many(CALIBRATION_EXPERIMENTS,
                                       out_dir=results_dir, quick=quick)
    docs = {name: load_results(rep.path) for name, rep in reports.items()}
    table = campaign_report.calibration_from_results(docs)
    if out_path:
        Path(out_path).write_text(json.dumps(table, indent=1))
    return table
