"""Measurement harness reproducing the paper's methodology on JAX arrays.

Paper methodology (§IV-A) -> here:
  * clock reads around an instruction sequence  -> wall-clock around a jit'd
    op chain with block_until_ready (on TPU, the Pallas kernels in
    repro.kernels measure in-kernel; this harness is the portable layer);
  * >=3 instructions to amortize launch overhead (Table I) -> we sweep chain
    length K and report CPI(K); the paper's "first instruction costs 5,
    steady state costs 2" behaviour reproduces as a falling t(K)/K curve;
  * clock overhead subtraction (2 cycles) -> linear regression t(K) = a + bK;
    the intercept a IS the measured launch/dispatch overhead and b the
    steady-state per-op latency;
  * dependent vs independent sequences (Table II) -> chains threaded through
    one value vs K parallel values.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn, *args, iters: int = 30, warmup: int = 5) -> float:
    """Median wall-time of fn(*args) in seconds (jit-compiled outside)."""
    for _ in range(warmup):
        # block INSIDE the loop: async dispatch would otherwise queue all
        # warmup work and bill it to the first timed iteration
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def fit_latency(lengths: Sequence[int], times: Sequence[float]) -> Tuple[float, float]:
    """Least-squares t = a + b*K -> (overhead a, per-op latency b)."""
    k = np.asarray(lengths, np.float64)
    t = np.asarray(times, np.float64)
    b, a = np.polyfit(k, t, 1)
    return float(a), float(b)


@dataclass
class ChainResult:
    op: str
    dtype: str
    dependent: bool
    lengths: List[int]
    times_s: List[float]
    overhead_s: float
    per_op_s: float
    cpi_curve: Dict[int, float]   # t(K)/(K*t_inf) — the paper's Table I shape

    def per_op_cycles(self, clock_hz: float) -> float:
        return self.per_op_s * clock_hz


def _chain_fn(op: Callable, k: int, dependent: bool):
    """Build a jit'd function executing k ops over an (8,128) VPU-shaped tile."""
    if dependent:
        def f(x, c):
            y = x
            for _ in range(k):
                y = op(y, c)
            return y
    else:
        def f(x, c):
            # k independent ops on k slices, combined once at the end
            ys = [op(x + i, c) for i in range(k)]
            out = ys[0]
            for y in ys[1:]:
                out = out + y * 0  # keep all live without a dependency chain
            return out
    return jax.jit(f)


def run_chain(op: Callable, name: str, dtype=jnp.float32,
              lengths: Sequence[int] = (4, 16, 64, 256),
              dependent: bool = True, shape=(64, 512)) -> ChainResult:
    """shape defaults to a tile large enough that one op's cost is above the
    host timer/dispatch noise floor (on TPU the Pallas twin of this harness
    uses the native (8,128) VPU tile and in-kernel iteration instead)."""
    x = jnp.linspace(0.5, 1.5, int(np.prod(shape)),
                     dtype=jnp.float32).reshape(shape).astype(dtype)
    c = jnp.asarray(1.0009765625, dtype)  # keeps chains numerically tame
    times = []
    for k in lengths:
        f = _chain_fn(op, int(k), dependent)
        times.append(time_fn(f, x, c))
    a, b = fit_latency(lengths, times)
    # robust steady-state per-op estimate: regression slope, floored by the
    # longest chain's overhead-corrected mean (slope ~ 0 under timer noise)
    t_longest = max((times[-1] - max(a, 0.0)) / lengths[-1], 0.0)
    t_inf = max(b, t_longest, 1e-12)
    cpi_curve = {int(k): float(t / (k * t_inf))
                 for k, t in zip(lengths, times)}
    return ChainResult(op=name, dtype=str(jnp.dtype(dtype).name),
                       dependent=dependent, lengths=list(map(int, lengths)),
                       times_s=times, overhead_s=max(a, 0.0),
                       per_op_s=max(b, t_longest, 0.0), cpi_curve=cpi_curve)


# --- the op registry (the paper's Table V rows, dtype-major) ----------------

OPS: Dict[str, Callable] = {
    "add": lambda y, c: y + c,
    "sub": lambda y, c: y - c,
    "mul": lambda y, c: y * c,
    "fma": lambda y, c: y * c + c,
    "max": lambda y, c: jnp.maximum(y, c),
    "min": lambda y, c: jnp.minimum(y, c),
    "abs": lambda y, c: jnp.abs(y) + c * 0,
    "and": lambda y, c: y & c,
    "xor": lambda y, c: y ^ c,
    "popc": lambda y, c: jax.lax.population_count(y) + c * 0,
    "clz": lambda y, c: jax.lax.clz(y) + c * 0,
    "div": lambda y, c: y / c,
    "rem": lambda y, c: y % c,
    "rsqrt": lambda y, c: jax.lax.rsqrt(jnp.abs(y) + c * 0 + 1e-6),
    "sqrt": lambda y, c: jnp.sqrt(jnp.abs(y)) + c * 0,
    "exp": lambda y, c: jnp.exp(y * 0.001) + c * 0,
    "log": lambda y, c: jnp.log(jnp.abs(y) + 1.0) + c * 0,
    "sin": lambda y, c: jnp.sin(y) + c * 0,
    "tanh": lambda y, c: jnp.tanh(y) + c * 0,
    "sigmoid": lambda y, c: jax.nn.sigmoid(y) + c * 0,
    "select": lambda y, c: jnp.where(y > c, y, c),
}

INT_OPS = {"and", "xor", "popc", "clz"}
FLOAT_ONLY = {"rsqrt", "sqrt", "exp", "log", "sin", "tanh", "sigmoid",
              "div", "fma"}


def default_suite(dtypes=("float32", "bfloat16", "int32"),
                  lengths=(4, 16, 64, 256)) -> List[ChainResult]:
    out = []
    for dt in dtypes:
        isint = jnp.issubdtype(jnp.dtype(dt), jnp.integer)
        for name, op in OPS.items():
            if isint and name in FLOAT_ONLY:
                continue
            if not isint and name in INT_OPS:
                continue
            for dep in (True, False):
                out.append(run_chain(op, name, jnp.dtype(dt), lengths, dep))
    return out
