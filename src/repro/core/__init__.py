from repro.core import isa, microbench, perfmodel  # noqa
