from repro.core import isa, microbench, perfmodel  # noqa
from repro.core import campaign  # noqa  (last: depends on the above)
