"""The measurement/model/tuning core.

Submodules load lazily (PEP 562): the analytic consumers — the cost-model
and autotune CLIs, calibration loading, candidate ranking — must answer
without importing jax, which ``microbench``/``isa`` pull in eagerly.
"""
import importlib

_SUBMODULES = ("autotune", "campaign", "costmodel", "isa", "microbench",
               "perfmodel")


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
