from repro.core import costmodel, isa, microbench, perfmodel  # noqa
from repro.core import campaign  # noqa  (last: depends on the above)
