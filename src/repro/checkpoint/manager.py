"""Sharded, atomic, async checkpointing (tensorstore-free).

Layout:  <dir>/step_<N>/
           manifest.json          tree structure + leaf metadata
           shard_<leafid>.npy     one file per leaf (addressable per device
                                  group when used under multi-host jax)
         <dir>/LATEST             atomic pointer (rename) to the last
                                  COMPLETE step - a crashed save can never
                                  be picked up by a restart.

Fault-tolerance contract used by repro.train.loop:
  * saves are atomic (tmp dir + rename) and retention-pruned;
  * `restore_latest` returns (step, state) or None - restart-from-step-0
    and restart-mid-run share one code path;
  * an optional background thread makes saves async so the step loop never
    blocks on disk (overlap of checkpoint I/O with compute).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False):
        self.wait()  # one in-flight save at a time
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(x) for x in leaves]  # device->host copy now
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, treedef)

    def _write(self, step, host_leaves, treedef):
        try:
            final = self.dir / f"step_{step:08d}"
            tmp = Path(tempfile.mkdtemp(prefix=".tmp_save_", dir=self.dir))
            manifest = {"step": step, "treedef": str(treedef),
                        "n_leaves": len(host_leaves),
                        "leaves": [{"dtype": str(x.dtype),
                                    "shape": list(x.shape)}
                                   for x in host_leaves]}
            for i, x in enumerate(host_leaves):
                np.save(tmp / f"shard_{i:05d}.npy", x)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._update_latest(step)
            self._prune()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _update_latest(self, step):
        tmp = self.dir / ".LATEST.tmp"
        tmp.write_text(str(step))
        os.replace(tmp, self.dir / "LATEST")

    def _prune(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if not f.exists():
            steps = self.all_steps()
            return steps[-1] if steps else None
        try:
            step = int(f.read_text().strip())
        except ValueError:
            return None
        return step if (self.dir / f"step_{step:08d}").exists() else None

    def restore(self, step: int, like: Any = None,
                shardings: Any = None) -> Any:
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        host = [np.load(d / f"shard_{i:05d}.npy")
                for i in range(manifest["n_leaves"])]
        if like is None:
            raise ValueError("pass `like` (a pytree prototype) to restore")
        leaves, treedef = _flatten(like)
        assert len(leaves) == len(host), "checkpoint/tree mismatch"
        if shardings is not None:
            sh_leaves, _ = _flatten(shardings)
            host = [jax.device_put(x, s) for x, s in zip(host, sh_leaves)]
        else:
            host = [jax.device_put(np.asarray(x).astype(l.dtype))
                    for x, l in zip(host, leaves)]
        return jax.tree.unflatten(treedef, host)

    def restore_latest(self, like: Any = None,
                       shardings: Any = None) -> Optional[Tuple[int, Any]]:
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, like, shardings)
