from repro.data.synthetic import DataConfig, Prefetcher, SyntheticLM  # noqa
