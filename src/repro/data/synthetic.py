"""Deterministic synthetic LM data (shard-aware, restart-reproducible).

A counter-based generator: batch i of epoch e is a pure function of
(seed, step), so a restarted job resumes mid-epoch with identical batches —
the data-side half of fault tolerance.  The token stream is a mixture of
Zipfian unigrams and deterministic motifs so the loss actually falls during
the example runs (pure-uniform tokens give a flat loss).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    n_motifs: int = 64


class SyntheticLM:
    """Stateless-per-step synthetic corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self._probs = p / p.sum()
        self._motifs = rng.integers(0, v, size=(cfg.n_motifs, cfg.motif_len),
                                    dtype=np.int64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._probs)
        # plant motifs: predictable spans the model can learn
        mlen = min(cfg.motif_len, (S + 1) // 2)
        n_plant = max(1, S // (4 * mlen))
        for b in range(B):
            ids = rng.integers(0, cfg.n_motifs, size=n_plant)
            pos = rng.integers(0, max(S + 1 - mlen, 1), size=n_plant)
            for m, p0 in zip(ids, pos):
                toks[b, p0:p0 + mlen] = self._motifs[m][:mlen]
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def shard_batch(batch, mesh, input_shardings):
    """Host numpy batch -> sharded global jax.Arrays for the mesh."""
    def put(x, sh):
        return jax.make_array_from_process_local_data(sh, x)
    return jax.tree.map(put, batch, input_shardings)


class Prefetcher:
    """One-batch-ahead prefetch: overlaps host data generation with the
    device step (the classic input-pipeline/compute overlap)."""

    def __init__(self, it: Iterator, transform=None):
        self._it = it
        self._tf = transform or (lambda x: x)
        self._next = self._tf(next(self._it))

    def __iter__(self):
        return self

    def __next__(self):
        cur = self._next
        self._next = self._tf(next(self._it))
        return cur
