"""Compute/communication overlap helpers.

On TPU, XLA's latency-hiding scheduler already overlaps the collectives the
partitioner inserts with independent compute inside each scanned layer; the
knobs here cover what the scheduler cannot do by itself:

  * `async_offload(fn)`      — run a host-side side effect (checkpoint write,
    metrics flush) on a worker thread so the device step never blocks;
  * `double_buffer(it)`      — device-prefetch one batch ahead (generalizes
    data.synthetic.Prefetcher to arbitrary iterators + device_put);
  * `microbatch_pipeline(..)`— interleave the gradient all-reduce of
    microstep i with the compute of microstep i+1 when gradient accumulation
    runs UNROLLED (opt-in; the default scan form leaves this to XLA).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax


def async_offload(fn: Callable, *args, **kwargs) -> threading.Thread:
    t = threading.Thread(target=fn, args=args, kwargs=kwargs, daemon=True)
    t.start()
    return t


class double_buffer:
    """Keep one device-resident batch in flight ahead of the consumer."""

    def __init__(self, it: Iterator, shardings: Optional[Any] = None):
        self._it = it
        self._sh = shardings
        self._next = self._put(next(it))

    def _put(self, batch):
        if self._sh is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(jax.device_put, batch, self._sh)

    def __iter__(self):
        return self

    def __next__(self):
        cur = self._next
        try:
            self._next = self._put(next(self._it))
        except StopIteration:
            self._next = None
            if cur is None:
                raise
        if cur is None:
            raise StopIteration
        return cur


def microbatch_pipeline(grad_fn: Callable, params, microbatches,
                        reduce_fn: Callable):
    """Unrolled accumulation with explicit overlap points: microstep i+1's
    forward/backward is issued before microstep i's cross-replica reduction
    is awaited (jax dispatch is async, so issuing order IS overlap order)."""
    reduced = []
    pending = None
    for mb in microbatches:
        g = grad_fn(params, mb)
        if pending is not None:
            reduced.append(pending)      # await previous reduction lazily
        pending = reduce_fn(g)           # issue reduction for this microstep
    reduced.append(pending)
    total = reduced[0]
    for g in reduced[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, g)
    return jax.tree.map(lambda x: x / len(reduced), total)
