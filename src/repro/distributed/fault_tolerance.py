"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-pod deployment each host runs a `HeartbeatRegistry` member
(backed by the cluster's coordination service); here the registry is
in-process but the POLICY layer — what the framework does about missing
heartbeats and stragglers — is the production logic and is fully unit
tested:

  * straggler mitigation: per-host step-time EWMA; hosts slower than
    `z_threshold` MADs from the fleet median are flagged, and the policy
    recommends checkpoint-and-evict before they stall the collectives
    (synchronous SPMD makes one straggler everyone's straggler);
  * failure handling: hosts missing `miss_limit` consecutive heartbeats are
    declared dead -> policy = restart from the last complete checkpoint with
    a re-formed (elastic) mesh, see repro.distributed.elastic;
  * restart budget: exponential backoff with a crash-loop breaker.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import defaultdict, deque
from typing import Dict, List, Optional


@dataclasses.dataclass
class HostState:
    host_id: str
    last_heartbeat: float = 0.0
    missed: int = 0
    step_times: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=32))
    ewma_s: float = 0.0
    alive: bool = True


class HeartbeatRegistry:
    """Membership is dynamic: the constructor list is a convenience for
    a fixed fleet, while :meth:`register`/:meth:`deregister` admit and
    remove hosts at runtime — a restarted replica rejoins under a fresh
    host id (its EWMA history died with the old process), and a declared-
    dead host is deregistered so it stops skewing the straggler median.
    ``beat`` for an unregistered host stays a loud ``KeyError``:
    membership changes are an explicit supervisor action, never a side
    effect of a stray heartbeat."""

    def __init__(self, hosts: Optional[List[str]] = None, *,
                 interval_s: float = 10.0,
                 miss_limit: int = 3, ewma_alpha: float = 0.2):
        self.hosts: Dict[str, HostState] = {h: HostState(h)
                                            for h in (hosts or ())}
        self.interval_s = interval_s
        self.miss_limit = miss_limit
        self.alpha = ewma_alpha

    # -- membership ----------------------------------------------------------
    def register(self, host_id: str,
                 now: Optional[float] = None) -> HostState:
        """Admit a host (idempotent reset if already present): fresh
        state, first heartbeat stamped now — a just-joined host must not
        be instantly dead because its ``last_heartbeat`` is 0."""
        st = HostState(host_id)
        st.last_heartbeat = time.time() if now is None else now
        self.hosts[host_id] = st
        return st

    def deregister(self, host_id: str) -> None:
        """Remove a host from membership (no-op if absent).  Its beats
        raise ``KeyError`` until it registers again."""
        self.hosts.pop(host_id, None)

    def beat(self, host_id: str, step_time_s: Optional[float] = None,
             now: Optional[float] = None):
        st = self.hosts[host_id]
        st.last_heartbeat = time.time() if now is None else now
        st.missed = 0
        st.alive = True
        if step_time_s is not None:
            st.step_times.append(step_time_s)
            st.ewma_s = (step_time_s if st.ewma_s == 0.0
                         else self.alpha * step_time_s
                         + (1 - self.alpha) * st.ewma_s)

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """Advance failure detection; returns newly-dead host ids."""
        now = time.time() if now is None else now
        dead = []
        for st in self.hosts.values():
            if not st.alive:
                continue
            st.missed = int((now - st.last_heartbeat) / self.interval_s)
            if st.missed >= self.miss_limit:
                st.alive = False
                dead.append(st.host_id)
        return dead

    def alive_hosts(self) -> List[str]:
        return [h for h, st in self.hosts.items() if st.alive]

    # -- straggler detection -------------------------------------------------
    def stragglers(self, z_threshold: float = 4.0,
                   abs_limit_s: Optional[float] = None) -> List[str]:
        """Hosts whose step-time EWMA is an outlier.  The MAD criterion
        needs >= 3 live hosts (a median of two cannot vote); ``abs_limit_s``
        adds an absolute ceiling that works at any fleet size — a
        two-replica cluster flags a hung peer against the known-healthy
        step price instead of a majority it doesn't have."""
        ew = {h: st.ewma_s for h, st in self.hosts.items()
              if st.alive and st.ewma_s > 0}
        out = []
        if abs_limit_s is not None:
            out = [h for h, v in ew.items() if v > abs_limit_s]
        if len(ew) < 3:
            return out
        vals = sorted(ew.values())
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        mad = max(mad, 1e-3 * med, 1e-9)
        return sorted(set(out) | {h for h, v in ew.items()
                                  if (v - med) / mad > z_threshold})


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 20
    backoff_base_s: float = 5.0
    backoff_cap_s: float = 300.0
    window_s: float = 3600.0
    crash_loop_limit: int = 5

    def __post_init__(self):
        self._restarts: deque = deque()

    def on_failure(self, now: Optional[float] = None) -> Optional[float]:
        """Returns backoff seconds before restarting, or None = give up."""
        now = time.time() if now is None else now
        while self._restarts and now - self._restarts[0] > self.window_s:
            self._restarts.popleft()
        if len(self._restarts) >= self.crash_loop_limit:
            return None
        self._restarts.append(now)
        n = len(self._restarts)
        if n > self.max_restarts:
            return None
        return min(self.backoff_base_s * 2 ** (n - 1), self.backoff_cap_s)


@dataclasses.dataclass
class FaultEvent:
    kind: str          # "dead_host" | "straggler" | "restart"
    host: str
    step: int
    action: str


class FaultTolerantRunner:
    """Glue: registry + policy + checkpoint manager -> step-loop callbacks."""

    def __init__(self, registry: HeartbeatRegistry,
                 policy: Optional[RestartPolicy] = None):
        self.registry = registry
        self.policy = policy or RestartPolicy()
        self.events: List[FaultEvent] = []

    def on_step(self, host_id: str, step: int, step_time_s: float,
                now: Optional[float] = None) -> List[FaultEvent]:
        self.registry.beat(host_id, step_time_s, now=now)
        out = []
        for dead in self.registry.sweep(now=now):
            out.append(FaultEvent("dead_host", dead, step,
                                  "restore_last_checkpoint+elastic_remesh"))
        for slow in self.registry.stragglers():
            out.append(FaultEvent("straggler", slow, step,
                                  "checkpoint_and_evict"))
        self.events.extend(out)
        return out
