"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

The 2x16x16 production mesh all-reduces gradients over the 'pod' axis across
the slow inter-pod links.  `compressed_psum` quantizes each gradient leaf to
int8 with a per-row scale before the collective (4x wire reduction vs f32)
and keeps the quantization residual in an error-feedback buffer that is
added back next step — the standard EF-SGD construction that preserves
convergence (the compression error is O(1)-bounded, not accumulated).

Implemented with jax.lax collectives under shard_map so the wire format is
explicit; falls back to plain psum when the mesh has no 'pod' axis.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor-row int8 quantization -> (q, scale)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = max(n // 1024, 1)
    pad = rows * 1024 - n
    flat = jnp.pad(flat, (0, pad)).reshape(rows, 1024)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def ef_compress_leaf(g, err):
    """Apply error feedback then quantize: returns (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale, g.shape)
    new_err = corrected - deq
    return q, scale, new_err


def compressed_pod_psum(grads: Any, err: Any, mesh,
                        axis: str = "pod") -> Tuple[Any, Any]:
    """All-reduce `grads` over `axis` in int8 with error feedback.

    grads/err: matching pytrees (err from `init_error_state`).
    Returns (averaged grads, new error state)."""
    if axis not in mesh.axis_names:
        return grads, err

    n = mesh.shape[axis]
    other = tuple(a for a in mesh.axis_names if a != axis)

    def per_device(g, e):
        q, scale, new_err = ef_compress_leaf(g, e)
        # wire: int8 payload + f32 scales over the pod links
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.psum(scale, axis)   # upper bound scale for dequant
        avg = dequantize_int8(qsum, ssum / n / n, g.shape) * n
        return avg.astype(g.dtype), new_err

    def fn(g_tree, e_tree):
        return jax.tree.map(per_device, g_tree, e_tree)

    # every leaf is fully replicated across 'pod'; shard_map over pod only
    spec = jax.tree.map(lambda _: P(), grads)
    out = jax.shard_map(fn, mesh=mesh,
                        in_specs=(spec, spec), out_specs=(spec, spec),
                        check_vma=False)(grads, err)
    return out


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compression_ratio(grads: Any) -> float:
    """Wire bytes ratio: int8+scales vs f32."""
    total_f32 = sum(g.size * 4 for g in jax.tree.leaves(grads))
    total_q = sum(g.size * 1 + (max(g.size // 1024, 1)) * 4
                  for g in jax.tree.leaves(grads))
    return total_q / total_f32
