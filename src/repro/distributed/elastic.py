"""Elastic re-meshing: continue training after losing devices/hosts.

Policy: the model axis is sacred (TP/EP sharding is baked into weight
layouts), so elasticity happens on the DATA (and pod) axis — the largest
data-axis size that (a) fits the surviving device count and (b) divides the
global batch is chosen, and state is re-sharded onto the new mesh by
device_put (all-gather + re-slice under the hood).  This mirrors how
production systems degrade: 2 pods -> 1 pod halves data parallelism and
doubles accumulation steps, keeping the global batch (and therefore the
training trajectory) EXACT.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

from repro.sharding.plans import named_tree


def plan_downsize(n_alive: int, model_axis: int = 16,
                  global_batch: int = 256) -> Tuple[int, int]:
    """(data_axis, accum_multiplier_change) for the surviving devices."""
    if n_alive < model_axis:
        raise RuntimeError(
            f"{n_alive} devices cannot host a {model_axis}-wide model axis; "
            "restore on fresh capacity instead")
    data = n_alive // model_axis
    # data axis must divide the global batch to keep the trajectory exact
    while data > 1 and global_batch % data != 0:
        data -= 1
    return data, data * model_axis


def remesh(devices, data_axis: int, model_axis: int = 16):
    import numpy as np
    n = data_axis * model_axis
    dev = np.asarray(devices[:n]).reshape(data_axis, model_axis)
    return jax.sharding.Mesh(dev, ("data", "model"))


def reshard_state(state: Any, specs: Any, new_mesh) -> Any:
    """Re-shard a pytree onto a new mesh (gather + re-slice)."""
    sh = named_tree(new_mesh, specs)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)
