from repro.distributed import (compression, elastic,  # noqa
                                fault_tolerance, overlap)
