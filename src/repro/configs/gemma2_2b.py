"""Gemma2-2B — dense, alternating local/global attention, softcaps [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; window 4096 on local
(odd) layers; attention logit softcap 50, final logit softcap 30.
"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    window=4096,
    layer_pattern="LG",          # alternating local / global
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    scale_embeds=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    act="gelu",
    microbatch=4,   # per data-shard microbatch rows
    sub_quadratic=True,
    notes="long_500k runs: half the layers are 4k-window local",
)
