"""Architecture config registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs.base import SHAPE_CELLS, ModelCfg, ShapeCell, reduced

from repro.configs.hymba_1p5b import CONFIG as _hymba
from repro.configs.yi_34b import CONFIG as _yi
from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.gemma3_1b import CONFIG as _gemma3
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.deepseek_v2_236b import CONFIG as _dsv2
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.rwkv6_1p6b import CONFIG as _rwkv6
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.seamless_m4t_medium import CONFIG as _m4t

ARCHS: dict[str, ModelCfg] = {
    "hymba-1.5b": _hymba,
    "yi-34b": _yi,
    "internlm2-20b": _internlm2,
    "gemma3-1b": _gemma3,
    "gemma2-2b": _gemma2,
    "deepseek-v2-236b": _dsv2,
    "olmoe-1b-7b": _olmoe,
    "rwkv6-1.6b": _rwkv6,
    "llava-next-34b": _llava,
    "seamless-m4t-medium": _m4t,
}


def get_config(arch: str) -> ModelCfg:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def cells_for(cfg: ModelCfg) -> list[ShapeCell]:
    """The runnable shape cells for an arch (long_500k only for sub-quadratic;
    every arch here has a decoder so decode cells always apply)."""
    cells = [SHAPE_CELLS["train_4k"], SHAPE_CELLS["prefill_32k"],
             SHAPE_CELLS["decode_32k"]]
    if cfg.sub_quadratic:
        cells.append(SHAPE_CELLS["long_500k"])
    return cells


__all__ = ["ARCHS", "SHAPE_CELLS", "ModelCfg", "ShapeCell", "get_config",
           "cells_for", "reduced"]
