"""OLMoE-1B-7B — fully sparse MoE, 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (kv=16, MHA) d_ff_expert=1024 vocab=50304.
"""
from repro.configs.base import ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    moe=MoECfg(n_experts=64, top_k=8, d_ff_expert=1024, n_shared=0,
               capacity_factor=1.0),
    qk_norm=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    microbatch=4,   # per data-shard microbatch rows
    sub_quadratic=False,
)
