"""Config schema for the repro framework.

Every assigned architecture is described by a frozen ``ModelCfg``; the four
assigned input-shape cells are ``ShapeCell`` instances.  Configs are pure data
(hashable, JSON-dumpable) so they can cross process boundaries for the
dry-run launcher.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    first_k_dense: int = 0          # leading dense-FFN layers (deepseek-v2: 1)
    capacity_factor: float = 1.0
    router_aux_coef: float = 1e-2
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    """Mamba-style selective-SSM mixer (used by hymba's parallel SSM heads)."""
    state_dim: int = 16
    conv_width: int = 4
    dt_rank: int = 64
    head_dim: int = 64              # ssm heads = d_inner // head_dim


@dataclass(frozen=True)
class RWKVCfg:
    head_dim: int = 64
    decay_lora: int = 64            # lora rank for data-dependent decay w
    mix_lora: int = 32              # lora rank for data-dependent token-shift


@dataclass(frozen=True)
class EncDecCfg:
    n_enc_layers: int
    n_dec_layers: int


@dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention flavour ---------------------------------------------------
    attn_impl: str = "gqa"          # gqa | mla | none
    rope_theta: float = 10000.0
    window: Optional[int] = None    # sliding-window width for local layers
    layer_pattern: Optional[str] = None   # e.g. "LLLLLG" tiled over layers
    global_layers: Tuple[int, ...] = ()   # explicit global-attn layer indices
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    qk_norm: bool = False
    mla: Optional[MLACfg] = None
    # --- mixture of experts --------------------------------------------------
    moe: Optional[MoECfg] = None
    # --- recurrent families --------------------------------------------------
    ssm: Optional[SSMCfg] = None    # hybrid: parallel attn+ssm heads per layer
    rwkv: Optional[RWKVCfg] = None  # attn-free rwkv6 time-mix
    # --- encoder-decoder -----------------------------------------------------
    encdec: Optional[EncDecCfg] = None
    # --- modality frontends (STUBS per task: precomputed embeddings) ---------
    frontend: Optional[str] = None  # vision | audio
    n_prefix_embeds: int = 0        # patches/frames prepended in train shape
    meta_tokens: int = 0            # hymba learnable memory registers
    # --- misc ------------------------------------------------------------------
    tie_embeddings: bool = True
    post_norms: bool = False        # gemma-style post-attn/post-mlp RMSNorms
    scale_embeds: bool = False      # gemma-style sqrt(d_model) embed scaling
    norm_eps: float = 1e-6
    act: str = "silu"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"        # adamw | adafactor
    microbatch: int = 2             # PER-DATA-SHARD microbatch rows (grad accum)
    attn_chunk: int = 512           # query-chunk for memory-efficient attention
    use_pallas: bool = False        # TPU hot path (ref jnp path used for dry-run)
    # --- beyond-paper performance plan (OFF for the faithful baseline) -------
    head_pad_multiple: int = 0      # pad Q heads to a TP-divisible count
    scatter_cache_update: bool = False  # scatter (not vmapped DUS) cache writes
    cast_params_once: bool = False  # hoist f32->bf16 casts out of accum loop
    remat_policy: str = "nothing"   # nothing | save_attn (keep attn outputs)
    moe_impl: str = "gather"        # gather (AG expert outputs) | shard (EP psum)
    sub_quadratic: bool = False     # arch supports long_500k decode state
    notes: str = ""

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab padded to a multiple of 128 so the
        vocab axis shards evenly (Megatron-style); pad logits are masked to
        -1e9, so softmax/argmax semantics are unchanged."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def padded_heads(self) -> int:
        """Q-head count after optional TP padding (== n_heads when off)."""
        m = self.head_pad_multiple
        if m and self.n_heads % m:
            return ((self.n_heads + m - 1) // m) * m
        return self.n_heads

    def kv_head_map(self):
        """Static q-head -> kv-head index map honouring the ORIGINAL GQA
        grouping (padding must not reshuffle real heads across kv groups).
        Dead (padded) heads map to group 0 and are masked after attention."""
        if self.n_kv_heads <= 0:
            return None
        qpk = max(self.n_heads // self.n_kv_heads, 1)
        real = [min(h // qpk, self.n_kv_heads - 1)
                for h in range(self.n_heads)]
        return tuple(real + [0] * (self.padded_heads - self.n_heads))

    def layer_is_global(self, idx: int) -> bool:
        """True if layer `idx` uses global (full) attention."""
        if self.window is None:
            return True
        if self.global_layers:
            return idx in self.global_layers
        if self.layer_pattern:
            return self.layer_pattern[idx % len(self.layer_pattern)] == "G"
        return True

    def global_layer_mask(self) -> Tuple[bool, ...]:
        n = self.encdec.n_dec_layers if self.encdec else self.n_layers
        return tuple(self.layer_is_global(i) for i in range(n))

    def replace(self, **kw) -> "ModelCfg":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS = {
    "train_4k":    ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeCell("long_500k", "decode", 524288, 1),
}


def reduced(cfg: ModelCfg, **overrides) -> ModelCfg:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        microbatch=2,
        attn_chunk=8,
        meta_tokens=4 if cfg.meta_tokens else 0,
        n_prefix_embeds=4 if cfg.n_prefix_embeds else 0,
        global_layers=(0,) if cfg.global_layers else (),
        window=8 if cfg.window else None,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=32,
            n_shared=min(cfg.moe.n_shared, 1), first_k_dense=cfg.moe.first_k_dense)
    if cfg.mla:
        kw["mla"] = MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                           qk_rope_dim=8, v_head_dim=16)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=4, dt_rank=8, head_dim=16)
    if cfg.rwkv:
        kw["rwkv"] = RWKVCfg(head_dim=16, decay_lora=8, mix_lora=4)
    if cfg.encdec:
        kw["encdec"] = EncDecCfg(n_enc_layers=2, n_dec_layers=2)
    kw.update(overrides)
    return cfg.replace(**kw)
