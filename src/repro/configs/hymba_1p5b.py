"""Hymba-1.5B — hybrid parallel attention+Mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Parallel attn+mamba heads fused per layer; sliding-window attention in all but
3 global layers (first / middle / last, per the paper); 128 meta tokens.
"""
from repro.configs.base import ModelCfg, SSMCfg

CONFIG = ModelCfg(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm=SSMCfg(state_dim=16, conv_width=4, dt_rank=100, head_dim=64),
    window=2048,
    global_layers=(0, 15, 31),
    meta_tokens=128,
    rope_theta=10000.0,
    microbatch=4,   # per data-shard microbatch rows
    sub_quadratic=True,       # SWA + SSM: bounded decode state
    notes="parallel attn+mamba heads, outputs mean-fused after per-path norm",
)
