"""DeepSeek-V2-236B — MLA + fine-grained MoE [arXiv:2405.04434].

60L d_model=5120 128H d_ff_expert=1536 vocab=102400; MLA kv_lora=512
(q_lora=1536, 128 nope + 64 rope qk dims, v=128); 2 shared + 160 routed
experts top-6; first layer is a dense FFN (d_ff=12288).
"""
from repro.configs.base import MLACfg, ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,            # MLA: effectively MHA over the compressed cache
    head_dim=128,
    d_ff=12288,                # dense-FFN width (first layer)
    vocab_size=102400,
    attn_impl="mla",
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
               qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
               first_k_dense=1, capacity_factor=1.0),
    rope_theta=10000.0,
    tie_embeddings=False,
    optimizer="adafactor",     # memory-lean optimizer so 236B fits one v5e pod
    microbatch=1,   # per data-shard microbatch rows
    sub_quadratic=False,       # MLA narrows the cache but still scores all positions
)
