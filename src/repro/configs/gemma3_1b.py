"""Gemma3-1B — dense, 5:1 local:global attention, 262k vocab [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144; sliding window 512 on
local layers, qk-norm, head_dim=256 (projection width independent of d_model).
"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    window=512,
    layer_pattern="LLLLLG",      # 5 local : 1 global
    qk_norm=True,
    post_norms=True,
    scale_embeds=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    act="gelu",
    microbatch=8,   # per data-shard microbatch rows
    sub_quadratic=True,          # local layers dominate → bounded-window state
    notes="long_500k runs: only the 1-in-6 global layers hold full-length KV",
)
