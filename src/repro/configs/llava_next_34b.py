"""LLaVA-NeXT-34B — VLM: anyres vision tiles + 34B LM backbone
[hf:llava-hf/llava-v1.6-mistral-7b-hf family; 34B variant backbone = Yi-34B].

Backbone: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The vision tower is a STUB per the task spec: ``input_specs()`` provides
precomputed patch embeddings [B, n_patches, d_model] that are prepended to the
token sequence (anyres tiling = variable patch count; we fix the spec to the
5-tile 2x2+base grid = 5*576 = 2880 patches for prefill shapes, 576 for train).
"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    frontend="vision",
    n_prefix_embeds=576,       # base-resolution tile in the train shape
    tie_embeddings=False,
    microbatch=1,   # per data-shard microbatch rows
    sub_quadratic=False,
)
