"""SeamlessM4T-medium — encoder-decoder multimodal translator [arXiv:2308.11596].

12L d_model=1024 16H d_ff=4096 vocab=256206; modelled as the transformer
BACKBONE (12 encoder + 12 decoder layers with cross-attention).  The speech
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
[B, S_enc, d_model] for the encoder; the decoder consumes text tokens.
"""
from repro.configs.base import EncDecCfg, ModelCfg

CONFIG = ModelCfg(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=24,               # 12 enc + 12 dec
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    encdec=EncDecCfg(n_enc_layers=12, n_dec_layers=12),
    frontend="audio",
    rope_theta=10000.0,
    tie_embeddings=True,
    microbatch=4,   # per data-shard microbatch rows
    sub_quadratic=False,
)
