"""Yi-34B — llama-architecture dense GQA transformer [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    microbatch=1,   # per data-shard microbatch rows
    sub_quadratic=False,      # pure full attention → long_500k skipped
)
