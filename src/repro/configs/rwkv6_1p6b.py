"""RWKV6 (Finch) 1.6B — attention-free, data-dependent decay [arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536; 32 wkv heads of dim 64; O(1) decode
state (per-head 64x64 matrix + token-shift buffers).
"""
from repro.configs.base import ModelCfg, RWKVCfg

CONFIG = ModelCfg(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                # wkv heads = d_model / head_dim
    n_kv_heads=0,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attn_impl="none",
    rwkv=RWKVCfg(head_dim=64, decay_lora=64, mix_lora=32),
    tie_embeddings=False,
    microbatch=4,   # per data-shard microbatch rows
    sub_quadratic=True,        # constant-size recurrent state
)
