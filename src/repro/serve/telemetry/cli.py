"""CLI for the telemetry layer: CI smokes + the docs consistency gate.

Subcommands (``python -m repro.serve.telemetry <cmd>``):

``smoke``
    Run the deterministic drift scenario (and, with ``--overload``, the
    SLO overload scenario) on the sim harness and FAIL (rc=2) unless the
    acceptance properties hold: >=1 recalibration event, post-
    recalibration error under the gate, exact tokens — and for overload,
    p99 at/under the target with newest-first shedding.  This is the CI
    telemetry smoke; it needs jax (CPU is fine).

``checkdocs``
    Verify ``docs/reference/metrics.md`` carries a row for every field
    of the telemetry schema (``metrics.schema_field_names``) and that
    the snapshot kind/version strings in the doc match the code.  Pure
    stdlib — the docs CI job runs it without importing jax.

``show``
    Pretty-print a saved telemetry snapshot's summary block (loudly
    refusing non-snapshot JSON).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.serve.telemetry import metrics

REPO_ROOT = Path(__file__).resolve().parents[4]
METRICS_DOC = REPO_ROOT / "docs" / "reference" / "metrics.md"


def _fail(msg: str) -> int:
    print(f"FAIL: {msg}")
    return 2


def cmd_smoke(args) -> int:
    from repro.serve.telemetry.scenarios import (run_drift_scenario,
                                                 run_overload_scenario)
    rc = 0
    res = run_drift_scenario()
    print(json.dumps({k: v for k, v in res.items() if k != "events"},
                     indent=1, default=str))
    if res["n_events"] < 1:
        rc = _fail("drift scenario emitted no recalibration event")
    elif res["post_error"] is None or res["post_error"] >= res["gate"]:
        rc = _fail(f"post-recalibration error {res['post_error']} not "
                   f"under the {res['gate']:.0%} gate")
    elif not res["tokens_ok"] or res["completed"] != res["n_requests"]:
        rc = _fail("recalibration changed served tokens")
    else:
        print(f"drift smoke OK: {res['n_events']} event(s), error "
              f"{res['pre_error']:.2f} -> {res['post_error']:.3f}")
    if args.overload:
        res = run_overload_scenario()
        print(json.dumps({k: v for k, v in res.items() if k != "summary"},
                         indent=1, default=str))
        if not res["slo_held"]:
            rc = _fail(f"p99 {res['p99_s']:.2f}s exceeded the "
                       f"{res['target_p99_s']:.2f}s SLO")
        elif not (res["deferred"] > 0 and res["admission_fifo"]):
            rc = _fail("overload did not shed newest-first")
        elif not res["tokens_ok"] or res["completed"] != res["n_requests"]:
            rc = _fail("overload shedding changed admitted tokens")
        else:
            print(f"overload smoke OK: p99 {res['p99_s']:.2f}s <= "
                  f"{res['target_p99_s']:.2f}s at "
                  f"{res['load_factor']}x load "
                  f"(ungated baseline {res['baseline_p99_s']:.2f}s)")
    return rc


def cmd_checkdocs(args) -> int:
    doc_path = Path(args.doc) if args.doc else METRICS_DOC
    if not doc_path.exists():
        return _fail(f"{doc_path} does not exist")
    text = doc_path.read_text()
    missing = [name for name in metrics.schema_field_names()
               if f"`{name}`" not in text]
    rc = 0
    if missing:
        rc = _fail(f"{doc_path.name} is missing rows for schema fields: "
                   f"{', '.join(missing)} — regenerate from "
                   "repro.serve.telemetry.metrics (STEP_FIELDS / "
                   "REQUEST_FIELDS)")
    for token in (metrics.SNAPSHOT_KIND,
                  f"version {metrics.SNAPSHOT_VERSION}"):
        if token not in text:
            rc = _fail(f"{doc_path.name} does not mention {token!r} — the "
                       "documented snapshot schema is out of date")
    if rc == 0:
        n = len(metrics.schema_field_names())
        print(f"checkdocs OK: all {n} schema fields documented in "
              f"{doc_path}")
    return rc


def cmd_show(args) -> int:
    doc = metrics.load_snapshot(args.snapshot)
    print(f"telemetry snapshot v{doc['version']} "
          f"(capacity {doc['capacity']}, {len(doc['steps'])} steps, "
          f"{len(doc['requests'])} requests, "
          f"{len(doc['events'])} events)")
    print(json.dumps(doc["summary"], indent=1))
    for e in doc["events"]:
        print(f"  recalibration: {e['kind']}/{e['bucket']} "
              f"ratio={e['ratio']:.3f} applied={e['applied']}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.telemetry",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sm = sub.add_parser("smoke", help="run the sim drift (+overload) "
                        "acceptance scenarios; rc!=0 on failure")
    sm.add_argument("--overload", action="store_true",
                    help="also run the SLO overload scenario")
    sm.set_defaults(fn=cmd_smoke)
    cd = sub.add_parser("checkdocs", help="fail unless every schema field "
                        "is documented in docs/reference/metrics.md")
    cd.add_argument("--doc", default=None,
                    help="override the reference doc path")
    cd.set_defaults(fn=cmd_checkdocs)
    sh = sub.add_parser("show", help="summarize a saved snapshot")
    sh.add_argument("snapshot")
    sh.set_defaults(fn=cmd_show)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
