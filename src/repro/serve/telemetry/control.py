"""The telemetry controller: the one object an engine binds.

``ServingEngine``/``PagedServingEngine`` accept ``telemetry=`` (a
:class:`TelemetryController`) and talk to it at exactly three points:

* ``begin_step()`` — once per engine iteration, *before* admission:
  refills the SLO token bucket (when an :class:`~.slo.SLO` is attached)
  and returns the step's admission budget, which the engines feed into
  the same arithmetic as the static ``step_budget_s`` gate;
* ``on_step(record)`` — once per productive iteration, with the filled
  :class:`~.metrics.StepRecord`: streams it into the sink, pays the
  bucket for the admitted work, feeds the SLO's AIMD loop with the
  measured latency, and feeds the drift detector;
* ``on_retire(request)`` — once per retirement: the per-request latency
  sample.

Drift attribution
-----------------
Only *attribution-unambiguous* steps feed the detector, so a drift event
names the table entry that actually drifted:

* a pure-decode step (decode dispatched, zero prefill units) is one
  ``("decode", "b<max_batch>")`` sample — predicted vs measured step;
* a pure-chunk step (prefill units, no decode) is one
  ``("chunk", "c<chunk_size>")`` sample at per-chunk granularity
  (both sides divided by the unit count);
* mixed steps are skipped: their error cannot be pinned on one entry.

When the detector fires, the controller *applies* the correction (unless
constructed with ``recalibrate=False``): a cost model exposing
``rescale(kind, factor)`` (the sim fake) is rescaled in place; a real
:class:`~repro.core.costmodel.model.CostModel` goes through the
pure-data ``recalibrate.rescale_calibration`` path keyed on the drifted
step's bottleneck.  Either way the engine's prediction cache is
invalidated (``engine.set_cost_model``), stale tuning-cache entries are
dropped, the autotuner's pricing model is swapped, and a
:class:`RecalibrationEvent` lands in the sink.

Simulation
----------
Under the deterministic harness (``repro.serve.sim``) the injected
clock is frozen within a step, so the engine-measured latency is 0;
``latency_model=`` (e.g. ``sim.work_latency_model``) replaces
``record.measured_s`` with a latency synthesized from the record's work
fields, closing the drift and SLO loops exactly as a wall clock would.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.serve.telemetry.drift import DriftDetector, DriftEvent
from repro.serve.telemetry.metrics import (MetricsSink, RequestRecord,
                                           StepRecord)
from repro.serve.telemetry.slo import SLO, TokenBucket


@dataclasses.dataclass
class RecalibrationEvent:
    """One applied (or skipped) online recalibration, as stored in the
    sink's event ring and the snapshot's ``events`` list."""
    kind: str                   # drifted path: "decode" | "chunk"
    bucket: str                 # shape bucket, e.g. "b4"
    ratio: float                # median measured/predicted at detection
    error: float                # windowed relative error at detection
    n_samples: int              # drift-window size behind the verdict
    step: int                   # engine step the event fired on
    t_s: float                  # record timestamp at detection
    bottleneck: str             # Prediction.bottleneck of the drifted step
    applied: str                # "rescale" | "calibration" | "none"
    invalidated: int            # tuning-cache entries dropped
    calibration_before: str     # cost-model calibration name pre-swap
    calibration_after: str      # ... post-swap ("" on the rescale path)

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class TelemetryController:
    """Binds a metrics sink, drift detector, and SLO admission loop to
    one engine (see module docstring for the three touch points).

    ``slo=None`` leaves admission on the engine's static
    ``step_budget_s``; ``drift=None`` builds a default
    :class:`DriftDetector` (pass ``drift=False`` to disable detection);
    ``recalibrate=False`` detects and records drift without applying
    corrections (observe-only mode, the runbook's first rollout stage).
    """

    def __init__(self, sink: Optional[MetricsSink] = None, *,
                 drift=None, slo=None,
                 latency_model: Optional[Callable[[StepRecord], float]]
                 = None,
                 recalibrate: bool = True):
        self.sink = sink if sink is not None else MetricsSink()
        self.detector: Optional[DriftDetector]
        if drift is False:
            self.detector = None
        else:
            self.detector = drift if drift is not None else DriftDetector()
        # slo: an SLO (wrapped in a default TokenBucket), a pre-built
        # TokenBucket (custom rate/burst), or None (static budget)
        if slo is None:
            self.slo, self.bucket = None, None
        elif isinstance(slo, TokenBucket):
            self.slo, self.bucket = slo.slo, slo
        elif isinstance(slo, SLO):
            self.slo, self.bucket = slo, TokenBucket(slo)
        else:
            raise TypeError(f"slo must be an SLO or TokenBucket, "
                            f"got {type(slo).__name__}")
        self.latency_model = latency_model
        self.recalibrate = recalibrate
        self.engine = None
        self.engine_name = ""
        self._decode_bucket = ""
        self._chunk_bucket = ""
        self.recalibrations: List[RecalibrationEvent] = []

    # ----- engine binding ----------------------------------------------------

    def bind(self, engine) -> None:
        """Called by the engine's ``__init__``; one controller drives one
        engine (the drift buckets are derived from its shapes)."""
        if self.engine is not None and self.engine is not engine:
            raise ValueError("TelemetryController is already bound to an "
                             "engine; use one controller per engine")
        self.engine = engine
        self.engine_name = ("paged" if "Paged" in type(engine).__name__
                            else "slot")
        self._decode_bucket = f"b{engine.max_batch}"
        if hasattr(engine, "chunk_size"):
            self._chunk_bucket = f"c{engine.chunk_size}"

    # ----- the three engine touch points -------------------------------------

    def begin_step(self) -> Optional[float]:
        """Refill and return the SLO admission budget for this step, or
        None when no SLO is attached (engine falls back to its static
        ``step_budget_s``)."""
        if self.bucket is None:
            return None
        return self.bucket.begin_step()

    def on_step(self, record: StepRecord) -> None:
        if self.latency_model is not None:
            record.measured_s = float(self.latency_model(record))
        self.sink.record_step(record)
        if self.bucket is not None:
            self.bucket.spend(record.predicted_s)
            self.bucket.observe(record.measured_s)
        if self.detector is not None:
            self._feed_drift(record)

    def on_retire(self, req) -> None:
        self.sink.record_request(RequestRecord(
            engine=self.engine_name, rid=req.rid,
            submitted_s=req.submitted_s, finished_s=req.finished_s,
            latency_s=req.finished_s - req.submitted_s,
            prompt_len=len(req.prompt), n_tokens=len(req.tokens)))

    # ----- drift -> recalibration --------------------------------------------

    def _feed_drift(self, record: StepRecord) -> None:
        """Feed only attribution-unambiguous samples (module docstring)."""
        if record.decode_ran and record.n_prefill_units == 0:
            event = self.detector.observe(
                "decode", self._decode_bucket,
                record.predicted_decode_s, record.measured_s)
        elif (not record.decode_ran and record.n_prefill_units > 0
              and self._chunk_bucket):
            n = record.n_prefill_units
            event = self.detector.observe(
                "chunk", self._chunk_bucket,
                record.predicted_s / n, record.measured_s / n)
        else:
            return
        if event is not None:
            self._apply(event, record)

    def _apply(self, drift: DriftEvent, record: StepRecord) -> None:
        """Turn a drift verdict into a live cost-model correction."""
        applied, invalidated = "none", 0
        cal_before = cal_after = ""
        engine, cm = self.engine, getattr(self.engine, "cost_model", None)
        if self.recalibrate and engine is not None and cm is not None:
            if hasattr(cm, "rescale"):
                # sim fakes (and any model exposing the protocol):
                # one in-place table multiply
                cm.rescale(drift.kind, drift.ratio)
                engine.set_cost_model(cm)
                applied = "rescale"
                cal_before = getattr(getattr(cm, "cal", None), "name", "")
            else:
                from repro.serve.telemetry.recalibrate import \
                    recalibrated_cost_model
                cal_before = cm.cal.name
                cm = recalibrated_cost_model(cm, drift.ratio,
                                             bottleneck=record.bottleneck)
                cal_after = cm.cal.name
                engine.set_cost_model(cm)
                applied = "calibration"
            invalidated = self._invalidate_tuning(cm, cal_before or None)
        event = RecalibrationEvent(
            kind=drift.kind, bucket=drift.bucket, ratio=drift.ratio,
            error=drift.error, n_samples=drift.n_samples,
            step=record.step, t_s=record.t_s,
            bottleneck=record.bottleneck, applied=applied,
            invalidated=invalidated, calibration_before=cal_before,
            calibration_after=cal_after)
        self.recalibrations.append(event)
        self.sink.record_event(event)

    def _invalidate_tuning(self, new_cm, calibration_id) -> int:
        """Configs ranked under the drifted calibration are stale: drop
        them and point the autotuner at the corrected model."""
        tuner = getattr(self.engine, "autotuner", None)
        if tuner is None:
            return 0
        from repro.serve.telemetry.recalibrate import \
            invalidate_tuning_entries
        n = 0
        if getattr(tuner, "cache", None) is not None:
            n = invalidate_tuning_entries(tuner.cache,
                                          calibration_id=calibration_id)
        tuner.cost_model = new_cm
        return n
