"""Deterministic drift / overload scenarios on the sim harness.

The acceptance demos for the telemetry layer, packaged as plain
functions so three consumers share one implementation:

* the sim tests (``tests/test_telemetry.py``) assert on the returned
  dict with exact expectations;
* the CI telemetry smoke (``python -m repro.serve.telemetry smoke``)
  asserts the same properties and sets the exit code;
* the ``telemetry_replay`` campaign experiment records the dict as a
  result artifact for the report table.

Both scenarios run the **paged engine** on the deterministic harness
(``repro.serve.sim``): a frozen ``SimClock``, the arithmetic
``FakeModel`` (so every request's tokens are computable in closed form),
constant ``FakeCostModel`` prices, and ``work_latency_model`` standing
in for wall-clock step latency (the clock is frozen within a step, so
engine-measured time is 0 — the latency model charges the *true* prices
for the work each step record says the engine did).

:func:`run_drift_scenario` — the cost model is constructed with its
decode price wrong by ``drift_factor``; the true prices flow in through
the latency model.  The drift detector must fire exactly once, the
rescale must bring the windowed prediction error back under the 10%
gate, and no request's tokens may change.

:func:`run_overload_scenario` — a burst of ``load_factor`` × the batch
capacity arrives at t=0 under an SLO-driven token bucket.  The bucket
must hold the measured step-time p99 at/under the target (an ungated
baseline run of the same trace is included to show the spike the bucket
prevents), shed admissions newest-first (deferrals, FIFO order intact),
and every admitted request must complete with byte-identical tokens.
"""
from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from repro.serve.sim import (FakeCostModel, FakeModel, SimClock, drive,
                             expected_tokens, work_latency_model)
from repro.serve.telemetry.control import TelemetryController
from repro.serve.telemetry.drift import DriftDetector
from repro.serve.telemetry.metrics import quantile
from repro.serve.telemetry.slo import SLO, TokenBucket

VOCAB = 97


def _paged(model, clock, **kw):
    from repro.serve.engine import PagedServingEngine
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("chunk_size", 4)
    return PagedServingEngine(model, params=None, clock=clock, **kw)


def _tokens_exact(engine, rids) -> bool:
    for rid in rids:
        req = engine.done[rid]
        if req.tokens != expected_tokens(req.prompt, req.max_new_tokens,
                                         VOCAB, req.eos_id):
            return False
    return True


def run_drift_scenario(*, drift_factor: float = 2.0, gate: float = 0.10,
                       n_requests: int = 6) -> Dict[str, Any]:
    """Inject a ``drift_factor`` decode-price error; return the
    recalibration evidence (see module docstring)."""
    true_decode_s, true_chunk_s = 1.0, 1.0
    # the table the engine prices admission with is WRONG by drift_factor
    cm = FakeCostModel(decode_s=true_decode_s / drift_factor,
                       prefill_s=true_chunk_s)
    detector = DriftDetector(gate, window=6, min_samples=4, cooldown=12)
    ctl = TelemetryController(
        drift=detector,
        latency_model=work_latency_model(true_decode_s, true_chunk_s))
    clock = SimClock()
    engine = _paged(FakeModel(vocab=VOCAB), clock, cost_model=cm,
                    telemetry=ctl)
    # long generations => a run of pure-decode steps, the unambiguous
    # samples the detector needs
    arrivals = [(0.0, [10 * i + 3, 10 * i + 4], 24, None)
                for i in range(n_requests)]
    rids = drive(engine, clock, arrivals, max_steps=400)

    events = [e.as_dict() for e in ctl.recalibrations]
    last_step = max((e["step"] for e in events), default=0)
    post = [abs(r.measured_s / r.predicted_decode_s - 1.0)
            for r in ctl.sink.steps()
            if r.decode_ran and r.n_prefill_units == 0
            and r.step > last_step and r.predicted_decode_s > 0]
    return {
        "scenario": "drift",
        "drift_factor": drift_factor,
        "gate": gate,
        "n_events": len(events),
        "events": events,
        "pre_error": events[0]["error"] if events else None,
        "post_error": quantile(post, 0.5) if post else None,
        "post_samples": len(post),
        "rescales": list(cm.rescales),
        "tokens_ok": _tokens_exact(engine, rids),
        "completed": engine.stats.completed,
        "n_requests": len(arrivals),
        "summary": ctl.sink.summary(),
    }


def run_overload_scenario(*, load_factor: int = 2,
                          target_p99_s: float = 3.5) -> Dict[str, Any]:
    """Burst-overload the paged engine under an SLO token bucket; return
    the p99-vs-target evidence plus an ungated baseline of the same
    trace (see module docstring)."""
    true_decode_s, true_chunk_s = 1.0, 1.0
    max_batch = 4
    # 2 chunks per prompt x load_factor x max_batch requests, all at t=0
    prompts: List[List[int]] = [
        [(7 * i + j) % VOCAB for j in range(8)]
        for i in range(load_factor * max_batch)]
    arrivals = [(0.0, p, 4, None) for p in prompts]

    # steady-state SLO: the plan prices a chunk-only step without the
    # decode that fires when that chunk COMPLETES a prefill, so the
    # bucket's initial rate (= the target) overshoots until the first
    # AIMD window observes the violation and cuts the refill rate.  The
    # SLO therefore holds from the first adaptation onward — p99 is
    # measured after one `slo.window` warmup (documented in the
    # runbook's "setting an SLO" section).
    warmup = 8

    def run(slo_on: bool):
        cm = FakeCostModel(decode_s=true_decode_s, prefill_s=true_chunk_s)
        latency = work_latency_model(true_decode_s, true_chunk_s)
        if slo_on:
            # increase=0 pins the post-adaptation rate: the demo shows
            # the bucket HOLDING the SLO, not the AIMD hunting around it
            # (upward adaptation is unit-tested on TokenBucket)
            slo = SLO(target_p99_s=target_p99_s, window=warmup,
                      increase=0.0)
            ctl = TelemetryController(
                slo=TokenBucket(slo, burst_factor=1.0),
                drift=False, latency_model=latency)
        else:
            ctl = TelemetryController(drift=False, latency_model=latency)
        clock = SimClock()
        engine = _paged(FakeModel(vocab=VOCAB), clock,
                        max_batch=max_batch, cost_model=cm, telemetry=ctl)
        rids = drive(engine, clock, arrivals, max_steps=400)
        meas = [r.measured_s for r in ctl.sink.steps()]
        # warmup applies to the SLO run only; the ungated baseline's
        # spike is exactly the early burst, so it is measured in full
        return engine, ctl, rids, quantile(meas[warmup:] if slo_on
                                           else meas, 0.99)

    engine, ctl, rids, p99 = run(slo_on=True)
    _, _, _, baseline_p99 = run(slo_on=False)
    order = engine.stats.admission_order
    return {
        "scenario": "overload",
        "load_factor": load_factor,
        "target_p99_s": target_p99_s,
        "warmup_steps": warmup,
        "p99_s": p99,
        "baseline_p99_s": baseline_p99,
        "slo_held": p99 <= target_p99_s,
        "baseline_violates": baseline_p99 > target_p99_s,
        "deferred": engine.stats.deferred_prefills,
        "admission_fifo": order == sorted(order),
        "tokens_ok": _tokens_exact(engine, rids),
        "completed": engine.stats.completed,
        "n_requests": len(arrivals),
        "bucket_windows": ctl.bucket.windows,
        "bucket_violations": ctl.bucket.violations,
        "summary": ctl.sink.summary(),
    }
