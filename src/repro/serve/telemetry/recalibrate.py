"""Apply a drift verdict: rescale the calibration, rebuild the model,
invalidate stale tuned configs.

The PR 2 calibration round-trip (``Calibration.to_dict``/``from_dict`` is
lossless) makes online recalibration a *pure-data* update: copy the
table, scale the rows the drift implicates, rebuild a ``CostModel`` on
the copy.  Nothing mutates the shipped calibration files and the old
model object stays valid for anyone still holding it.

Which rows get scaled follows the bottleneck the engine's own
predictions attribute the drifted step to (``Prediction.bottleneck``):

* ``memory``-bound drift → the streaming ``bandwidth_bps`` (and per-level
  latencies) — measured/predicted ratio ``r`` means real bandwidth is
  ``1/r`` of the table's;
* ``compute``-bound drift → the MXU surface (``mxu_peaks`` and every
  ``mxu_points`` throughput) scaled by ``1/r``;
* unknown/mixed → uniform: all of the above **plus** the instruction CPI
  table scaled by ``r`` — conservative, keeps every layer consistent.

Because the :class:`~repro.core.autotune.cache.TuningCache` key embeds
``calibration_id`` (PR 3: "a cache tuned against one calibration never
leaks configs onto another"), configs ranked under the drifted
calibration are unreachable-but-stale after a swap;
:func:`invalidate_tuning_entries` drops them so the cache file doesn't
accumulate dead weight and ``autotune show`` reflects reality.
"""
from __future__ import annotations

from typing import Optional

from repro.core.costmodel.calibration import Calibration
from repro.core.costmodel.model import CostModel


def rescale_calibration(cal: Calibration, factor: float, *,
                        bottleneck: str = "",
                        name_suffix: str = "+recal") -> Calibration:
    """Return a NEW calibration whose predictions scale by ``factor``
    (= measured/predicted from the drift window) for the implicated
    ``bottleneck`` term.  The input is never mutated."""
    if factor <= 0:
        raise ValueError("rescale factor must be positive")
    new = Calibration.from_dict(cal.to_dict())
    new.name = (cal.name or "calibration") + name_suffix
    inv = 1.0 / factor

    def scale_memory():
        if new.bandwidth_bps:
            new.bandwidth_bps *= inv
        for lvl in new.memory_levels:
            lvl.latency_ns *= factor

    def scale_compute():
        for dt in new.mxu_peaks:
            new.mxu_peaks[dt] *= inv
        for p in new.mxu_points:
            p.flops_per_s *= inv
            if p.cycles is not None:
                p.cycles *= factor

    if bottleneck == "memory":
        scale_memory()
    elif bottleneck == "compute":
        scale_compute()
    else:
        # unknown attribution: keep every layer mutually consistent
        scale_memory()
        scale_compute()
        for e in new.instructions.values():
            e.dependent_cycles *= factor
            e.independent_cycles *= factor
    return new


def recalibrated_cost_model(model: CostModel, factor: float, *,
                            bottleneck: str = "") -> CostModel:
    """A fresh :class:`CostModel` over the rescaled calibration, keeping
    the original's hardware spec and issue-cycle setting."""
    cal = rescale_calibration(model.cal, factor, bottleneck=bottleneck)
    return CostModel(cal, hw=model.hw,
                     issue_cycles=model.instructions.issue_cycles)


def invalidate_tuning_entries(cache, *,
                              calibration_id: Optional[str] = None) -> int:
    """Drop tuning-cache entries ranked under a now-stale calibration.

    ``calibration_id=None`` drops everything (the conservative default
    when the caller cannot name the calibration the entries were tuned
    under).  Returns the number of entries removed; flushes if any were.
    """
    from repro.core.autotune.cache import split_key
    stale = [key for key in cache.entries
             if calibration_id is None
             or split_key(key)[4] == calibration_id]
    for key in stale:
        del cache.entries[key]
    if stale:
        cache.flush()
    return len(stale)
