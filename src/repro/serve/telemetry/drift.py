"""Online prediction-drift detection per (kernel-kind, shape-bucket).

The paper's value proposition is that measured tables *predict* runtime;
the successor-architecture studies (Hopper arXiv:2402.13499, Blackwell
arXiv:2507.10789) show those tables go stale per device generation.  This
module watches the live predicted-vs-measured pairs the engines stream
through telemetry and decides when a calibration no longer holds:

* samples are keyed ``(kind, bucket)`` — ``kind`` names the priced code
  path (``"decode"``, ``"chunk"``), ``bucket`` its shape class (batch
  width / chunk size), mirroring the tuning cache's (kernel,
  shape-bucket) key granularity;
* per key, a sliding window of ``(predicted_s, measured_s)`` pairs
  maintains the **median measured/predicted ratio** — median, not mean,
  so one preempted/compacted outlier step cannot fake a drift;
* when the windowed relative error ``|ratio - 1|`` exceeds ``gate``
  (default 0.10 — the SAME 10% bar the cost-model CLI enforces on its
  calibration round-trip, ``python -m repro.core.costmodel
  --prediction-error``) with at least ``min_samples`` samples, a
  :class:`DriftEvent` fires carrying the correction ratio;
* firing clears that key's window and starts a ``cooldown`` (in samples)
  so the recalibration gets a fresh window of post-correction evidence
  before it can be judged again — "exactly one event per injected drift"
  is a property the sim tests pin.

The detector only *detects*; applying the correction (rescaling the
``Calibration``, invalidating tuning-cache entries) is
``serve.telemetry.recalibrate`` driven by the controller.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.serve.telemetry.metrics import quantile

DEFAULT_GATE = 0.10     # the cost-model CLI's prediction-error bar


@dataclasses.dataclass
class DriftEvent:
    """One detected calibration drift: predictions for ``kind``/``bucket``
    are off by ``ratio`` (median measured/predicted over the window)."""
    kind: str               # priced path: "decode" | "chunk"
    bucket: str             # shape bucket, e.g. "b4" / "c8"
    ratio: float            # median measured / predicted (>1: underpredict)
    error: float            # |ratio - 1|, the windowed relative error
    n_samples: int          # window size the verdict rests on
    predicted_s: float      # median predicted over the window
    measured_s: float       # median measured over the window

    @property
    def key(self) -> Tuple[str, str]:
        return (self.kind, self.bucket)

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class DriftDetector:
    """Windowed predicted-vs-measured watcher (see module docstring).

    ``gate``         relative-error threshold (default: the 10% CLI bar)
    ``window``       sliding-window length per key
    ``min_samples``  evidence floor before a verdict
    ``cooldown``     samples ignored per key after an event fires
    """

    def __init__(self, gate: float = DEFAULT_GATE, *, window: int = 8,
                 min_samples: int = 4, cooldown: int = 0):
        if not 0 < gate:
            raise ValueError("gate must be positive")
        if min_samples < 1 or window < min_samples:
            raise ValueError("need window >= min_samples >= 1")
        self.gate = gate
        self.window = window
        self.min_samples = min_samples
        self.cooldown = cooldown
        self._pairs: Dict[Tuple[str, str],
                          Deque[Tuple[float, float]]] = {}
        self._cool: Dict[Tuple[str, str], int] = {}
        self.events: List[DriftEvent] = []

    # ----- the read the runbook documents ------------------------------------

    def error(self, kind: str, bucket: str) -> Optional[float]:
        """Current windowed relative error for a key (None before
        ``min_samples`` pairs have arrived)."""
        pairs = self._pairs.get((kind, bucket), ())
        if len(pairs) < self.min_samples:
            return None
        return abs(self._ratio(pairs) - 1.0)

    @staticmethod
    def _ratio(pairs) -> float:
        return quantile([m / p for p, m in pairs], 0.5)

    # ----- the write side (controller feeds this) ----------------------------

    def observe(self, kind: str, bucket: str, predicted_s: float,
                measured_s: float) -> Optional[DriftEvent]:
        """Add one sample; returns a :class:`DriftEvent` when this sample
        tips the window past the gate.  Non-positive predictions are
        unpriceable (no cost model / zero-work step) and are skipped."""
        if predicted_s <= 0 or measured_s < 0:
            return None
        key = (kind, bucket)
        if self._cool.get(key, 0) > 0:
            self._cool[key] -= 1
            return None
        pairs = self._pairs.setdefault(key, deque(maxlen=self.window))
        pairs.append((predicted_s, measured_s))
        if len(pairs) < self.min_samples:
            return None
        ratio = self._ratio(pairs)
        error = abs(ratio - 1.0)
        if error <= self.gate:
            return None
        event = DriftEvent(
            kind=kind, bucket=bucket, ratio=ratio, error=error,
            n_samples=len(pairs),
            predicted_s=quantile([p for p, _ in pairs], 0.5),
            measured_s=quantile([m for _, m in pairs], 0.5))
        self.events.append(event)
        # fresh window + cooldown: the correction is judged on new
        # evidence only, and cannot be re-judged mid-refill
        pairs.clear()
        if self.cooldown:
            self._cool[key] = self.cooldown
        return event
