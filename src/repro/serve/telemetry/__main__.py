import sys

from repro.serve.telemetry.cli import main

sys.exit(main())
