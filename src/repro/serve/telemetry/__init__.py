"""Production telemetry for the serving engines: metrics, drift
detection, online cost-model recalibration, and SLO-aware admission.

The observability-and-control layer above ``ServingEngine`` and
``PagedServingEngine`` (ROADMAP item 4).  An engine constructed with
``telemetry=TelemetryController(...)`` streams one
:class:`~.metrics.StepRecord` per iteration and one
:class:`~.metrics.RequestRecord` per retirement into a bounded
:class:`~.metrics.MetricsSink`; the controller watches
predicted-vs-measured step time per (kernel-kind, shape-bucket)
(:class:`~.drift.DriftDetector`), rescales the cost model live when the
10% gate is breached (``recalibrate``), and can replace the static
``step_budget_s`` admission gate with a p99-targeting token bucket
(:class:`~.slo.SLO` / :class:`~.slo.TokenBucket`).

Docs: ``docs/ops-runbook.md`` (reading the metrics, responding to drift,
setting SLOs), ``docs/reference/metrics.md`` (the field-by-field schema,
CI-checked against :data:`~.metrics.STEP_FIELDS`).

Import note: this package root and :mod:`~.metrics` are stdlib-only;
jax is touched only by the sim scenarios/CLI smoke, which import the
engines.
"""
from repro.serve.telemetry.control import (RecalibrationEvent,
                                           TelemetryController)
from repro.serve.telemetry.drift import DriftDetector, DriftEvent
from repro.serve.telemetry.metrics import (REQUEST_FIELDS, STEP_FIELDS,
                                           MetricsSink, RequestRecord,
                                           StepRecord, load_snapshot,
                                           validate_snapshot)
from repro.serve.telemetry.recalibrate import (invalidate_tuning_entries,
                                               recalibrated_cost_model,
                                               rescale_calibration)
from repro.serve.telemetry.slo import SLO, TokenBucket

__all__ = [
    "SLO",
    "DriftDetector",
    "DriftEvent",
    "MetricsSink",
    "RecalibrationEvent",
    "RequestRecord",
    "StepRecord",
    "STEP_FIELDS",
    "REQUEST_FIELDS",
    "TelemetryController",
    "TokenBucket",
    "invalidate_tuning_entries",
    "load_snapshot",
    "recalibrated_cost_model",
    "rescale_calibration",
    "validate_snapshot",
]
