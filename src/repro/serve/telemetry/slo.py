"""SLO-aware admission: a token-bucket controller over step-time budget.

PR 4 gated admission on a *static* ``step_budget_s``: a prefill (or
chunk) is admitted iff its predicted cost fits the remaining per-step
budget.  That holds mean step time but says nothing about the tail —
a static budget is either so tight it starves throughput or so loose
that bursts blow the p99.  This module replaces the static gate with a
closed loop:

* the operator states intent as an :class:`SLO` — a target p99 step
  latency — instead of a per-step second count;
* a :class:`TokenBucket` meters *predicted seconds of admitted work*:
  each step refills ``rate`` seconds (capped at ``burst``), and the
  scheduler may only admit work whose predicted cost the bucket can
  pay.  Bursts are absorbed up to ``burst`` and then shed —
  **newest-first**, because both engines admit from the queue head and
  the paged engine's eviction policy protects the oldest request
  (forward-progress guarantee, PR 4): overload never starves work
  already in flight;
* the loop closes with AIMD: every observation window the controller
  compares the measured p99 step latency against the target and adapts
  the refill rate — additive increase (``+increase``, fractional) while
  under target, multiplicative decrease (``*decrease``) when over.

``TokenBucket.budget_s`` is what the engines consume: it plugs into the
exact same arithmetic as the static ``step_budget_s`` (see
``ServingEngine._admit`` / ``ChunkedPrefillScheduler.plan(budget_s=)``),
so the whole PR 4 deferral/eviction machinery is reused unchanged —
only the number it compares against becomes adaptive.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

from repro.serve.telemetry.metrics import quantile


@dataclasses.dataclass(frozen=True)
class SLO:
    """Operator intent: hold step p99 at/under ``target_p99_s``.

    ``window``      steps per observation window (AIMD adapts per window)
    ``increase``    additive refill-rate increase per good window
                    (fraction of the target, e.g. 0.05 = +5%/window)
    ``decrease``    multiplicative refill-rate cut on a violated window
    ``min_rate_s``  refill-rate floor — keeps at least one small unit of
                    work admissible so the system drains instead of
                    deadlocking under a transient latency spike
    """
    target_p99_s: float
    window: int = 16
    increase: float = 0.05
    decrease: float = 0.7
    min_rate_s: float = 1e-6

    def __post_init__(self):
        if self.target_p99_s <= 0:
            raise ValueError("target_p99_s must be positive")
        if not 0 < self.decrease < 1:
            raise ValueError("decrease must be in (0, 1)")


class TokenBucket:
    """Meters predicted seconds of admitted work against an SLO.

    Per step: :meth:`begin_step` refills, the scheduler reads
    :attr:`budget_s` / calls :meth:`spend`, and the controller feeds the
    measured step latency back through :meth:`observe`.
    """

    def __init__(self, slo: SLO, *, rate_s: Optional[float] = None,
                 burst_factor: float = 2.0):
        self.slo = slo
        # start from the target itself: steady state admits about one
        # target-latency step's worth of work per step
        self.rate_s = slo.target_p99_s if rate_s is None else rate_s
        self.burst_factor = burst_factor
        self.tokens_s = self.rate_s          # start full: first step admits
        self._window: Deque[float] = deque(maxlen=slo.window)
        self.windows = 0                     # observation windows closed
        self.violations = 0                  # ... of which violated target
        self.rate_trace: List[float] = []    # rate_s after each window

    @property
    def burst_s(self) -> float:
        """Bucket capacity: the largest admissible single-step burst."""
        return self.rate_s * self.burst_factor

    @property
    def budget_s(self) -> float:
        """Admissible predicted seconds for the current step."""
        return self.tokens_s

    def begin_step(self) -> float:
        """Refill at the adapted rate (capped at burst); returns the
        step's budget."""
        self.tokens_s = min(self.tokens_s + self.rate_s, self.burst_s)
        return self.tokens_s

    def spend(self, predicted_s: float) -> None:
        """Pay for admitted work (floored at zero — prediction error must
        not drive the bucket negative and wedge admission)."""
        self.tokens_s = max(0.0, self.tokens_s - max(0.0, predicted_s))

    def tighten(self, factor: float) -> float:
        """Brownout: multiplicatively cut the admission rate (e.g. to the
        surviving-capacity fraction after a replica death) without
        waiting for a violation window — the AIMD loop then *earns* the
        rate back additively as the shrunk fleet proves it can hold the
        target.  Spills above the new burst ceiling are clipped so the
        very next step already admits at brownout rate.  Returns the new
        ``rate_s``."""
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"tighten factor must be in (0, 1], "
                             f"got {factor}")
        self.rate_s = max(self.slo.min_rate_s, self.rate_s * factor)
        self.tokens_s = min(self.tokens_s, self.burst_s)
        self.rate_trace.append(self.rate_s)
        return self.rate_s

    def observe(self, measured_s: float) -> None:
        """Feed one measured step latency; closes the AIMD loop once per
        ``slo.window`` observations."""
        self._window.append(measured_s)
        if len(self._window) < self.slo.window:
            return
        p99 = quantile(list(self._window), 0.99)
        self.windows += 1
        if p99 > self.slo.target_p99_s:
            self.violations += 1
            self.rate_s = max(self.slo.min_rate_s,
                              self.rate_s * self.slo.decrease)
        else:
            self.rate_s += self.slo.increase * self.slo.target_p99_s
        self.rate_trace.append(self.rate_s)
        self._window.clear()
