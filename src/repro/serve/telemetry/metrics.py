"""The metrics pipeline: per-step / per-request records, a bounded ring
sink, and the schema-versioned snapshot + JSON-lines export formats.

Both engines (``serve.engine``) build one :class:`StepRecord` per engine
iteration and one :class:`RequestRecord` per retirement and stream them
into a :class:`MetricsSink` (via ``serve.telemetry.TelemetryController``)
— the sink is a fixed-capacity ring buffer, so a long-running serving
process holds a bounded window of recent records, never an unbounded log.

Two on-disk forms, both documented in ``docs/reference/metrics.md``:

* **snapshot** — one schema-versioned JSON document (``kind:
  "telemetry_snapshot"``, like the campaign results and the autotune
  cache), carrying the current ring contents, every recalibration event,
  and a summary block (latency quantiles, drift error, totals).
  ``load_snapshot`` refuses kind-less or newer-versioned JSON loudly —
  the same discipline as ``autotune.cache``.
* **JSON lines** — ``export_jsonl`` writes one tagged object per line
  (``{"record": "step"|"request"|"event", ...}``), the append-friendly
  form a log shipper tails.

The field tables (:data:`STEP_FIELDS`, :data:`REQUEST_FIELDS`) are the
single source of truth for the metrics reference doc:
``python -m repro.serve.telemetry checkdocs`` fails CI when a field here
is missing from ``docs/reference/metrics.md``.

This module is deliberately stdlib-only (no jax): the docs-check CI job
and log tooling import it without paying accelerator-runtime startup.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from collections import deque
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Tuple

SNAPSHOT_KIND = "telemetry_snapshot"
SNAPSHOT_VERSION = 1


@dataclasses.dataclass
class Field:
    """One schema row: the unit and provenance of a record field."""
    name: str
    type: str
    unit: str
    engines: str        # "slot", "paged", or "both"
    description: str


@dataclasses.dataclass
class StepRecord:
    """One engine iteration, as the engines report it.

    ``predicted_*`` fields are 0.0 when the engine has no cost model;
    ``measured_s`` is the wall (or injected-clock) duration of the
    iteration.  Counter fields (``host_syncs`` .. ``deferred``) are
    cumulative engine-lifetime values — consumers diff consecutive
    records for rates.  ``n_prefill_units`` is per-step: whole prompts
    admitted (slot engine) or prefill chunks run (paged engine) in this
    iteration.
    """
    engine: str                 # "slot" | "paged"
    step: int                   # stats.steps after this iteration
    t_s: float                  # clock.time() at record emission
    n_active: int               # rows/slots occupied at dispatch
    queue_depth: int            # requests waiting (not yet placed)
    predicted_s: float          # planned iteration time (decode+prefill)
    predicted_decode_s: float   # the decode-step component of the plan
    measured_s: float           # measured iteration wall time
    decode_ran: bool            # a batched decode was dispatched
    n_prefill_units: int        # prompts (slot) / chunks (paged) this step
    bottleneck: str             # decode Prediction.bottleneck ("" w/o model)
    budget_s: float             # effective admission budget (0.0 ungated)
    host_syncs: int             # cumulative device->host syncs (_sync)
    table_uploads: int          # cumulative block-table uploads (paged)
    blocks_in_use: int          # allocated pool blocks now (paged; 0 slot)
    n_blocks: int               # pool size (paged; 0 slot)
    decoded_tokens: int         # cumulative delivered tokens
    preemptions: int            # cumulative evictions (paged)
    deferred: int               # cumulative budget-deferred admissions
    kernel_splits: int          # tuned split-KV factor (paged; 0 slot)
    integrity_failures: int = 0  # cumulative corrupted-step drains dropped


@dataclasses.dataclass
class RequestRecord:
    """One retired request: the per-request latency sample."""
    engine: str                 # "slot" | "paged"
    rid: int                    # request id
    submitted_s: float          # clock.time() at submit
    finished_s: float           # clock.time() at retirement
    latency_s: float            # finished - submitted
    prompt_len: int             # prompt tokens
    n_tokens: int               # generated tokens delivered


def _fields(cls, meta: Dict[str, Tuple[str, str, str]]) -> List[Field]:
    """Zip the dataclass fields with their (unit, engines, description)
    rows; a KeyError here means a record field was added without schema
    metadata — exactly the gap the docs check exists to catch."""
    out = []
    for f in dataclasses.fields(cls):
        unit, engines, desc = meta[f.name]
        out.append(Field(f.name, f.type if isinstance(f.type, str)
                         else f.type.__name__, unit, engines, desc))
    return out


# (unit, emitting engines, description) per record field — the one table
# docs/reference/metrics.md must mirror (checked by `checkdocs`)
_STEP_META = {
    "engine": ("-", "both", "emitting engine: 'slot' or 'paged'"),
    "step": ("count", "both", "engine step counter after this iteration"),
    "t_s": ("s", "both", "clock.time() at record emission"),
    "n_active": ("count", "both", "occupied rows/slots at dispatch"),
    "queue_depth": ("count", "both", "requests waiting, not yet placed"),
    "predicted_s": ("s", "both",
                    "planned iteration time (decode + prefill units)"),
    "predicted_decode_s": ("s", "both",
                           "decode-step component of the plan"),
    "measured_s": ("s", "both", "measured iteration wall time"),
    "decode_ran": ("bool", "both", "a batched decode was dispatched"),
    "n_prefill_units": ("count", "both",
                        "prompts (slot) / chunks (paged) this step"),
    "bottleneck": ("-", "both",
                   "decode Prediction.bottleneck; '' without a model"),
    "budget_s": ("s", "both", "effective admission budget; 0.0 ungated"),
    "host_syncs": ("count", "both", "cumulative device->host syncs"),
    "table_uploads": ("count", "paged",
                      "cumulative block-table host->device uploads"),
    "blocks_in_use": ("blocks", "paged", "allocated pool blocks now"),
    "n_blocks": ("blocks", "paged", "pool size"),
    "decoded_tokens": ("tokens", "both", "cumulative delivered tokens"),
    "preemptions": ("count", "paged", "cumulative evictions"),
    "deferred": ("count", "both", "cumulative budget-deferred admissions"),
    "kernel_splits": ("count", "paged",
                      "resolved split-KV flash-decoding factor from the "
                      "tuning cache (1 = unsplit; 0 on the slot engine)"),
    "integrity_failures": ("count", "both",
                           "cumulative fused-step drains dropped by the "
                           "token-echo integrity probe (0 healthy)"),
}
_REQUEST_META = {
    "engine": ("-", "both", "emitting engine: 'slot' or 'paged'"),
    "rid": ("-", "both", "request id"),
    "submitted_s": ("s", "both", "clock.time() at submit"),
    "finished_s": ("s", "both", "clock.time() at retirement"),
    "latency_s": ("s", "both", "finished_s - submitted_s"),
    "prompt_len": ("tokens", "both", "prompt tokens"),
    "n_tokens": ("tokens", "both", "generated tokens delivered"),
}

STEP_FIELDS: List[Field] = _fields(StepRecord, _STEP_META)
REQUEST_FIELDS: List[Field] = _fields(RequestRecord, _REQUEST_META)


def schema_field_names() -> List[str]:
    """Every field name the reference doc must carry a row for."""
    return sorted({f.name for f in STEP_FIELDS} |
                  {f.name for f in REQUEST_FIELDS})


def quantile(xs: Iterable[float], q: float) -> float:
    """Nearest-rank-with-interpolation quantile of a finite sample
    (0 on empty input) — the p50/p99 the summary and the SLO loop use."""
    vals = sorted(xs)
    if not vals:
        return 0.0
    pos = q * (len(vals) - 1)
    lo = math.floor(pos)
    hi = min(lo + 1, len(vals) - 1)
    return vals[lo] + (vals[hi] - vals[lo]) * (pos - lo)


class MetricsSink:
    """Bounded ring buffer of step / request / event records.

    ``capacity`` bounds each ring independently; the oldest records fall
    off first.  ``events`` (recalibrations) are kept in full up to the
    same cap — they are rare by construction (drift gate + cooldown).

    ``stream_path`` turns on the incremental append-and-flush JSONL mode
    for crash post-mortems: every record is ALSO written to the stream
    file the moment it is recorded — one ``{"record": ...}``-tagged line
    per record, the same format as :meth:`export_jsonl`, appended with a
    single ``write`` call and flushed — so the tail of a replica that
    dies mid-step survives on disk even though the process never reached
    an explicit export.  (One line per ``write`` keeps lines atomic on
    POSIX appends; a torn final line can only be the crash instant
    itself, which is exactly what a post-mortem wants to see.)
    """

    def __init__(self, capacity: int = 4096,
                 stream_path: "os.PathLike | str | None" = None):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._steps: deque = deque(maxlen=capacity)
        self._requests: deque = deque(maxlen=capacity)
        self._events: deque = deque(maxlen=capacity)
        # lifetime totals survive ring eviction
        self.total_steps = 0
        self.total_requests = 0
        self.total_events = 0
        self._stream = None
        self.stream_path: Optional[Path] = None
        if stream_path is not None:
            self.open_stream(stream_path)

    # ----- incremental stream ------------------------------------------------

    def open_stream(self, path: "os.PathLike | str") -> Path:
        """Start (or redirect) the append-and-flush JSONL stream."""
        self.close_stream()
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        self._stream = out.open("a")
        self.stream_path = out
        return out

    def close_stream(self) -> None:
        if self._stream is not None:
            self._stream.close()
            self._stream = None

    def stream_note(self, obj: Dict[str, Any]) -> None:
        """Append one arbitrary tagged line to the stream (no ring entry)
        — e.g. the cluster supervisor's dead-replica tag."""
        self._write_line(obj)

    def _write_line(self, obj: Dict[str, Any]) -> None:
        if self._stream is None:
            return
        self._stream.write(json.dumps(obj) + "\n")   # one atomic append
        self._stream.flush()

    # ----- write side --------------------------------------------------------

    def record_step(self, rec: StepRecord) -> None:
        self._steps.append(rec)
        self.total_steps += 1
        self._write_line({"record": "step", **dataclasses.asdict(rec)})

    def record_request(self, rec: RequestRecord) -> None:
        self._requests.append(rec)
        self.total_requests += 1
        self._write_line({"record": "request", **dataclasses.asdict(rec)})

    def record_event(self, event) -> None:
        """``event`` is any dataclass with an ``as_dict()`` (the
        controller's ``RecalibrationEvent``)."""
        self._events.append(event)
        self.total_events += 1
        self._write_line({"record": "event", **event.as_dict()})

    # ----- read side ---------------------------------------------------------

    def steps(self) -> List[StepRecord]:
        return list(self._steps)

    def requests(self) -> List[RequestRecord]:
        return list(self._requests)

    def events(self) -> list:
        return list(self._events)

    def summary(self) -> Dict[str, Any]:
        """The at-a-glance health block the ops runbook documents."""
        steps = self.steps()
        reqs = self.requests()
        meas = [s.measured_s for s in steps]
        lat = [r.latency_s for r in reqs]
        errs = [abs(s.measured_s - s.predicted_s) / s.predicted_s
                for s in steps if s.predicted_s > 0]
        return {
            "steps": self.total_steps,
            "requests": self.total_requests,
            "recalibrations": self.total_events,
            "step_p50_s": quantile(meas, 0.50),
            "step_p99_s": quantile(meas, 0.99),
            "request_p50_s": quantile(lat, 0.50),
            "request_p99_s": quantile(lat, 0.99),
            "mean_abs_pred_err": (sum(errs) / len(errs)) if errs else 0.0,
            "window": len(steps),
        }

    # ----- snapshot (schema-versioned document) ------------------------------

    def snapshot(self) -> Dict[str, Any]:
        return {
            "kind": SNAPSHOT_KIND,
            "version": SNAPSHOT_VERSION,
            "capacity": self.capacity,
            "schema": {
                "step": [dataclasses.asdict(f) for f in STEP_FIELDS],
                "request": [dataclasses.asdict(f) for f in REQUEST_FIELDS],
            },
            "steps": [dataclasses.asdict(s) for s in self._steps],
            "requests": [dataclasses.asdict(r) for r in self._requests],
            "events": [e.as_dict() for e in self._events],
            "summary": self.summary(),
        }

    def save(self, path: "os.PathLike | str") -> Path:
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        tmp = out.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(self.snapshot(), indent=1, sort_keys=True))
        os.replace(tmp, out)
        return out

    # ----- JSON lines export -------------------------------------------------

    def export_jsonl(self, path: "os.PathLike | str") -> Path:
        """One tagged JSON object per line, in ring order: the
        shipper-friendly export (append a file per snapshot interval)."""
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        with out.open("w") as fh:
            for s in self._steps:
                fh.write(json.dumps({"record": "step",
                                     **dataclasses.asdict(s)}) + "\n")
            for r in self._requests:
                fh.write(json.dumps({"record": "request",
                                     **dataclasses.asdict(r)}) + "\n")
            for e in self._events:
                fh.write(json.dumps({"record": "event",
                                     **e.as_dict()}) + "\n")
        return out


def validate_snapshot(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Refuse non-snapshot / newer-versioned JSON loudly (the
    ``autotune.cache`` discipline: pointing tooling at the wrong artifact
    must never be silently accepted)."""
    if not isinstance(doc, dict):
        raise ValueError("telemetry snapshot must be a JSON object")
    if doc.get("kind") != SNAPSHOT_KIND:
        raise ValueError(f"not a telemetry snapshot (kind="
                         f"{doc.get('kind')!r}, expected {SNAPSHOT_KIND!r})")
    version = doc.get("version", 0)
    if version > SNAPSHOT_VERSION:
        raise ValueError(
            f"telemetry snapshot schema v{version} is newer than supported "
            f"v{SNAPSHOT_VERSION}; upgrade the repo to read this file")
    return doc


def load_snapshot(path: "os.PathLike | str") -> Dict[str, Any]:
    return validate_snapshot(json.loads(Path(path).read_text()))
