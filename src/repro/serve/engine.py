"""Batched serving engines: slot-granular continuous batching, and the
paged engine that replaces per-slot ``max_len`` KV stripes with a shared
block pool.

``ServingEngine`` is the vLLM-style loop reduced to its scheduling core
with slot-granular KV memory: every admitted sequence reserves a full
``max_len`` stripe of the batch cache, so KV bytes resident are always
``max_batch x max_len`` regardless of actual context lengths.

``PagedServingEngine`` replaces that with a paged subsystem:

* the KV store is a fixed pool of blocks (``serve.paging``) gathered
  through per-request block tables — resident KV bytes are
  ``n_blocks x block_size``, sized to the *traffic*, not to the
  worst-case ``max_batch x max_len`` rectangle;
* admission is a policy object (``serve.scheduler``): prompts prefill in
  fixed-size chunks interleaved with decode steps, each chunk priced via
  the cost model so the iteration respects ``step_budget_s``;
* when the pool runs out, the youngest placed request is preempted —
  its blocks freed, the request re-enqueued at the queue front — and
  replayed later (greedy decode is deterministic, so eviction never
  changes tokens); the oldest placed request is never evicted, which
  guarantees forward progress;
* on retire, freed blocks may leave gaps; copy-on-retire compaction
  moves the allocated blocks down to the lowest ids (one gather-then-
  scatter copy) so the touched span of the pool stays dense.

The fused decode hot path (``fused=True``, the default)
-------------------------------------------------------
Both engines rebuild their per-step traffic around one fused, donated,
pipelined device step:

* **on-device sampling** — greedy argmax runs inside the jitted step
  (``Model.decode_step``), so ``[B]`` int32 tokens cross to host per
  step instead of a ``[B, vocab]`` logit matrix materialized at the step
  boundary for eager host-side sampling;
* **donated caches** — the KV cache (slot stripes or the paged pool) is
  donated on both the ``jax.jit`` and ``.lower().compile()`` paths, so
  a step updates it in place instead of materializing a second cache
  (halves peak KV memory, removes a full-cache HBM round-trip per step);
  prefill splices and admission writes donate the same way;
* **device-resident loop state** — tokens stay on device between steps
  (updated by the step itself / jitted scatters on admission), and the
  paged block tables upload once per *mutation*, not per step;
* **one-step-ahead pipelining** — step N+1 is dispatched *before* step
  N's tokens are synced, so host bookkeeping (retire / admit / schedule)
  runs in the shadow of the device step.  The step additionally echoes
  its *input* tokens (a ``[2, B]`` array: inputs + outputs), so a
  prefill's first token reaches ``Request.tokens`` through the same
  single per-step sync instead of its own transfer.  Retirement and
  admission therefore lag the device by exactly one step — token
  streams per request are unchanged (greedy decode is deterministic and
  per-row state is independent), the retired row just rides along for
  one masked/overwritten "shadow" step whose outputs are dropped.

``fused=False`` keeps the legacy blocking path (fresh host uploads per
step, the ``[B, vocab]`` logit output pulled through an eager argmax +
blocking sync, undonated caches) — the baseline the
``decode_hotpath`` campaign experiment measures against.

All device->host reads go through ``_sync`` (counted in
``EngineStats.host_syncs`` and performed with the *explicit*
``jax.device_get``), so a test can run an engine under
``jax.transfer_guard_device_to_host("disallow")`` and prove the fused
path performs no stray transfers and at most one sync per step.

Both engines price admission with a ``repro.core.costmodel.CostModel``
when one is supplied, install an ``repro.core.autotune.Autotuner`` handle
for the duration of each step, and accept an injectable ``clock`` (any
object with ``time()``/``perf_counter()``) so the simulation test harness
can drive them on a deterministic fake clock.
"""
from __future__ import annotations

import dataclasses
import itertools
import time as _time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel.model import CostModel, Prediction
from repro.models.zoo import Model, fused_decode_step
from repro.serve.paging import (BlockAllocator, blocks_for_tokens,
                                remap_table)
from repro.serve.scheduler import ChunkedPrefillScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0


@dataclasses.dataclass
class EngineStats:
    """Cumulative per-engine counters, exposed as ``engine.stats``.

    Field-by-field meaning (units, healthy ranges, how they differ from
    the per-step telemetry records) is documented in
    ``docs/ops-runbook.md``; the telemetry layer
    (``serve.telemetry.metrics.StepRecord``) snapshots several of these
    counters per step so consumers can diff consecutive records for
    rates.
    """
    steps: int = 0
    prefills: int = 0               # completed prefills (net of evictions)
    decoded_tokens: int = 0         # DELIVERED tokens (eviction replays
    #                                 are rolled back, not double-counted)
    completed: int = 0
    deferred_prefills: int = 0      # admissions pushed to a later step
    host_syncs: int = 0             # device->host transfers (via _sync)
    table_uploads: int = 0          # block-table host->device uploads
    predicted_step_s: List[float] = dataclasses.field(default_factory=list)
    measured_step_s: List[float] = dataclasses.field(default_factory=list)
    # paged-engine extensions (stay 0/empty on the slot engine)
    prefill_chunks: int = 0         # chunked-prefill calls run
    preemptions: int = 0            # evictions (blocks reclaimed, re-enqueued)
    compactions: int = 0            # copy-on-retire block compactions
    peak_blocks_in_use: int = 0
    block_occupancy: List[float] = dataclasses.field(default_factory=list)
    admission_order: List[int] = dataclasses.field(default_factory=list)
    integrity_failures: int = 0     # corrupted fused-step drains dropped


def _analytic_prefill_prediction(cost_model: CostModel, cfg,
                                 n_tokens: int) -> Prediction:
    """Price a prefill of ``n_tokens`` ANALYTICALLY (``costmodel.
    analytic``), not by compiling it — admission runs per engine step and
    a per-length XLA compile there would stall serving for pure
    bookkeeping.  THE one implementation both engines' cached
    ``_predict_*`` methods wrap, so slot and paged admission can never
    silently price the same prompt differently."""
    from repro.configs.base import ShapeCell
    from repro.core.costmodel.analytic import analytic_census
    cell = ShapeCell("admission", "prefill", n_tokens, 1)
    return cost_model.predict(analytic_census(cfg, cell, n_devices=1,
                                              n_model=1))


def _decode_step_fn(model):
    """``Model.decode_step`` when the model ships one, else the same
    fusion built from ``model.decode`` (the simulation harness's fake
    models only define ``decode``)."""
    if getattr(model, "decode_step", None) is not None:
        return model.decode_step
    return fused_decode_step(model.decode)


def _echo_ok(arr: np.ndarray) -> bool:
    """Per-step integrity probe over the synced ``[2, B]`` token echo.

    Token ids are non-negative by construction (argmax indices; masked
    rows echo their input), so any negative or non-finite value in the
    drained array means the step's output is corrupt — NaN logits argmax
    into garbage, and a poisoned device buffer shows up directly.  The
    check is host-side on the array the drain already paid to sync, so
    the probe adds zero device work and zero extra transfers."""
    a = np.asarray(arr)
    return bool(np.isfinite(a).all() and (a >= 0).all())


class _TunedDispatch:
    """Shared ``step()`` shell: install the engine's autotuner handle for
    the duration of one ``_step()`` so tuned=True kernel lookups hit this
    engine's cache without leaking a process-global handle.

    Also hosts the telemetry/recalibration surface both engines share:
    ``_step_budget`` (SLO token bucket else static budget) and
    ``set_cost_model`` (the online-recalibration swap point)."""

    autotuner = None
    telemetry = None

    def step(self) -> int:
        if self.autotuner is not None:
            from repro.core import autotune as autotune_mod
            with autotune_mod.using(self.autotuner):
                return self._step()
        return self._step()

    def _sync(self, x) -> np.ndarray:
        """THE device->host boundary: every value an engine reads back
        crosses here (explicit ``jax.device_get``, counted), so the
        transfer-guard test can disallow every other transfer."""
        self.stats.host_syncs += 1
        return np.asarray(jax.device_get(x))

    def _step_budget(self) -> Optional[float]:
        """The effective admission budget for this iteration: the SLO
        token bucket when the telemetry controller carries one (refilled
        here — call once per iteration), else the static
        ``step_budget_s``.  The returned number feeds the exact same
        gate arithmetic either way."""
        if self.telemetry is not None:
            budget = self.telemetry.begin_step()
            if budget is not None:
                return budget
        return self.step_budget_s

    def set_cost_model(self, cost_model) -> None:
        """Swap the pricing model in place (online recalibration).

        Clears the prediction cache so every later admission re-prices
        against the new tables; the decode step itself is already an AOT
        executable, and ``_decode_text`` (the compiled HLO captured at
        first pricing) lets ``_predict_decode`` re-price it without
        re-lowering."""
        self.cost_model = cost_model
        self._pred_cache.clear()


class ServingEngine(_TunedDispatch):
    """Slot-granular continuous batching (see module docstring)."""

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512,
                 cost_model: Optional[CostModel] = None,
                 step_budget_s: Optional[float] = None,
                 autotuner=None, clock=None, fused: bool = True,
                 telemetry=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cost_model = cost_model
        self.step_budget_s = step_budget_s
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.bind(self)
        # tuned kernel dispatch: the handle is installed for the duration
        # of each step() so the model's use_pallas hot paths (tuned=True
        # lookups) hit this engine's cache without leaking a process-global
        # handle past the engine's own iterations
        self.autotuner = autotuner
        self._clock = clock if clock is not None else _time
        self.fused = fused
        self.queue: deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self.stats = EngineStats()
        self._rid = itertools.count()
        # slot state
        self.cache = model.init_cache(max_batch, max_len)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.slot_tok = np.zeros(max_batch, np.int32)
        self._pred_cache: Dict = {}
        self._decode_text: Optional[str] = None
        self._pending = None
        step_fn = _decode_step_fn(model)
        if fused:
            # device-resident loop state: the step consumes and reproduces
            # it, so nothing but the [2,B] token echo crosses to host
            self._toks = jnp.zeros((max_batch,), jnp.int32)
            self._pos = jnp.zeros((max_batch,), jnp.int32)

            def fused_step(params, cache, toks, pos):
                nxt, cache = step_fn(params, cache, toks[:, None], pos)
                io = jnp.stack([toks, nxt])      # input echo + outputs
                return io, nxt, pos + 1, cache

            def admit_write(cache, cache1, logits, toks, pos, slot, start):
                def splice(big, small):
                    return jax.lax.dynamic_update_slice_in_dim(
                        big, small.astype(big.dtype), slot, axis=1)
                cache = jax.tree.map(splice, cache, cache1)
                tok0 = jnp.argmax(logits[0]).astype(jnp.int32)
                return (cache, toks.at[slot].set(tok0),
                        pos.at[slot].set(start))

            self._decode = jax.jit(fused_step, donate_argnums=(1,))
            self._admit_fn = jax.jit(admit_write, donate_argnums=(0, 3, 4))
        else:
            self._decode = jax.jit(model.decode)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None,
               submitted_s: Optional[float] = None) -> int:
        """Enqueue one request.  ``submitted_s`` is the external-admission
        hook: the cluster router (``serve.cluster``) re-submits a
        re-routed request with its ORIGINAL arrival time so per-request
        latency accounting survives the move; the default stamps this
        engine's clock."""
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt of {len(prompt)} tokens cannot fit "
                             f"max_len={self.max_len} (needs >= 1 decode "
                             "slot)")
        rid = next(self._rid)
        self.queue.append(Request(rid, prompt, max_new_tokens, eos_id,
                                  submitted_s=self._clock.time()
                                  if submitted_s is None else submitted_s))
        return rid

    def kv_cache_bytes(self) -> int:
        """Resident bytes of the decode cache (the full preallocated
        ``max_batch x max_len`` stripe set, by construction).  With
        ``fused=True`` this is also the *peak*: steps donate the cache
        and update it in place, so no second copy ever materializes."""
        return int(sum(x.nbytes for x in jax.tree.leaves(self.cache)))

    # -- cost-model pricing ---------------------------------------------------
    def _predict_decode(self) -> Prediction:
        """Price one decode step (fixed shape: the padded max_batch).  The
        AOT executable this compiles REPLACES the jitted decode fn — jit's
        dispatch cache would not reuse it, and the decode shapes never
        change — so pricing costs no extra compilation.  Donation carries
        through ``.lower().compile()``, so the AOT path updates the cache
        in place exactly like the jitted one.

        The compiled HLO text is kept (``_decode_text``) so a
        recalibration (``set_cost_model`` clearing ``_pred_cache``) can
        re-price the step without re-lowering — the executable has no
        ``.lower`` once AOT-compiled."""
        key = ("decode", self.max_batch)
        if key not in self._pred_cache:
            if self._decode_text is None:
                pos = jnp.zeros((self.max_batch,), jnp.int32)
                if self.fused:
                    toks = jnp.zeros((self.max_batch,), jnp.int32)
                else:
                    toks = jnp.zeros((self.max_batch, 1), jnp.int32)
                compiled = self._decode.lower(self.params, self.cache,
                                              toks, pos).compile()
                self._decode_text = compiled.as_text()
                self._decode = compiled
            self._pred_cache[key] = self.cost_model.predict_compiled(
                self._decode_text)
        return self._pred_cache[key]

    def _predict_prefill(self, prompt_len: int) -> Prediction:
        """Price one prefill at this prompt length (cached per length);
        see ``_analytic_prefill_prediction`` for why this never
        compiles."""
        key = ("prefill", prompt_len)
        if key not in self._pred_cache:
            self._pred_cache[key] = _analytic_prefill_prediction(
                self.cost_model, self.model.cfg, prompt_len)
        return self._pred_cache[key]

    # -- internals ------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> "tuple[float, int, Optional[float]]":
        """Pack queued prefills into free slots; returns ``(planned,
        admitted, budget)``: the predicted time of this engine iteration
        (0.0 when no cost model is attached), the number of prefills
        admitted, and the budget the gate used (None when ungated).

        With a cost model + budget, admission stops once the predicted
        iteration time (decode step + admitted prefills) would exceed the
        budget — but always admits at least one prefill when a slot is
        free, so the engine cannot starve on an over-tight budget.  The
        budget is ``step_budget_s`` (static) or the SLO token bucket's
        per-step allowance when a telemetry controller carries one
        (``_step_budget``) — same arithmetic, adaptive number."""
        budget = self._step_budget()
        gated = self.cost_model is not None and budget is not None
        planned = self._predict_decode().step_s \
            if self.cost_model is not None else 0.0
        admitted = 0
        free = self._free_slots()
        for idx, slot in enumerate(free):
            if not self.queue:
                break
            if self.cost_model is not None:
                pre_s = self._predict_prefill(
                    len(self.queue[0].prompt)).step_s
                if gated and admitted > 0 \
                        and planned + pre_s > budget:
                    # deferral accounting: walk the queued requests a free
                    # slot could still have taken this step and count ONLY
                    # those whose own predicted prefill would not have fit
                    # in the remaining budget.  Requests blocked purely by
                    # FIFO order behind an over-budget head (they would
                    # have fit) are waiting on ordering, not on the
                    # budget, and are not counted.
                    for q in itertools.islice(self.queue, len(free) - idx):
                        q_s = self._predict_prefill(len(q.prompt)).step_s
                        if planned + q_s > budget:
                            self.stats.deferred_prefills += 1
                    break
                planned += pre_s
            self._prefill_into_slot(slot, self.queue.popleft())
            admitted += 1
        return planned, admitted, budget

    def _prefill_into_slot(self, slot: int, req: Request):
        """Prefill a single request and splice its KV into the batch cache.

        Fused mode: the splice, the first-token argmax and the device
        token/pos scatter run in ONE jitted call with the batch cache and
        the loop-state arrays donated — admission is an in-place slot
        write, not a full new cache tree, and nothing crosses to host
        (the first token reaches ``req.tokens`` through the next step's
        input echo)."""
        S = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache1 = self.model.prefill(self.params, batch,
                                            max_len=self.max_len)
        if self.fused:
            self.cache, self._toks, self._pos = self._admit_fn(
                self.cache, cache1, logits, self._toks, self._pos,
                jnp.asarray(slot, jnp.int32), jnp.asarray(S, jnp.int32))
        else:
            def splice(big, small):
                return big.at[:, slot:slot + 1].set(small.astype(big.dtype))
            self.cache = jax.tree.map(splice, self.cache, cache1)
            self.slot_tok[slot] = int(self._sync(jnp.argmax(logits[0])))
            req.tokens.append(int(self.slot_tok[slot]))
        self.slot_req[slot] = req
        self.slot_pos[slot] = S
        self.stats.prefills += 1
        self.stats.admission_order.append(req.rid)

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.finished_s = self._clock.time()
        self.done[req.rid] = req
        self.slot_req[slot] = None
        self.stats.completed += 1
        if self.telemetry is not None:
            self.telemetry.on_retire(req)

    def _drain(self, pending) -> None:
        """Sync and book one in-flight step: append its tokens (plus the
        echoed prefill token for rows on their first decode), advance the
        host position mirror, retire.  Rows whose slot changed hands
        since dispatch were retired in an earlier drain — their shadow
        tokens are dropped."""
        if pending is None:
            return
        io, snap = pending
        arr = self._sync(io)                 # the ONE transfer of the step
        if not _echo_ok(arr):
            # corrupted step: drop the whole drain rather than book
            # garbage tokens — the supervisor reads this counter's delta
            # and fails the replica (requests are reclaimed by prompt)
            self.stats.integrity_failures += 1
            return
        in_t, out_t = arr[0], arr[1]
        for i, req in snap:
            if self.slot_req[i] is not req:
                continue                     # shadow step of a retired row
            if not req.tokens:
                req.tokens.append(int(in_t[i]))      # prefill's first token
            req.tokens.append(int(out_t[i]))
            self.stats.decoded_tokens += 1
            self.slot_pos[i] += 1
            hit_eos = req.eos_id is not None and req.tokens[-1] == req.eos_id
            out_of_budget = len(req.tokens) >= req.max_new_tokens
            out_of_cache = self.slot_pos[i] >= self.max_len - 1
            if hit_eos or out_of_budget or out_of_cache:
                self._retire(i)

    def _step_record(self, planned: float, measured: float, n_active: int,
                     admitted: int, budget: Optional[float]):
        """One telemetry ``StepRecord`` for this iteration (the slot
        engine dispatches a decode whenever any slot is occupied, so
        ``decode_ran`` is simply ``n_active > 0``)."""
        from repro.serve.telemetry.metrics import StepRecord
        pred = self._pred_cache.get(("decode", self.max_batch))
        return StepRecord(
            engine="slot", step=self.stats.steps, t_s=self._clock.time(),
            n_active=n_active, queue_depth=len(self.queue),
            predicted_s=planned,
            predicted_decode_s=pred.step_s if pred else 0.0,
            measured_s=measured, decode_ran=n_active > 0,
            n_prefill_units=admitted,
            bottleneck=getattr(pred, "bottleneck", ""),
            budget_s=budget if budget is not None else 0.0,
            host_syncs=self.stats.host_syncs,
            table_uploads=self.stats.table_uploads,
            blocks_in_use=0, n_blocks=0,
            decoded_tokens=self.stats.decoded_tokens,
            preemptions=0, deferred=self.stats.deferred_prefills,
            kernel_splits=0,
            integrity_failures=self.stats.integrity_failures)

    def _step(self) -> int:
        """One engine iteration.  Returns #active at dispatch time.
        (``step()`` — the public entry — is the autotuner-installing shell
        inherited from ``_TunedDispatch``.)

        Fused: admit (host work in the shadow of the in-flight step),
        dispatch step N, then drain step N-1 — the sync of a step's
        tokens always happens after the NEXT step is on the device."""
        if not self.fused:
            return self._step_blocking()
        t0 = self._clock.perf_counter()
        prev, self._pending = self._pending, None
        planned, admitted, budget = self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if active:
            io, nxt, pos, self.cache = self._decode(
                self.params, self.cache, self._toks, self._pos)
            self._toks, self._pos = nxt, pos
            self._pending = (io, [(i, self.slot_req[i]) for i in active])
            self.stats.steps += 1
        self._drain(prev)
        measured = self._clock.perf_counter() - t0
        if active and self.cost_model is not None:
            self.stats.predicted_step_s.append(planned)
            self.stats.measured_step_s.append(measured)
        if active and self.telemetry is not None:
            self.telemetry.on_step(self._step_record(
                planned, measured, len(active), admitted, budget))
        return len(active)

    def _step_blocking(self) -> int:
        """The legacy (unfused) iteration: fresh uploads, the [B, vocab]
        logits synced, undonated cache — the decode_hotpath baseline."""
        t0 = self._clock.perf_counter()
        planned, admitted, budget = self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.slot_tok[:, None])
        pos = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        nxt = self._sync(jnp.argmax(logits, axis=-1)).astype(np.int32)
        self.stats.steps += 1
        measured = self._clock.perf_counter() - t0
        if self.cost_model is not None:
            self.stats.predicted_step_s.append(planned)
            self.stats.measured_step_s.append(measured)
        if self.telemetry is not None:
            self.telemetry.on_step(self._step_record(
                planned, measured, len(active), admitted, budget))
        for i in active:
            req = self.slot_req[i]
            req.tokens.append(int(nxt[i]))
            self.stats.decoded_tokens += 1
            self.slot_tok[i] = nxt[i]
            self.slot_pos[i] += 1
            hit_eos = req.eos_id is not None and nxt[i] == req.eos_id
            out_of_budget = len(req.tokens) >= req.max_new_tokens
            out_of_cache = self.slot_pos[i] >= self.max_len - 1
            if hit_eos or out_of_budget or out_of_cache:
                self._retire(i)
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            active = self.step()
            if active == 0 and not self.queue:
                break
        if self._pending is not None:        # max_steps exhausted mid-flight
            self._drain(self._pending)
            self._pending = None
        return self.stats


# ---------------------------------------------------------------------------
# the paged engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Row:
    """One decode row of the paged batch: the request it serves plus its
    prefill progress.  The row's block table lives in the engine's
    ``block_tables`` array (row-indexed), not here."""
    req: Request
    filled: int = 0                 # prompt tokens whose K/V are written
    ready: bool = False             # prefill complete; decodes each step
    pos: int = 0                    # context length == next write position
    last_tok: int = 0               # legacy path only; fused keeps it on device
    dispatched: int = 0             # fused: decode dispatches incl. in-flight


class PagedServingEngine(_TunedDispatch):
    """Continuous batching over a paged KV cache with chunked prefill.

    ``block_size`` defaults to the autotuner's cached ``paged_attention``
    pick when a tuner is attached (the tunable block-size axis), else 16.
    ``n_blocks`` defaults to the slot-equivalent pool
    (``max_batch x ceil(max_len/block_size)``); size it smaller to serve
    the same traffic in strictly less KV memory — preemption-by-eviction
    keeps the engine correct when the pool runs dry.
    """

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512, block_size: Optional[int] = None,
                 n_blocks: Optional[int] = None, chunk_size: int = 32,
                 cost_model: Optional[CostModel] = None,
                 step_budget_s: Optional[float] = None,
                 autotuner=None, clock=None, compact_on_retire: bool = True,
                 fused: bool = True, telemetry=None, mesh=None):
        if model.init_paged_cache is None:
            raise NotImplementedError(
                f"{model.cfg.name}: no paged KV cache for this architecture")
        if mesh is not None and not fused:
            raise ValueError("a sharded replica (mesh=...) requires the "
                             "fused decode path (fused=True); the legacy "
                             "blocking path is single-device by design")
        self.model = model
        self.params = params
        # -- the sharded replica (mesh) ------------------------------------
        # One replica spanning plan.data x plan.model chips: the paged KV
        # pool is laid out with KV heads over 'model' and the [B] decode
        # loop state with batch rows over 'data'
        # (sharding.plans.paged_decode_shardings); block tables stay
        # replicated, so the host-side allocator / eviction / compaction
        # bookkeeping is identical to the single-device engine.  The fused
        # step closures are jitted with explicit in/out shardings — GSPMD
        # partitions the step, donation carries through unchanged (in ==
        # out sharding for the pool), and the [2, B] io echo stays the only
        # device->host sync — so the one-sync-per-step and donation
        # invariants hold verbatim on a mesh.
        self.mesh = mesh
        self._shardings = None
        self.sharding_log: List[str] = []
        if mesh is not None:
            from repro.sharding.plans import (named_tree,
                                              paged_decode_shardings,
                                              sanitize_specs, strip_axis)
            self._shardings = paged_decode_shardings(
                model.cfg, mesh, max_batch, self.sharding_log)
            pshapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
            # params: TP over 'model' only — 'data' stays replicated
            # (strip_axis documents why FSDP-split weights would break
            # the byte-identical-tokens contract)
            pspecs = sanitize_specs(strip_axis(model.param_specs()),
                                    pshapes, mesh, self.sharding_log)
            self._param_sh = named_tree(mesh, pspecs)
            self.params = jax.device_put(params, self._param_sh)
        self.max_batch = max_batch
        self.max_len = max_len
        self.cost_model = cost_model
        self.step_budget_s = step_budget_s
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.bind(self)
        self.autotuner = autotuner
        self._clock = clock if clock is not None else _time
        self.compact_on_retire = compact_on_retire
        self.fused = fused

        # the tuning cache resolves both paged axes here: block_size is a
        # cache-LAYOUT parameter (fixed at pool construction), while
        # num_splits is a launch parameter the kernel re-resolves at
        # dispatch (attention passes tuned=True) — kernel_splits records
        # the resolved value for telemetry either way
        self.kernel_splits = 1
        tuned_cfg = None
        if autotuner is not None:
            cfg = model.cfg
            shapes = {"batch": max_batch, "heads": cfg.n_heads,
                      "kv_heads": cfg.n_kv_heads,
                      "head_dim": cfg.head_dim, "ctx": max_len}
            tuned_cfg = autotuner.config_for("paged_attention", shapes)
            self.kernel_splits = int(tuned_cfg.get("num_splits", 1))
        if block_size is None:
            block_size = (int(tuned_cfg["block_size"])
                          if tuned_cfg is not None else 16)
        self.block_size = block_size
        self.max_blocks_per_seq = blocks_for_tokens(max_len, block_size)
        if n_blocks is None:
            n_blocks = max_batch * self.max_blocks_per_seq
        if n_blocks < self.max_blocks_per_seq:
            # one sequence must always be able to reach max_len, or the
            # oldest-request progress guarantee (and so termination) breaks
            raise ValueError(
                f"n_blocks={n_blocks} < blocks for one max_len sequence "
                f"({self.max_blocks_per_seq})")
        self.n_blocks = n_blocks

        self.allocator = BlockAllocator(n_blocks, block_size)
        self.scheduler = ChunkedPrefillScheduler(
            chunk_size, step_budget_s=step_budget_s)
        self.chunk_size = chunk_size
        if mesh is not None:
            self.cache = model.init_paged_cache(n_blocks, block_size,
                                                mesh=mesh)
        else:
            self.cache = model.init_paged_cache(n_blocks, block_size)
        self.block_tables = np.full(
            (max_batch, self.max_blocks_per_seq), -1, np.int32)
        self._bt_dev = None             # cached device copy of block_tables
        self.rows: List[Optional[_Row]] = [None] * max_batch
        self.done: Dict[int, Request] = {}
        self.stats = EngineStats()
        self._rid = itertools.count()
        self._pred_cache: Dict = {}
        self._decode_text: Optional[str] = None
        self._pending = None
        step_fn = _decode_step_fn(model)
        if fused:
            self._toks = self._dev(np.zeros(max_batch, np.int32), "batch")

            def fused_decode(params, cache, toks, pos, bt):
                nxt, cache = step_fn(params, cache, toks[:, None], pos, bt)
                io = jnp.stack([toks, nxt])
                # masked rows (pos < 0) keep their resident token
                return io, jnp.where(pos >= 0, nxt, toks), cache

            def fused_chunk(params, cache, toks, start, bt, toks_dev, idx,
                            final):
                nxt, cache = step_fn(params, cache, toks, start, bt)
                # only a prompt's FINAL chunk yields its first token;
                # intermediate chunks leave the row's slot untouched
                tok0 = jnp.where(final, nxt[0], toks_dev[idx])
                return cache, toks_dev.at[idx].set(tok0)

            if mesh is None:
                self._decode = jax.jit(fused_decode, donate_argnums=(1,))
                self._chunk = jax.jit(fused_chunk, donate_argnums=(1, 5))
            else:
                # explicit in/out shardings: GSPMD partitions the step, and
                # — critically — they survive ``.lower().compile()``, so the
                # AOT executable ``_predict_decode`` swaps in keeps the
                # exact same layout contract as the jitted path.  The pool
                # keeps one sharding on both sides of the step, so donation
                # is an in-place per-shard update, never a reshard.
                sh = self._shardings
                pool_sh = jax.tree.map(lambda _: sh["pool"], self.cache)
                self._pool_sh = pool_sh
                self._decode = jax.jit(
                    fused_decode, donate_argnums=(1,),
                    in_shardings=(self._param_sh, pool_sh, sh["batch"],
                                  sh["batch"], sh["repl"]),
                    out_shardings=(sh["io"], sh["batch"], pool_sh))
                self._chunk = jax.jit(
                    fused_chunk, donate_argnums=(1, 5),
                    in_shardings=(self._param_sh, pool_sh, sh["repl"],
                                  sh["repl"], sh["repl"], sh["batch"],
                                  sh["repl"], sh["repl"]),
                    out_shardings=(pool_sh, sh["batch"]))
        else:
            self._decode = jax.jit(model.decode)     # batch decode [B, 1]
            self._chunk = jax.jit(model.decode)      # chunk prefill [1, C]

    # -- public ---------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None,
               submitted_s: Optional[float] = None) -> int:
        """Enqueue one request.  ``submitted_s`` is the external-admission
        hook (see the slot engine's ``submit``): a cluster re-route keeps
        the request's original arrival time for latency accounting."""
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) >= self.max_len:
            # over-long prompts must be rejected HERE: mid-trace they
            # would grow past the fixed-width block table and strand a
            # freshly-allocated block outside any table (a pool leak)
            raise ValueError(f"prompt of {len(prompt)} tokens cannot fit "
                             f"max_len={self.max_len} (needs >= 1 decode "
                             "slot)")
        rid = next(self._rid)
        self.scheduler.submit(Request(rid, prompt, max_new_tokens, eos_id,
                                      submitted_s=self._clock.time()
                                      if submitted_s is None else submitted_s))
        return rid

    @property
    def queue(self):
        return self.scheduler.queue

    def kv_cache_bytes(self) -> int:
        """Resident bytes of the paged KV store: ``n_blocks x block_size``
        token slots regardless of ``max_batch x max_len``.  Fused steps
        donate the pool, so this is the peak too."""
        return int(sum(x.nbytes for x in jax.tree.leaves(self.cache)))

    # -- cost-model pricing ---------------------------------------------------
    def _predict_decode(self) -> Prediction:
        """Price the paged decode step; like the slot engine, the AOT
        executable replaces the jitted decode (shapes never change) and
        keeps the jit path's pool donation.  The compiled HLO text is
        kept (``_decode_text``) so recalibration can re-price without
        re-lowering (see the slot engine's ``_predict_decode``)."""
        key = ("decode", self.max_batch)
        if key not in self._pred_cache:
            if self._decode_text is None:
                pos = jnp.zeros((self.max_batch,), jnp.int32)
                bt = jnp.full((self.max_batch, self.max_blocks_per_seq), -1,
                              jnp.int32)
                if self.fused:
                    toks = jnp.zeros((self.max_batch,), jnp.int32)
                else:
                    toks = jnp.zeros((self.max_batch, 1), jnp.int32)
                compiled = self._decode.lower(self.params, self.cache, toks,
                                              pos, bt).compile()
                self._decode_text = compiled.as_text()
                self._decode = compiled
            self._pred_cache[key] = self.cost_model.predict_compiled(
                self._decode_text)
        return self._pred_cache[key]

    def _predict_chunk(self) -> Prediction:
        """Price one prefill chunk as a chunk_size-token prefill (chunks
        never shrink: final partial chunks overlap).

        APPROXIMATION: the analytic census is parameter-streaming
        dominated and linear in tokens — it does not model attention over
        the row's already-filled context, for chunks here exactly as for
        whole prompts in the slot engine's ``_predict_prefill``.  Late
        chunks of a long prompt therefore cost somewhat more than this
        gate charges them; the budget bounds chunk COUNT per step
        faithfully, not long-context attention."""
        key = ("chunk", self.chunk_size)
        if key not in self._pred_cache:
            self._pred_cache[key] = _analytic_prefill_prediction(
                self.cost_model, self.model.cfg, self.chunk_size)
        return self._pred_cache[key]

    # -- block management -----------------------------------------------------
    def _retirement_bound(self, row: _Row) -> bool:
        """True when the row cannot legitimately decode again — its
        retirement is already in the pending drain, so any further
        dispatch is a pure shadow step.  Two host-computable cases: a
        prior dispatch reached the cache-ceiling retire point
        (pos_after >= max_len-1; a fresh prefill AT max_len-1 still owes
        its one decode), or every token the budget allows is already
        dispatched (delivered length after D drained dispatches is D+1;
        retire at >= max_new, with the legacy floor of one decode).
        Only eos retirements, which need the synced token, are not
        predictable here."""
        if row.dispatched > 0 and row.pos >= self.max_len - 1:
            return True
        return row.dispatched >= max(row.req.max_new_tokens - 1, 1)

    def _dev(self, x, kind: str = "repl"):
        """THE host->device boundary for per-step operands.  Unsharded:
        a plain uncommitted upload (``jnp.asarray``), exactly the old
        behavior.  Sharded: an explicit ``jax.device_put`` onto the
        replica mesh with the named sharding — required because the AOT
        decode executable (``_predict_decode``) checks operand shardings
        instead of auto-resharding, and because an uncommitted
        single-device array would not even live on the mesh's device
        set."""
        if self.mesh is None:
            return jnp.asarray(x)
        return jax.device_put(np.asarray(x), self._shardings[kind])

    def _bt_device(self):
        """The device block tables, uploaded only when a table row
        actually mutated (growth, eviction, retire, compaction) instead
        of fresh per step.  Replicated on a mesh: every shard reads the
        whole table to translate logical slots to physical blocks."""
        if self._bt_dev is None:
            self._bt_dev = self._dev(self.block_tables)
            self.stats.table_uploads += 1
        return self._bt_dev

    def _row_blocks(self, idx: int) -> List[int]:
        return [int(b) for b in self.block_tables[idx] if b >= 0]

    def _free_row(self, idx: int) -> None:
        self.allocator.free(self._row_blocks(idx))
        self.block_tables[idx] = -1
        self._bt_dev = None
        self.rows[idx] = None

    def _placed(self) -> List[int]:
        return [i for i, r in enumerate(self.rows) if r is not None]

    def _evict_for(self, needy: int) -> bool:
        """Free blocks by evicting a victim row.  Victim: the YOUNGEST
        placed request, excluding the needy row itself and the OLDEST
        placed request (never evicted — that guarantee makes the engine
        terminate: the oldest always keeps its blocks, completes, and
        frees them).  Returns False when no eligible victim exists."""
        placed = self._placed()
        oldest = min(placed, key=lambda i: self.rows[i].req.rid)
        cands = [i for i in placed if i != needy and i != oldest]
        if not cands:
            return False
        victim = max(cands, key=lambda i: self.rows[i].req.rid)
        row = self.rows[victim]
        req = row.req
        self._free_row(victim)
        # the victim replays from scratch: roll back its DELIVERED-token
        # accounting so replayed tokens are not double-counted (the
        # paged_serve throughput comparison reads decoded_tokens).
        # prefill_chunks/preemptions stay — they record work actually
        # done.  ``row.ready`` (not ``req.tokens``) keys the rollback:
        # on the fused path a ready row's first token may still be in
        # flight (the echo), leaving the list briefly empty.
        if row.ready:
            self.stats.decoded_tokens -= max(len(req.tokens) - 1, 0)
            self.stats.prefills -= 1
        req.tokens.clear()           # replayed from scratch on re-admission
        self.scheduler.requeue(req)
        self.stats.preemptions += 1
        return True

    def _ensure_blocks(self, idx: int, n_needed: int) -> bool:
        """Grow row ``idx``'s block table to ``n_needed`` blocks, evicting
        if the pool is dry.  Returns False when the row must wait."""
        if n_needed > self.max_blocks_per_seq:
            # unreachable given the submit() length check + the
            # max_len - 1 retire cap, but fail loudly BEFORE allocating:
            # a block granted past the table width belongs to no table
            # and would leak
            raise AssertionError(
                f"row {idx} needs {n_needed} blocks > table width "
                f"{self.max_blocks_per_seq}")
        bt = self.block_tables[idx]
        have = int((bt >= 0).sum())
        while have < n_needed:
            b = self.allocator.alloc()
            if b is None:
                if not self._evict_for(idx):
                    return False
                continue
            bt[have] = b
            have += 1
            self._bt_dev = None      # table row mutated
        return True

    def _maybe_compact(self) -> None:
        """Copy-on-retire compaction: densify the allocated blocks so the
        touched span of the pool stays minimal.  One functional
        gather-then-scatter per cache leaf, so overlapping moves are safe."""
        if not self.compact_on_retire:
            return
        plan = self.allocator.compaction_plan()
        if plan is None:
            return
        src, dst = plan
        s = self._dev(np.asarray(src, np.int32))
        d = self._dev(np.asarray(dst, np.int32))
        self.cache = jax.tree.map(
            lambda c: c.at[:, d].set(c[:, s]), self.cache)
        if self.mesh is not None:
            # the block axis (1) is unsharded, so the copy is shard-local;
            # re-pin the result in case eager sharding propagation picked
            # a different layout — the AOT decode executable checks
            # operand shardings instead of auto-resharding
            self.cache = jax.device_put(self.cache, self._pool_sh)
        for i in self._placed():
            self.block_tables[i] = remap_table(
                list(self.block_tables[i]), src, dst)
        self._bt_dev = None
        self.allocator.commit_compaction()
        self.stats.compactions += 1

    # -- prefill chunks -------------------------------------------------------
    def _place(self, req: Request) -> Optional[int]:
        free = [i for i, r in enumerate(self.rows) if r is None]
        if not free:
            return None
        idx = free[0]
        self.rows[idx] = _Row(req)
        self.scheduler.take(req)
        self.stats.admission_order.append(req.rid)
        return idx

    def _run_chunk(self, idx: int) -> None:
        """Advance row ``idx``'s prefill by one chunk.

        Chunks are always exactly ``chunk_size`` tokens so the jitted call
        never retraces: the final chunk of a prompt *overlaps* already-
        written positions (re-running the same tokens against the same
        cache rewrites identical K/V — chunked prefill is deterministic),
        and prompts shorter than one chunk are LEFT-padded with the write
        positions pushed negative, which the paged scatter drops.

        Fused: the pool is donated, and the final chunk's first-token
        argmax lands in the device token array (no host transfer — the
        value reaches ``req.tokens`` via the first decode's echo)."""
        row = self.rows[idx]
        req, C = row.req, self.chunk_size
        S = len(req.prompt)
        end = min(row.filled + C, S)
        start = end - C              # < filled on overlap, < 0 on left-pad
        if not self._ensure_blocks(idx, blocks_for_tokens(end,
                                                          self.block_size)):
            return                   # pool dry, no victim: retry next step
        if self.rows[idx] is not row:
            return                   # the eviction chain took this row
        toks = np.zeros(C, np.int32)
        lo = max(start, 0)
        toks[C - (end - lo):] = req.prompt[lo:end]
        bt = self._bt_device()[idx:idx + 1]
        if self.fused:
            self.cache, self._toks = self._chunk(
                self.params, self.cache, self._dev(toks[None]),
                self._dev(np.asarray([start], np.int32)), bt, self._toks,
                self._dev(np.int32(idx)), self._dev(end == S))
        else:
            logits, self.cache = self._chunk(
                self.params, self.cache, jnp.asarray(toks[None]),
                jnp.asarray([start], jnp.int32), bt)
        row.filled = end
        self.stats.prefill_chunks += 1
        if end == S:
            row.ready = True
            row.pos = S
            self.stats.prefills += 1
            if not self.fused:
                row.last_tok = int(self._sync(jnp.argmax(logits[0])))
                req.tokens.append(row.last_tok)

    # -- the engine iteration -------------------------------------------------
    def _step(self) -> int:
        """One iteration: plan, run prefill chunks, dispatch the decode,
        then drain the PREVIOUS step (fused) — so step N's tokens are
        synced only after step N+1 is on the device, and retire/admit/
        schedule bookkeeping runs in the device step's shadow.  Returns
        the number of placed rows (>= 1 while a step is still in
        flight).  (``step()`` is the inherited autotuner-installing
        shell.)"""
        t0 = self._clock.perf_counter()
        prev, self._pending = self._pending, None
        unfinished = sorted(
            ((i, self.rows[i].req.rid, self.rows[i].req)
             for i in self._placed() if not self.rows[i].ready),
            key=lambda t: t[1])
        n_free = self.rows.count(None)
        any_ready = any(r is not None and r.ready for r in self.rows)
        if not unfinished and not any_ready and not self.scheduler.queue:
            self._drain(prev)        # flush the tail step, if any
            return 0
        budget = self._step_budget()
        gated = self.cost_model is not None and budget is not None
        decode_s = self._predict_decode().step_s \
            if self.cost_model is not None else 0.0
        chunk_s = self._predict_chunk().step_s \
            if self.cost_model is not None else 0.0
        chunks_before = self.stats.prefill_chunks
        plan = self.scheduler.plan(
            unfinished=unfinished, n_free_rows=n_free, any_ready=any_ready,
            decode_s=decode_s, chunk_s=chunk_s, gated=gated,
            budget_s=budget)
        self.stats.deferred_prefills += plan.deferred

        for item in plan.items:
            if item.row is None:
                idx = self._place(item.request)
                if idx is None:      # an eviction refilled the rows
                    continue
            else:
                idx = item.row
                if (self.rows[idx] is None
                        or self.rows[idx].req.rid != item.rid):
                    continue         # evicted mid-step; replanned later
            self._run_chunk(idx)

        active = self._decode_phase()

        # the allocator records the exact intra-step peak (a row can grow
        # a block AND retire within one _decode_phase; sampling n_in_use
        # here would miss that high-water mark)
        self.stats.peak_blocks_in_use = self.allocator.peak_in_use
        did_work = bool(plan.items) or active
        if did_work:
            # sampled iff the step counts, so occupancy and steps stay
            # one-to-one (an iteration can dispatch nothing when its only
            # ready rows are retirement-bound in the pending drain)
            self.stats.block_occupancy.append(self.allocator.occupancy)
        self._drain(prev)
        if did_work:
            self.stats.steps += 1
            measured = self._clock.perf_counter() - t0
            if self.cost_model is not None:
                self.stats.predicted_step_s.append(plan.predicted_s)
                self.stats.measured_step_s.append(measured)
            if self.telemetry is not None:
                self.telemetry.on_step(self._step_record(
                    plan.predicted_s, measured, active,
                    self.stats.prefill_chunks - chunks_before, budget))
        n = len(self._placed())
        return n if self._pending is None else max(n, 1)

    def _step_record(self, planned: float, measured: float,
                     n_decoded_rows: int, n_chunks: int,
                     budget: Optional[float]):
        """One telemetry ``StepRecord`` for this iteration.
        ``n_prefill_units`` counts chunks actually RUN (a planned chunk
        can be skipped when the pool is dry), so drift attribution sees
        the work the measured latency paid for."""
        from repro.serve.telemetry.metrics import StepRecord
        pred = self._pred_cache.get(("decode", self.max_batch))
        return StepRecord(
            engine="paged", step=self.stats.steps, t_s=self._clock.time(),
            n_active=len(self._placed()),
            queue_depth=len(self.scheduler.queue),
            predicted_s=planned,
            predicted_decode_s=pred.step_s if pred else 0.0,
            measured_s=measured, decode_ran=n_decoded_rows > 0,
            n_prefill_units=n_chunks,
            bottleneck=getattr(pred, "bottleneck", ""),
            budget_s=budget if budget is not None else 0.0,
            host_syncs=self.stats.host_syncs,
            table_uploads=self.stats.table_uploads,
            blocks_in_use=self.allocator.n_in_use, n_blocks=self.n_blocks,
            decoded_tokens=self.stats.decoded_tokens,
            preemptions=self.stats.preemptions,
            deferred=self.stats.deferred_prefills,
            kernel_splits=self.kernel_splits,
            integrity_failures=self.stats.integrity_failures)

    def _decode_phase(self) -> int:
        """Batched decode over the ready rows; rows mid-prefill (or whose
        block growth must wait) ride along masked out via write_pos=-1."""
        ready = [i for i in self._placed() if self.rows[i].ready]
        if not ready:
            return 0
        stepping = []
        for i in ready:
            row = self.rows[i]
            if row is None or not row.ready:
                continue             # evicted by an earlier row's growth
            if self.fused and self._retirement_bound(row):
                # pipelining: the row's retirement is already determined
                # by host-visible state (cache ceiling / token budget) and
                # sits in the pending drain — a further shadow dispatch
                # would only burn a step and could grow a block (even
                # evicting a LIVE victim) for output the drain drops.
                # Only eos retirements, which need the synced token,
                # still cost one shadow step.
                continue
            need = blocks_for_tokens(row.pos + 1, self.block_size)
            if self._ensure_blocks(i, need) and self.rows[i] is row:
                stepping.append((i, row))
        # a LATER row's block growth may have evicted a row already
        # collected above — re-validate the whole list before stepping
        stepping = [(i, row) for i, row in stepping if self.rows[i] is row]
        if not stepping:
            return 0
        pos = np.full(self.max_batch, -1, np.int32)
        for i, row in stepping:
            pos[i] = row.pos
        if self.fused:
            io, self._toks, self.cache = self._decode(
                self.params, self.cache, self._toks,
                self._dev(pos, "batch"), self._bt_device())
            # the snapshot carries each row's post-step position: that is
            # the value retire checks compare against at drain time
            # (row.pos itself may advance again before the drain)
            self._pending = (io, [(i, row, row.pos + 1)
                                  for i, row in stepping])
            for i, row in stepping:
                row.pos += 1
                row.dispatched += 1
            return len(stepping)
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, row in stepping:
            toks[i, 0] = row.last_tok
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            self._bt_device())
        nxt = self._sync(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for i, row in stepping:
            req = row.req
            req.tokens.append(int(nxt[i]))
            self.stats.decoded_tokens += 1
            row.last_tok = int(nxt[i])
            row.pos += 1
            hit_eos = req.eos_id is not None and nxt[i] == req.eos_id
            out_of_budget = len(req.tokens) >= req.max_new_tokens
            out_of_cache = row.pos >= self.max_len - 1
            if hit_eos or out_of_budget or out_of_cache:
                self._retire(i)
        return len(stepping)

    def _drain(self, pending) -> None:
        """Sync and book one in-flight fused step (see the slot engine's
        ``_drain``); rows evicted or retired since dispatch are dropped
        by identity, so replays and shadow steps never double-count."""
        if pending is None:
            return
        io, snap = pending
        arr = self._sync(io)
        if not _echo_ok(arr):
            self.stats.integrity_failures += 1   # see the slot _drain
            return
        in_t, out_t = arr[0], arr[1]
        for i, row, pos_after in snap:
            if self.rows[i] is not row:
                continue
            req = row.req
            if not req.tokens:
                req.tokens.append(int(in_t[i]))      # echoed prefill token
            req.tokens.append(int(out_t[i]))
            self.stats.decoded_tokens += 1
            hit_eos = req.eos_id is not None and req.tokens[-1] == req.eos_id
            out_of_budget = len(req.tokens) >= req.max_new_tokens
            out_of_cache = pos_after >= self.max_len - 1
            if hit_eos or out_of_budget or out_of_cache:
                self._retire(i)

    def _retire(self, idx: int) -> None:
        req = self.rows[idx].req
        req.finished_s = self._clock.time()
        self.done[req.rid] = req
        self._free_row(idx)
        self.stats.completed += 1
        if self.telemetry is not None:
            self.telemetry.on_retire(req)
        self._maybe_compact()

    def run_until_done(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            active = self.step()
            if active == 0 and not self.scheduler.queue:
                break
        if self._pending is not None:        # max_steps exhausted mid-flight
            self._drain(self._pending)
            self._pending = None
        self.allocator.check()
        return self.stats
