"""Batched serving engine: continuous batching over a fixed decode batch.

Requests queue in; the engine packs up to `max_batch` concurrent sequences
into one KV cache, prefills new arrivals into free slots (per-slot write
positions — the model's decode path already takes per-row `pos`), decodes
one token per step for every active slot, and retires sequences on EOS or
length budget.  This is the vLLM-style loop reduced to its scheduling core,
with slot-granular (not paged) KV memory.

Admission control is cost-model-driven when a ``repro.core.costmodel.
CostModel`` is supplied: the engine prices the decode step and each pending
prefill from their compiled modules' instruction censuses, and packs
prefills into an engine iteration only while the predicted iteration time
(decode + admitted prefills) stays under ``step_budget_s`` — the predicted
decode-step latency gates how many prefills ride along, instead of greedily
stuffing every free slot and stalling in-flight decodes behind a wall of
prefill compute.

Kernel dispatch is autotuner-aware: pass an ``repro.core.autotune.
Autotuner`` (with its persistent tuning cache) and the engine installs it
as the dispatch handle for the duration of each ``step()``, so every
``tuned=True`` Pallas kernel call inside the model (flash attention in
prefill, the recurrent scans) resolves its launch config from the tuned
cache instead of the hardcoded defaults — and two engines with different
tuners (or none) never leak configs into each other.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel.model import CostModel, Prediction
from repro.models.zoo import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    prefills: int = 0
    decoded_tokens: int = 0
    completed: int = 0
    deferred_prefills: int = 0      # admissions pushed to a later step
    predicted_step_s: List[float] = dataclasses.field(default_factory=list)
    measured_step_s: List[float] = dataclasses.field(default_factory=list)


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512,
                 cost_model: Optional[CostModel] = None,
                 step_budget_s: Optional[float] = None,
                 autotuner=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cost_model = cost_model
        self.step_budget_s = step_budget_s
        # tuned kernel dispatch: the handle is installed for the duration
        # of each step() so the model's use_pallas hot paths (tuned=True
        # lookups) hit this engine's cache without leaking a process-global
        # handle past the engine's own iterations
        self.autotuner = autotuner
        self.queue: deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self.stats = EngineStats()
        self._rid = itertools.count()
        # slot state
        self.cache = model.init_cache(max_batch, max_len)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.slot_tok = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(model.decode)
        self._pred_cache: Dict = {}

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id,
                                  submitted_s=time.time()))
        return rid

    # -- cost-model pricing ---------------------------------------------------
    def _predict_decode(self) -> Prediction:
        """Price one decode step (fixed shape: the padded max_batch).  The
        AOT executable this compiles REPLACES the jitted decode fn — jit's
        dispatch cache would not reuse it, and the decode shapes never
        change — so pricing costs no extra compilation."""
        key = ("decode", self.max_batch)
        if key not in self._pred_cache:
            toks = jnp.zeros((self.max_batch, 1), jnp.int32)
            pos = jnp.zeros((self.max_batch,), jnp.int32)
            compiled = self._decode.lower(self.params, self.cache,
                                          toks, pos).compile()
            self._pred_cache[key] = self.cost_model.predict_compiled(
                compiled.as_text())
            self._decode = compiled
        return self._pred_cache[key]

    def _predict_prefill(self, prompt_len: int) -> Prediction:
        """Price one prefill at this prompt length (cached per length).

        Priced ANALYTICALLY (``costmodel.analytic``), not by compiling the
        prefill — the admission loop runs per engine step and a per-length
        XLA compile there would stall serving for pure bookkeeping (the
        execution path calls ``model.prefill`` eagerly and never reuses
        such a compile)."""
        key = ("prefill", prompt_len)
        if key not in self._pred_cache:
            from repro.configs.base import ShapeCell
            from repro.core.costmodel.analytic import analytic_census
            cell = ShapeCell("admission", "prefill", prompt_len, 1)
            census = analytic_census(self.model.cfg, cell, n_devices=1,
                                     n_model=1)
            self._pred_cache[key] = self.cost_model.predict(census)
        return self._pred_cache[key]

    # -- internals ------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> float:
        """Pack queued prefills into free slots; returns the predicted time
        of this engine iteration (0.0 when no cost model is attached).

        With a cost model + budget, admission stops once the predicted
        iteration time (decode step + admitted prefills) would exceed the
        budget — but always admits at least one prefill when a slot is
        free, so the engine cannot starve on an over-tight budget."""
        gated = (self.cost_model is not None
                 and self.step_budget_s is not None)
        planned = self._predict_decode().step_s \
            if self.cost_model is not None else 0.0
        admitted = 0
        free = self._free_slots()
        for idx, slot in enumerate(free):
            if not self.queue:
                break
            if self.cost_model is not None:
                pre_s = self._predict_prefill(
                    len(self.queue[0].prompt)).step_s
                if gated and admitted > 0 \
                        and planned + pre_s > self.step_budget_s:
                    # count only requests a free slot could have taken
                    # this step; they retry next step
                    self.stats.deferred_prefills += min(
                        len(self.queue), len(free) - idx)
                    break
                planned += pre_s
            self._prefill_into_slot(slot, self.queue.popleft())
            admitted += 1
        return planned

    def _prefill_into_slot(self, slot: int, req: Request):
        """Prefill a single request and splice its KV into the batch cache."""
        S = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache1 = self.model.prefill(self.params, batch,
                                            max_len=self.max_len)
        def splice(big, small):
            return big.at[:, slot:slot + 1].set(small.astype(big.dtype))
        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.slot_req[slot] = req
        self.slot_pos[slot] = S
        self.slot_tok[slot] = int(jnp.argmax(logits[0]))
        req.tokens.append(int(self.slot_tok[slot]))
        self.stats.prefills += 1

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.finished_s = time.time()
        self.done[req.rid] = req
        self.slot_req[slot] = None
        self.stats.completed += 1

    def step(self) -> int:
        """One engine iteration: admit, decode, retire.  Returns #active."""
        if self.autotuner is not None:
            from repro.core import autotune as autotune_mod
            with autotune_mod.using(self.autotuner):
                return self._step()
        return self._step()

    def _step(self) -> int:
        t0 = time.perf_counter()
        planned = self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.slot_tok[:, None])
        pos = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.stats.steps += 1
        if self.cost_model is not None:
            self.stats.predicted_step_s.append(planned)
            self.stats.measured_step_s.append(time.perf_counter() - t0)
        for i in active:
            req = self.slot_req[i]
            req.tokens.append(int(nxt[i]))
            self.stats.decoded_tokens += 1
            self.slot_tok[i] = nxt[i]
            self.slot_pos[i] += 1
            hit_eos = req.eos_id is not None and nxt[i] == req.eos_id
            out_of_budget = len(req.tokens) >= req.max_new_tokens
            out_of_cache = self.slot_pos[i] >= self.max_len - 1
            if hit_eos or out_of_budget or out_of_cache:
                self._retire(i)
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            active = self.step()
            if active == 0 and not self.queue:
                break
        return self.stats
