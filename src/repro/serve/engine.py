"""Batched serving engine: continuous batching over a fixed decode batch.

Requests queue in; the engine packs up to `max_batch` concurrent sequences
into one KV cache, prefills new arrivals into free slots (per-slot write
positions — the model's decode path already takes per-row `pos`), decodes
one token per step for every active slot, and retires sequences on EOS or
length budget.  This is the vLLM-style loop reduced to its scheduling core,
with slot-granular (not paged) KV memory.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.zoo import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    prefills: int = 0
    decoded_tokens: int = 0
    completed: int = 0


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self.stats = EngineStats()
        self._rid = itertools.count()
        # slot state
        self.cache = model.init_cache(max_batch, max_len)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.slot_tok = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(model.decode)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id,
                                  submitted_s=time.time()))
        return rid

    # -- internals ------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _prefill_into_slot(self, slot: int, req: Request):
        """Prefill a single request and splice its KV into the batch cache."""
        S = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache1 = self.model.prefill(self.params, batch,
                                            max_len=self.max_len)
        def splice(big, small):
            return big.at[:, slot:slot + 1].set(small.astype(big.dtype))
        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.slot_req[slot] = req
        self.slot_pos[slot] = S
        self.slot_tok[slot] = int(jnp.argmax(logits[0]))
        req.tokens.append(int(self.slot_tok[slot]))
        self.stats.prefills += 1

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.finished_s = time.time()
        self.done[req.rid] = req
        self.slot_req[slot] = None
        self.stats.completed += 1

    def step(self) -> int:
        """One engine iteration: admit, decode, retire.  Returns #active."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._prefill_into_slot(slot, self.queue.popleft())
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.slot_tok[:, None])
        pos = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.stats.steps += 1
        for i in active:
            req = self.slot_req[i]
            req.tokens.append(int(nxt[i]))
            self.stats.decoded_tokens += 1
            self.slot_tok[i] = nxt[i]
            self.slot_pos[i] += 1
            hit_eos = req.eos_id is not None and nxt[i] == req.eos_id
            out_of_budget = len(req.tokens) >= req.max_new_tokens
            out_of_cache = self.slot_pos[i] >= self.max_len - 1
            if hit_eos or out_of_budget or out_of_cache:
                self._retire(i)
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            active = self.step()
            if active == 0 and not self.queue:
                break
        return self.stats
