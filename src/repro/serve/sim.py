"""Deterministic engine-simulation harness — first-class, not test-only.

Everything nondeterministic about serving is injected through three
fakes, so scheduler/telemetry behaviour is an exact computation instead
of a flaky wall-clock observation:

* :class:`SimClock` replaces ``time.time``/``time.perf_counter`` — both
  engines take a ``clock=`` object, so timestamps advance only when the
  trace driver says so and every submitted/finished time is an exact
  scripted value.
* :class:`FakeModel` replaces the transformer: decode is a pure-jnp
  arithmetic rule (next token = last token + 1 mod vocab), so the
  *expected* output of every request is computable in the test
  (:func:`expected_tokens`), and the shapes the engine feeds the model
  are recorded at trace time (jit traces once per shape — the recording
  IS the shape census).
* :class:`FakeCostModel` replaces calibrated pricing with a constant
  table, making the scheduler's budget arithmetic — and therefore the
  exact ``deferred_prefills`` count per step — a hand-checkable
  computation.  Its :meth:`FakeCostModel.rescale` implements the online-
  recalibration protocol (``serve.telemetry``): a drift event rescales
  the table entry it fired on, exactly like a real ``Calibration``
  update, but as one multiply.

This module started life inside ``tests/test_serve_sim.py`` (PR 4) and
was promoted here so the telemetry layer's drift/overload scenarios
(``serve.telemetry.scenarios``), the ``telemetry_replay`` campaign
experiment, and the CI smoke CLI can all drive the engines without
hardware — the tests now import the harness from here.
"""
from __future__ import annotations

import dataclasses
from collections import deque


class SimClock:
    """Injected in place of the ``time`` module: advances only on demand.

    ``time()`` and ``perf_counter()`` both read the same scripted value;
    :meth:`advance` is the only way time passes.
    """

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def time(self) -> float:
        return self.t

    def perf_counter(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@dataclasses.dataclass
class _Pred:
    step_s: float


class FakeCostModel:
    """Constant (or census-derived) prices; only ``.step_s`` is consumed.

    ``decode_s`` prices the batched decode step (``predict_compiled``),
    ``prefill_s`` one analytic prefill/chunk (``predict``).  A
    ``predict_fn(census)`` overrides the constant prefill price with a
    census-derived one (e.g. proportional to flops).

    ``rescale`` is the online-recalibration hook
    (``serve.telemetry.recalibrate``): multiply the named table entry by
    ``factor`` — the fake's one-row equivalent of rescaling a
    ``Calibration`` table from live measurements.
    """

    def __init__(self, decode_s=1.0, prefill_s=1.0, predict_fn=None):
        self.decode_s = decode_s
        self.prefill_s = prefill_s
        self.predict_fn = predict_fn
        self.rescales = []          # (kind, factor) audit trail

    def predict(self, census, **kw):
        if self.predict_fn is not None:
            return _Pred(self.predict_fn(census))
        return _Pred(self.prefill_s)

    def predict_compiled(self, compiled_text, **kw):
        return _Pred(self.decode_s)

    def rescale(self, kind: str, factor: float) -> None:
        """Recalibrate one price in place: ``decode`` scales the step
        table entry, anything else the prefill/chunk entry."""
        if kind == "decode":
            self.decode_s *= factor
        else:
            self.prefill_s *= factor
        self.rescales.append((kind, factor))


class FakeModel:
    """Minimal paged-decodeable model: next token = last + 1 (mod vocab).

    ``decode_shapes`` records every (tokens, block_tables) shape pair the
    engine traces — the recorded prefill/decode shape census.
    """

    def __init__(self, vocab=97, cfg=None):
        from repro.configs import ARCHS, reduced
        self.vocab = vocab
        self.cfg = cfg if cfg is not None else reduced(
            ARCHS["gemma2-2b"], n_layers=2, vocab_size=vocab)
        self.decode_shapes = []

    def decode(self, params, cache, tokens, pos, block_tables=None):
        import jax
        self.decode_shapes.append(
            (tuple(tokens.shape),
             None if block_tables is None else tuple(block_tables.shape)))
        nxt = (tokens[:, -1] + 1) % self.vocab
        return jax.nn.one_hot(nxt, self.vocab), cache

    def init_paged_cache(self, n_blocks, block_size):
        import jax.numpy as jnp
        shape = (1, n_blocks, block_size, 1, 1)
        return {"k": jnp.zeros(shape, jnp.bfloat16),
                "v": jnp.zeros(shape, jnp.bfloat16)}


def expected_tokens(prompt, n, vocab, eos_id=None):
    """What :class:`FakeModel` greedily generates for ``prompt``."""
    out, t = [], int(prompt[-1])
    for _ in range(n):
        t = (t + 1) % vocab
        out.append(t)
        if eos_id is not None and t == eos_id:
            break
    return out


def drive(engine, clock, arrivals, dt=1.0, max_steps=500):
    """Scripted-trace driver: submit each (t, prompt, max_new, eos) at its
    arrival time, stepping the engine once per clock tick.  Returns
    {rid: arrival_time} for every submitted request."""
    import numpy as np
    pending = deque(sorted(arrivals, key=lambda a: a[0]))
    rids = {}
    for _ in range(max_steps):
        while pending and pending[0][0] <= clock.t:
            t, prompt, max_new, eos = pending.popleft()
            rids[engine.submit(np.asarray(prompt, np.int32),
                               max_new_tokens=max_new, eos_id=eos)] = t
        active = engine.step()
        clock.advance(dt)
        if not pending and active == 0 and not len(engine.queue):
            break
    return rids


def work_latency_model(decode_s: float, chunk_s: float,
                       overhead_s: float = 0.0):
    """A deterministic stand-in for measured step latency: charge the
    "true" per-unit costs for the work one step record says the engine
    actually did.  ``serve.telemetry.TelemetryController`` accepts this
    as ``latency_model=`` so drift and SLO feedback loops close in
    simulation exactly as they would against a wall clock — the sim's
    ground truth replaces ``perf_counter`` deltas, which a
    :class:`SimClock` (frozen within a step) measures as zero."""

    def latency(record) -> float:
        s = overhead_s + chunk_s * record.n_prefill_units
        if record.decode_ran:
            s += decode_s
        return s

    return latency


__all__ = ["SimClock", "FakeCostModel", "FakeModel", "expected_tokens",
           "drive", "work_latency_model"]
