"""Multi-replica serving: Router + placement policies + ServingCluster
+ the traffic-scaling trace driver.  See docs/architecture.md
("The cluster tier") for the picture.

Lazy exports (PEP 562, the ``repro.serve`` idiom): ``policy`` and
``traffic`` are host-side; ``cluster`` pulls in the engines (jax) only
when a cluster is actually built.
"""
import importlib

_EXPORTS = {
    "CostAwarePolicy": "policy",
    "LeastLoadedPolicy": "policy",
    "PlacementPolicy": "policy",
    "RoundRobinPolicy": "policy",
    "make_policy": "policy",
    "predicted_queue_seconds": "policy",
    "RouteStats": "router",
    "Router": "router",
    "ServingCluster": "cluster",
    "ClusterTelemetry": "metrics",
    "serve_trace": "traffic",
    "skewed_trace": "traffic",
    "unit_latency": "traffic",
}
_SUBMODULES = ("cluster", "metrics", "policy", "router", "traffic")

__all__ = sorted(_EXPORTS) + list(_SUBMODULES)


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module(f"repro.serve.cluster.{_EXPORTS[name]}")
        return getattr(mod, name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.serve.cluster.{name}")
    raise AttributeError(
        f"module 'repro.serve.cluster' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS) | set(_SUBMODULES))
