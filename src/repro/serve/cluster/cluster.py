""":class:`ServingCluster` — N engine replicas behind one Router, with
the device budget optionally factorized by the cost model.

``build`` is the one-stop constructor: it can be told the replica count
directly, or handed a device budget + serving shape and let
``sharding.rank_cluster_topologies`` choose — the same calibrated
pricing that ranks per-replica meshes decides how many replicas the
budget buys (the chosen :class:`~repro.sharding.plans.ClusterTopology`
is kept on ``cluster.topology`` for reporting).  Every replica is a
full engine with its own KV pool, scheduler, and (optionally) its own
bound TelemetryController from a :class:`ClusterTelemetry`; they share
one clock so cross-replica latency accounting is comparable.

``step`` advances every replica by one engine step, then sweeps
completions into ``router.done``.  Under the frozen-clock sim harness
this is the cluster's tick: the driver advances the shared SimClock by
the MAX of the per-replica step walls (replicas are independent chips
running concurrently — see ``cluster.traffic.serve_trace``).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class ClusterStalled(RuntimeError):
    """``run_until_done`` exhausted its step budget with requests still
    in flight — a wedged cluster must be LOUD, not indistinguishable
    from a drained one.  Carries the leftover state for the post-mortem."""

    def __init__(self, steps: int, in_flight: int, queued: int,
                 produced: int):
        self.steps = steps
        self.in_flight = in_flight
        self.queued = queued
        self.produced = produced
        super().__init__(
            f"cluster stalled: {in_flight} request(s) in flight "
            f"({queued} queued) after {steps} steps; "
            f"{produced} tokens delivered")


class ServingCluster:
    """Replicas + router; delegates admission/completion to the router."""

    def __init__(self, replicas: List, policy="cost_aware",
                 shed_wait_s: Optional[float] = None,
                 max_reroutes: int = 3, telemetry=None, topology=None):
        from repro.serve.cluster.router import Router
        self.replicas = list(replicas)
        self.router = Router(self.replicas, policy=policy,
                             shed_wait_s=shed_wait_s,
                             max_reroutes=max_reroutes)
        self.telemetry = telemetry
        self.topology = topology
        # optional chaos/fault supervisor (serve.chaos.supervise) — when
        # installed it owns per-replica stepping and the detection sweep
        self.supervisor = None

    # -- construction ---------------------------------------------------------
    @classmethod
    def build(cls, model, params, n_replicas: Optional[int] = None, *,
              engine: str = "paged", policy="cost_aware",
              clock=None, cost_model=None, telemetry=None,
              shed_wait_s: Optional[float] = None, max_reroutes: int = 3,
              n_devices: Optional[int] = None, cell=None,
              **engine_kwargs) -> "ServingCluster":
        """Stand up a cluster of identical replicas.

        Either pass ``n_replicas`` directly, or pass a device budget
        (``n_devices``) plus the serving shape (``cell``) and the
        replica count is read off ``rank_cluster_topologies(...)[0]`` —
        the cost-model-chosen topology.  ``engine_kwargs`` (max_batch,
        n_blocks, chunk_size, fused, ...) go to every replica verbatim.
        ``telemetry`` may be a :class:`ClusterTelemetry` (one controller
        per replica) — a single TelemetryController cannot be shared,
        its ``bind`` refuses a second engine.

        When the budget came with a topology whose replicas span more
        than one chip (``plan.data x plan.model > 1``) and the process
        actually HAS that many devices, each paged replica is
        instantiated on its own device sub-slice
        (``launch.mesh.slice_devices``) with the per-replica mesh built
        from the ranked plan — the priced factorization becomes the
        physical layout.  With fewer physical devices than the budget
        (the analytic/simulation case: pricing an 8-chip cluster from a
        1-chip host) replicas stay unsharded, exactly as before.
        """
        topology = None
        if n_replicas is None:
            if n_devices is None or cell is None:
                raise ValueError("build needs n_replicas, or n_devices+cell "
                                 "for the cost model to choose")
            from repro.sharding.plans import rank_cluster_topologies
            topology = rank_cluster_topologies(
                model.cfg, cell, n_devices, cost_model)[0]
            n_replicas = topology.n_replicas
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")

        if engine == "paged":
            from repro.serve.engine import PagedServingEngine as Engine
        elif engine == "slot":
            from repro.serve.engine import ServingEngine as Engine
        else:
            raise ValueError(f"unknown engine kind {engine!r} "
                             f"(want 'paged' or 'slot')")
        meshes: List = [None] * n_replicas
        if (engine == "paged" and topology is not None
                and topology.devices_per_replica > 1
                and "mesh" not in engine_kwargs):
            import jax
            from repro.launch.mesh import make_host_mesh, slice_devices
            per = topology.devices_per_replica
            if n_replicas * per <= len(jax.devices()):
                meshes = [
                    make_host_mesh(model_axis=topology.plan.model,
                                   devices=devs)
                    for devs in slice_devices(n_replicas, per)]
        replicas = []
        for i in range(n_replicas):
            controller = telemetry.controller(i) if telemetry else None
            kw = dict(engine_kwargs)
            if meshes[i] is not None:
                kw["mesh"] = meshes[i]
            replicas.append(Engine(model, params, clock=clock,
                                   cost_model=cost_model,
                                   telemetry=controller, **kw))
        return cls(replicas, policy=policy, shed_wait_s=shed_wait_s,
                   max_reroutes=max_reroutes, telemetry=telemetry,
                   topology=topology)

    # -- admission / completion ----------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> Optional[int]:
        """Route one request; returns its cluster id, or None if shed."""
        return self.router.submit(prompt, max_new_tokens=max_new_tokens,
                                  eos_id=eos_id)

    @property
    def done(self) -> Dict[int, object]:
        return self.router.done

    @property
    def stats(self):
        return self.router.stats

    # -- failure recovery -----------------------------------------------------
    def replace_replica(self, i: int, engine) -> None:
        """Swap a restarted engine into slot ``i`` on BOTH lists — the
        router copies the replicas list at construction, so the cluster's
        and the router's views must be updated together or they diverge
        on the first warm-rejoin."""
        self.replicas[i] = engine
        self.router.replace_replica(i, engine)

    def _live_replicas(self) -> List:
        """Replicas eligible for work (all of them without a supervisor;
        the router's live set under one — a dead replica's frozen queue
        must not keep ``run_until_done`` spinning)."""
        if self.supervisor is None:
            return self.replicas
        return [self.replicas[j] for j in self.router.live_indices()]

    # -- stepping -------------------------------------------------------------
    def step(self) -> int:
        """One cluster tick: every replica takes one engine step, then
        completions are swept.  Returns total tokens delivered.

        With a chaos supervisor installed, stepping is delegated per
        replica (the supervisor wraps the step with heartbeat + fault
        bookkeeping and skips dead replicas) and the detection/recovery
        sweep runs after the tick."""
        produced = 0
        if self.supervisor is not None:
            for i in range(len(self.replicas)):
                produced += self.supervisor.step_replica(i)
            self.router.collect()
            self.supervisor.after_tick()
        else:
            for eng in self.replicas:
                produced += eng.step()
            self.router.collect()
        return produced

    def run_until_done(self, max_steps: int = 10_000, *,
                       raise_on_stall: bool = True) -> int:
        """Step until every admitted request is collected (or the step
        budget runs out).  Returns total tokens delivered.

        Exhausting ``max_steps`` with requests still in flight raises
        :class:`ClusterStalled` (set ``raise_on_stall=False`` to get the
        old silent return while inspecting the wreckage) — a wedged
        cluster used to return normally, indistinguishable from success.
        """
        produced = 0
        steps = 0
        for _ in range(max_steps):
            if self.router.in_flight == 0 and not any(
                    len(eng.queue) for eng in self._live_replicas()):
                break
            produced += self.step()
            steps += 1
        # flush any one-step-ahead pipelines left in flight
        for eng in self._live_replicas():
            if eng._pending is not None:
                eng._drain(eng._pending)
                eng._pending = None
        self.router.collect()
        if raise_on_stall and self.router.in_flight > 0:
            raise ClusterStalled(
                steps, self.router.in_flight,
                sum(len(eng.queue) for eng in self._live_replicas()),
                produced)
        return produced
