"""Placement policies: where the router puts each request, and whether an
eviction victim is worth moving to another replica.

All three policies see the same inputs — the candidate replicas (live
engine objects) and the request's shape — and return a replica index.
What separates them is how much of the cost model they consult:

* :class:`RoundRobinPolicy` — none.  The baseline the traffic-scaling
  campaign measures against: blind cycling, so a trace whose long
  requests recur with the replica period piles every one of them onto
  the same replica.
* :class:`LeastLoadedPolicy` — queue awareness.  Each replica's pending
  work is converted to predicted queue-seconds through the engine's own
  cached ``_predict_*`` prices (uniform work units without a cost
  model), and the emptiest replica wins.
* :class:`CostAwarePolicy` — queue awareness plus the request's own
  MARGINAL cost on each candidate (its prefill + decode seconds there)
  plus the inter-replica route traffic
  (``costmodel.analytic.analytic_route_bytes`` over a wire bandwidth).
  It is also the only policy that re-routes eviction victims: a victim
  moves only when another replica's queue + replay + route price beats
  replaying at the front of the source's queue.

``predicted_queue_seconds`` is duck-typed over both engines (paged rows
or slot occupancy) so a cluster can stand either kind of replica.
"""
from __future__ import annotations

from typing import List, Optional


def _prefill_seconds(engine, n_tokens: int) -> float:
    """Predicted seconds to prefill ``n_tokens`` on this replica, through
    the engine's own cached pricing paths.  Without a cost model the
    unit is chunks (paged) or prompts (slot) — dimensionless but still a
    valid relative load signal."""
    if n_tokens <= 0:
        return 0.0
    chunk = getattr(engine, "chunk_size", None)
    if engine.cost_model is None:
        return float(-(-n_tokens // chunk)) if chunk else 1.0
    if chunk:
        return -(-n_tokens // chunk) * engine._predict_chunk().step_s
    return engine._predict_prefill(n_tokens).step_s


def _decode_token_seconds(engine) -> float:
    """Per-delivered-token decode seconds at full batch: one step serves
    up to ``max_batch`` rows, so a replica's decode backlog amortizes."""
    step_s = (engine._predict_decode().step_s
              if engine.cost_model is not None else 1.0)
    return step_s / max(engine.max_batch, 1)


def predicted_queue_seconds(engine, include_queue: bool = True) -> float:
    """Predicted seconds of work already committed to one replica:
    remaining prefill + remaining decode for every placed row, plus (by
    default) everything still waiting in its queue."""
    per_tok = _decode_token_seconds(engine)
    total = 0.0
    rows = getattr(engine, "rows", None)
    if rows is not None:                       # paged engine
        for row in rows:
            if row is None:
                continue
            req = row.req
            if not row.ready:
                total += _prefill_seconds(engine,
                                          len(req.prompt) - row.filled)
            total += max(req.max_new_tokens - len(req.tokens), 0) * per_tok
    else:                                      # slot engine
        for req in engine.slot_req:
            if req is None:
                continue
            total += max(req.max_new_tokens - len(req.tokens), 0) * per_tok
    if include_queue:
        for req in engine.queue:
            total += _prefill_seconds(engine, len(req.prompt))
            total += req.max_new_tokens * per_tok
    return total


class PlacementPolicy:
    """Interface: ``place`` picks the replica for a fresh request;
    ``reroute`` may claim an eviction victim for another replica (None =
    leave it to the source scheduler's front-requeue, today's behavior)."""

    name = "?"

    def place(self, prompt_len: int, max_new_tokens: int,
              replicas: List) -> int:
        raise NotImplementedError

    def reroute(self, req, src: int, replicas: List) -> Optional[int]:
        return None


class RoundRobinPolicy(PlacementPolicy):
    """Blind cycling — the campaign's baseline."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def place(self, prompt_len: int, max_new_tokens: int,
              replicas: List) -> int:
        i = self._next % len(replicas)
        self._next = (i + 1) % len(replicas)
        return i


class LeastLoadedPolicy(PlacementPolicy):
    """Emptiest predicted queue wins; ties go to the lowest index (so a
    drained cluster degenerates to replica 0, deterministically)."""

    name = "least_loaded"

    def place(self, prompt_len: int, max_new_tokens: int,
              replicas: List) -> int:
        return min(range(len(replicas)),
                   key=lambda i: (predicted_queue_seconds(replicas[i]), i))


class CostAwarePolicy(PlacementPolicy):
    """Marginal-completion placement (see module docstring).

    ``route_bw_bps`` prices ``analytic_route_bytes`` into seconds — the
    inter-replica fabric, defaulting to a 25 GB/s NIC.  On engines
    without a cost model the queue/marginal terms are unit-work, so the
    route term is scaled by ``unit_route_s`` per byte-free move instead
    (keeps the comparison dimensionally consistent either way).
    """

    name = "cost_aware"

    def __init__(self, route_bw_bps: float = 25e9,
                 unit_route_s: float = 0.25):
        if route_bw_bps <= 0:
            raise ValueError("route_bw_bps must be positive")
        self.route_bw_bps = route_bw_bps
        self.unit_route_s = unit_route_s

    # -- pricing helpers ------------------------------------------------------
    def _route_s(self, engine, prompt_len: int, filled: int = 0) -> float:
        if engine.cost_model is None:
            return self.unit_route_s
        from repro.core.costmodel.analytic import analytic_route_bytes
        nbytes = analytic_route_bytes(engine.model.cfg, prompt_len, filled)
        return nbytes / self.route_bw_bps

    def _marginal_s(self, engine, prompt_len: int,
                    max_new_tokens: int) -> float:
        return (_prefill_seconds(engine, prompt_len)
                + max_new_tokens * _decode_token_seconds(engine))

    # -- the decisions --------------------------------------------------------
    def place(self, prompt_len: int, max_new_tokens: int,
              replicas: List) -> int:
        def completion_s(i):
            eng = replicas[i]
            return (predicted_queue_seconds(eng)
                    + self._marginal_s(eng, prompt_len, max_new_tokens)
                    + self._route_s(eng, prompt_len))
        return min(range(len(replicas)), key=lambda i: (completion_s(i), i))

    def reroute(self, req, src: int, replicas: List) -> Optional[int]:
        """Move an eviction victim only when it wins: staying means a
        front-requeue (it waits behind the source's PLACED rows only,
        then replays), moving means waiting behind the target's whole
        queue, replaying there, and paying the route traffic — including
        the abandoned KV of the already-prefilled prefix."""
        if len(replicas) < 2:
            return None
        n, new = len(req.prompt), req.max_new_tokens
        stay_s = (predicted_queue_seconds(replicas[src], include_queue=False)
                  + self._marginal_s(replicas[src], n, new))
        best, best_s = None, stay_s
        for j, eng in enumerate(replicas):
            if j == src:
                continue
            move_s = (predicted_queue_seconds(eng)
                      + self._marginal_s(eng, n, new)
                      + self._route_s(eng, n, filled=n))
            if move_s < best_s:
                best, best_s = j, move_s
        return best


POLICIES = {p.name: p for p in
            (RoundRobinPolicy, LeastLoadedPolicy, CostAwarePolicy)}


def make_policy(name_or_policy) -> PlacementPolicy:
    """'round_robin' | 'least_loaded' | 'cost_aware', or a ready instance."""
    if isinstance(name_or_policy, PlacementPolicy):
        return name_or_policy
    try:
        return POLICIES[name_or_policy]()
    except KeyError:
        raise ValueError(f"unknown placement policy {name_or_policy!r}; "
                         f"known: {', '.join(sorted(POLICIES))}") from None
