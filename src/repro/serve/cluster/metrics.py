"""Cluster-level telemetry: one TelemetryController + MetricsSink PER
replica (a controller's ``bind`` refuses a second engine — the drift
buckets are shape-derived per engine), aggregated here with per-replica
tags.

The aggregation is deliberately thin: per-replica sinks stay the source
of truth (ring capacity, lifetime totals, drift events all per-engine),
and :class:`ClusterTelemetry` only merges at read time — ``summary()``
recomputes the cluster-wide request p50/p99 over ALL replicas' request
records (a mean of per-replica percentiles would be wrong), and
``export_jsonl`` re-tags each replica's lines with ``"replica": i`` so
one shipped file carries the whole cluster.

Chaos extensions: ``stream_dir`` turns on each sink's incremental
append-and-flush JSONL stream (``replica_<i>.jsonl``) so a replica that
dies mid-drill leaves its telemetry tail on disk; ``tag_dead`` appends
the fault verdict to that stream and records it for ``summary()``;
``rebind`` retires a dead replica's sink/controller pair and stands up a
fresh one for the warm-rejoined engine (a controller's ``bind`` refuses
a second engine, so rejoin MUST re-bind).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.serve.telemetry.control import TelemetryController
from repro.serve.telemetry.metrics import MetricsSink, quantile


class ClusterTelemetry:
    """N controllers, one per replica; merged read-side views.

    ``controller(i)`` hands out the i-th controller — exactly what
    ``ServingCluster.build`` passes to the i-th replica's constructor.
    Controller knobs (``latency_model``, ``drift``, ``recalibrate``)
    apply to every replica identically.  ``slo`` (an
    :class:`~repro.serve.telemetry.slo.SLO`) gives every controller its
    OWN token bucket — buckets hold mutable admission state and cannot
    be shared across engines any more than controllers can.
    """

    def __init__(self, n_replicas: int, *, capacity: int = 4096,
                 latency_model=None, drift=False, recalibrate: bool = False,
                 slo=None, stream_dir: "Path | str | None" = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self._ctor = dict(capacity=capacity, latency_model=latency_model,
                          drift=drift, recalibrate=recalibrate, slo=slo)
        self.stream_dir = Path(stream_dir) if stream_dir is not None else None
        self.sinks: List[MetricsSink] = []
        self.controllers: List[TelemetryController] = []
        for i in range(n_replicas):
            sink, ctrl = self._make_pair(i)
            self.sinks.append(sink)
            self.controllers.append(ctrl)
        # fault-tagged (replica, t_s, kind) verdicts + retired sinks
        # (kept as (replica, sink) — faults and rebinds are not 1:1, a
        # crash-looping replica tags several deaths per rebind)
        self.faults: List[Dict[str, Any]] = []
        self.retired: List = []           # [(replica, MetricsSink), ...]
        self._generation = [0] * n_replicas

    def _make_pair(self, i: int):
        stream = (None if self.stream_dir is None
                  else self.stream_dir / f"replica_{i}.jsonl")
        sink = MetricsSink(capacity=self._ctor["capacity"],
                           stream_path=stream)
        ctrl = TelemetryController(
            sink, drift=self._ctor["drift"],
            latency_model=self._ctor["latency_model"],
            recalibrate=self._ctor["recalibrate"],
            slo=self._ctor["slo"])
        return sink, ctrl

    @property
    def n_replicas(self) -> int:
        return len(self.sinks)

    def controller(self, i: int) -> TelemetryController:
        return self.controllers[i]

    # -- fault bookkeeping ----------------------------------------------------
    def tag_dead(self, i: int, t_s: float, kind: str) -> None:
        """Mark replica ``i``'s record stream with its fault verdict —
        the line lands on the incremental stream immediately (the whole
        point: the verdict must survive even if nothing ever exports),
        and the verdict is carried in ``summary()``/``export_jsonl``."""
        tag = {"replica": i, "t_s": float(t_s), "kind": str(kind)}
        self.faults.append(tag)
        self.sinks[i].stream_note({"record": "fault", **tag})

    def rebind(self, i: int) -> TelemetryController:
        """Retire replica ``i``'s sink/controller and stand up a fresh
        pair for a warm-rejoined engine.  The retired sink keeps the dead
        incarnation's records (and stays in ``export_jsonl``); the fresh
        sink streams to a generation-suffixed file so the post-mortem
        and the rejoin never interleave in one stream."""
        old = self.sinks[i]
        old.close_stream()
        self.retired.append((i, old))
        self._generation[i] += 1
        sink, ctrl = self._make_pair(i)
        if self.stream_dir is not None:
            sink.open_stream(self.stream_dir
                             / f"replica_{i}.g{self._generation[i]}.jsonl")
        self.sinks[i] = sink
        self.controllers[i] = ctrl
        return ctrl

    # -- merged views ---------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Cluster block plus the per-replica summaries verbatim."""
        per_replica = [s.summary() for s in self.sinks]
        all_sinks = self.sinks + [s for _, s in self.retired]
        lat = [r.latency_s for s in all_sinks for r in s.requests()]
        out = {
            "n_replicas": self.n_replicas,
            "requests": sum(s.total_requests for s in all_sinks),
            "steps": sum(s.total_steps for s in all_sinks),
            "latency_p50_s": quantile(lat, 0.50),
            "latency_p99_s": quantile(lat, 0.99),
            "per_replica": per_replica,
        }
        if self.faults:
            out["faults"] = list(self.faults)
        return out

    def request_latencies(self) -> List[float]:
        return [r.latency_s
                for s in self.sinks + [s for _, s in self.retired]
                for r in s.requests()]

    def export_jsonl(self, path: "Path | str") -> Path:
        """Every replica's ring, one tagged JSON object per line, each
        carrying its ``"replica"`` index next to the ``"record"`` tag.
        Retired (pre-fault) sinks export first under their replica index,
        then the live rings, then the fault tags — the shipped file reads
        in event order per replica."""
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        live = list(enumerate(self.sinks))
        with out.open("w") as fh:
            for i, sink in self.retired + live:
                tmp = out.with_suffix(f".r{i}.tmp")
                sink.export_jsonl(tmp)
                for line in tmp.read_text().splitlines():
                    rec = json.loads(line)
                    fh.write(json.dumps({"replica": i, **rec}) + "\n")
                tmp.unlink()
            for tag in self.faults:
                fh.write(json.dumps({"record": "fault", **tag}) + "\n")
        return out
