"""Cluster-level telemetry: one TelemetryController + MetricsSink PER
replica (a controller's ``bind`` refuses a second engine — the drift
buckets are shape-derived per engine), aggregated here with per-replica
tags.

The aggregation is deliberately thin: per-replica sinks stay the source
of truth (ring capacity, lifetime totals, drift events all per-engine),
and :class:`ClusterTelemetry` only merges at read time — ``summary()``
recomputes the cluster-wide request p50/p99 over ALL replicas' request
records (a mean of per-replica percentiles would be wrong), and
``export_jsonl`` re-tags each replica's lines with ``"replica": i`` so
one shipped file carries the whole cluster.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.serve.telemetry.control import TelemetryController
from repro.serve.telemetry.metrics import MetricsSink, quantile


class ClusterTelemetry:
    """N controllers, one per replica; merged read-side views.

    ``controller(i)`` hands out the i-th controller — exactly what
    ``ServingCluster.build`` passes to the i-th replica's constructor.
    Controller knobs (``latency_model``, ``drift``, ``recalibrate``)
    apply to every replica identically.
    """

    def __init__(self, n_replicas: int, *, capacity: int = 4096,
                 latency_model=None, drift=False, recalibrate: bool = False):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.sinks: List[MetricsSink] = [MetricsSink(capacity=capacity)
                                         for _ in range(n_replicas)]
        self.controllers: List[TelemetryController] = [
            TelemetryController(sink, drift=drift,
                                latency_model=latency_model,
                                recalibrate=recalibrate)
            for sink in self.sinks]

    @property
    def n_replicas(self) -> int:
        return len(self.sinks)

    def controller(self, i: int) -> TelemetryController:
        return self.controllers[i]

    # -- merged views ---------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Cluster block plus the per-replica summaries verbatim."""
        per_replica = [s.summary() for s in self.sinks]
        lat = [r.latency_s for s in self.sinks for r in s.requests()]
        return {
            "n_replicas": self.n_replicas,
            "requests": sum(s.total_requests for s in self.sinks),
            "steps": sum(s.total_steps for s in self.sinks),
            "latency_p50_s": quantile(lat, 0.50),
            "latency_p99_s": quantile(lat, 0.99),
            "per_replica": per_replica,
        }

    def request_latencies(self) -> List[float]:
        return [r.latency_s for s in self.sinks for r in s.requests()]

    def export_jsonl(self, path: "Path | str") -> Path:
        """Every replica's ring, one tagged JSON object per line, each
        carrying its ``"replica"`` index next to the ``"record"`` tag."""
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        with out.open("w") as fh:
            for i, sink in enumerate(self.sinks):
                tmp = out.with_suffix(f".r{i}.tmp")
                sink.export_jsonl(tmp)
                for line in tmp.read_text().splitlines():
                    rec = json.loads(line)
                    fh.write(json.dumps({"replica": i, **rec}) + "\n")
                tmp.unlink()
        return out
