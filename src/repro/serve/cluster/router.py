"""The cluster's front door: one :class:`Router` in front of N engine
replicas, owning the cluster-wide request id space and the two routing
decisions — where a fresh request lands (``policy.place``) and whether
an eviction victim moves to another replica (``policy.reroute``).

The router does NOT re-implement batching.  Each replica keeps its own
shadow-step pipeline (chunked prefill, fused decode, preemption) exactly
as a bare engine; the router only chooses which replica's ``submit``
a request reaches, then sweeps finished requests out of the replicas'
``done`` dicts into its own, keyed by cluster id.  That is what makes
admission O(1) per request regardless of replica count: continuous
batching stays inside each replica, and cross-replica work only happens
at the two seams (placement, eviction).

Re-routing rides the scheduler's ``requeue_policy`` hook: when a replica
evicts a victim, the router's reclaim closure asks the policy whether
another replica would finish it sooner (counting the route traffic —
see ``CostAwarePolicy.reroute``).  If yes, the victim is re-submitted to
the target WITH ITS ORIGINAL ``submitted_s`` so latency accounting
survives the move, and the closure returns True — the source scheduler
drops it.  If no (or the request already moved ``max_reroutes`` times —
a ping-pong damper), the closure returns False and the source
front-requeues as a single-replica engine would.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.cluster.policy import (PlacementPolicy, make_policy,
                                        predicted_queue_seconds)


@dataclasses.dataclass
class RouteStats:
    """Cumulative router counters (the cluster-tier analogue of
    ``EngineStats``; documented in docs/ops-runbook.md)."""
    submitted: int = 0              # requests accepted and placed
    shed: int = 0                   # requests refused at admission
    reroutes: int = 0               # eviction victims moved cross-replica
    front_requeues: int = 0         # eviction victims kept on their source
    decisions: int = 0              # placement + reroute decisions taken
    recovered: int = 0              # reclaimed from a dead replica, re-placed
    abandoned: int = 0              # reclaimed but shed (retry budget spent)
    routed: List[int] = dataclasses.field(default_factory=list)  # per replica


class Router:
    """Place requests across replicas; reclaim eviction victims.

    Parameters
    ----------
    replicas:
        Live engine objects (``ServingEngine`` or ``PagedServingEngine``).
        Replicas with a chunked-prefill scheduler get the reclaim closure
        installed on ``scheduler.requeue_policy``; slot engines never
        preempt, so they route at placement only.
    policy:
        A :class:`PlacementPolicy` instance or its name
        ('round_robin' | 'least_loaded' | 'cost_aware').
    shed_wait_s:
        Optional admission ceiling: a request whose chosen replica already
        carries more than this many predicted queue-seconds is SHED
        (``submit`` returns None) instead of enqueued.  None = never shed.
    max_reroutes:
        Per-request cap on cross-replica moves; after this many the
        victim always front-requeues at its current replica.
    """

    def __init__(self, replicas: List, policy="cost_aware",
                 shed_wait_s: Optional[float] = None,
                 max_reroutes: int = 3):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        self.replicas = list(replicas)
        self.policy: PlacementPolicy = make_policy(policy)
        self.shed_wait_s = shed_wait_s
        self.max_reroutes = max_reroutes
        self.done: Dict[int, object] = {}           # crid -> Request
        self.stats = RouteStats(routed=[0] * len(self.replicas))
        self._next_crid = 0
        self._local: Dict[int, Tuple[int, int]] = {}    # crid -> (i, rid)
        self._origin: Dict[Tuple[int, int], int] = {}   # (i, rid) -> crid
        self._moves: Dict[int, int] = {}                # crid -> reroute count
        self._live: List[bool] = [True] * len(self.replicas)
        for i, eng in enumerate(self.replicas):
            self._install_reclaim(i, eng)

    def _install_reclaim(self, i: int, eng) -> None:
        sched = getattr(eng, "scheduler", None)
        if sched is not None:
            if sched.requeue_policy is not None:
                raise ValueError(
                    f"replica {i} already has a requeue_policy; "
                    f"a replica can serve at most one router")
            sched.requeue_policy = self._make_reclaim(i)

    # -- liveness -------------------------------------------------------------
    def live_indices(self) -> List[int]:
        return [i for i in range(len(self.replicas)) if self._live[i]]

    def set_live(self, i: int, alive: bool) -> None:
        """Mark a replica (in)eligible for placement and reroute.  A dead
        replica keeps its slot in ``replicas`` (indices stay stable for
        bookkeeping and warm-rejoin); it simply stops receiving work."""
        self._live[i] = bool(alive)

    # -- admission ------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> Optional[int]:
        """Place one request; returns its cluster id, or None if shed."""
        live = self.live_indices()
        if not live:
            self.stats.shed += 1            # total outage: shed at the door
            return None
        self.stats.decisions += 1
        i = live[self.policy.place(len(prompt), max_new_tokens,
                                   [self.replicas[j] for j in live])]
        if (self.shed_wait_s is not None
                and predicted_queue_seconds(self.replicas[i])
                > self.shed_wait_s):
            self.stats.shed += 1
            return None
        rid = self.replicas[i].submit(prompt, max_new_tokens=max_new_tokens,
                                      eos_id=eos_id)
        crid = self._next_crid
        self._next_crid += 1
        self._local[crid] = (i, rid)
        self._origin[(i, rid)] = crid
        self.stats.submitted += 1
        self.stats.routed[i] += 1
        return crid

    # -- eviction reclaim -----------------------------------------------------
    def _make_reclaim(self, src: int):
        def reclaim(req) -> bool:
            crid = self._origin.get((src, req.rid))
            if crid is None:            # not router-owned (direct submit)
                return False
            self.stats.decisions += 1
            if self._moves.get(crid, 0) >= self.max_reroutes:
                self.stats.front_requeues += 1
                return False
            # reroute candidates: live replicas (plus the source itself,
            # whose index the policy needs for its stay-vs-move price)
            cand = [j for j in range(len(self.replicas))
                    if self._live[j] or j == src]
            tgt_k = self.policy.reroute(req, cand.index(src),
                                        [self.replicas[j] for j in cand])
            tgt = None if tgt_k is None else cand[tgt_k]
            if tgt is None or tgt == src:
                self.stats.front_requeues += 1
                return False
            self._move(crid, req, src, tgt)
            return True
        return reclaim

    def _move(self, crid: int, req, src: int, tgt: int) -> None:
        """Re-submit an eviction victim on ``tgt``.  The victim replays
        from scratch there (its KV was freed by the eviction); keeping
        the original ``submitted_s`` keeps its latency honest."""
        del self._origin[(src, self._local[crid][1])]
        new_rid = self.replicas[tgt].submit(
            req.prompt, max_new_tokens=req.max_new_tokens,
            eos_id=req.eos_id, submitted_s=req.submitted_s)
        self._local[crid] = (tgt, new_rid)
        self._origin[(tgt, new_rid)] = crid
        self._moves[crid] = self._moves.get(crid, 0) + 1
        self.stats.reroutes += 1
        self.stats.routed[tgt] += 1

    # -- failure recovery -----------------------------------------------------
    def reclaim_replica(self, i: int) -> List[Tuple[int, object]]:
        """Pull every router-owned request off a failed replica.

        Returns ``[(crid, request), ...]`` — the prompts are retained on
        ``Request``, so each one can replay from scratch elsewhere
        (:meth:`resubmit`).  All bookkeeping for the reclaimed ids is
        dropped here; the dead replica's internal state is NOT mutated
        (a crashed process can't be asked to clean up).  Requests that
        already finished on the replica but were never collected are
        reclaimed too: a dead replica's uncollected output is treated as
        lost and recomputed, which keeps recovery independent of how far
        the crash let the final drain get."""
        eng = self.replicas[i]
        by_rid: Dict[int, object] = {}
        for req in list(getattr(eng, "queue", ()) or ()):   # still waiting
            by_rid[req.rid] = req
        for row in getattr(eng, "rows", None) or ():        # paged rows
            if row is not None:
                by_rid[row.req.rid] = row.req
        for req in getattr(eng, "slot_req", None) or ():    # slot engine
            if req is not None:
                by_rid[req.rid] = req
        by_rid.update(eng.done)                             # uncollected
        out = []
        for crid in sorted(c for c, (j, _) in self._local.items() if j == i):
            _, rid = self._local.pop(crid)
            self._origin.pop((i, rid), None)
            self._moves.pop(crid, None)
            req = by_rid.get(rid)
            if req is None:
                raise KeyError(
                    f"crid {crid} (replica {i} rid {rid}) is tracked by "
                    f"the router but not found on the replica — "
                    f"bookkeeping is corrupt")
            out.append((crid, req))
        return out

    def resubmit(self, crid: int, req) -> bool:
        """Re-place one reclaimed request on a live replica UNDER ITS
        ORIGINAL cluster id and ``submitted_s`` (recovery must not
        launder latency).  Returns False when no replica is live — the
        caller decides between retrying later and :meth:`abandon`."""
        if crid in self._local:
            raise ValueError(f"crid {crid} is still tracked; reclaim it "
                             f"before resubmitting")
        live = self.live_indices()
        if not live:
            return False
        self.stats.decisions += 1
        i = live[self.policy.place(len(req.prompt), req.max_new_tokens,
                                   [self.replicas[j] for j in live])]
        rid = self.replicas[i].submit(
            req.prompt, max_new_tokens=req.max_new_tokens,
            eos_id=req.eos_id, submitted_s=req.submitted_s)
        self._local[crid] = (i, rid)
        self._origin[(i, rid)] = crid
        self.stats.recovered += 1
        self.stats.routed[i] += 1
        return True

    def abandon(self, crid: int) -> None:
        """Give up on a reclaimed request (retry budget exhausted or no
        capacity).  The id is gone from all bookkeeping after reclaim;
        this just records the shed-after-admission outcome."""
        self.stats.abandoned += 1

    def replace_replica(self, i: int, engine) -> None:
        """Swap a (restarted) engine into slot ``i`` and install the
        reclaim closure on it.  Does NOT flip liveness — the supervisor
        marks the slot live once the rejoin is complete."""
        self.replicas[i] = engine
        self._install_reclaim(i, engine)

    # -- completion -----------------------------------------------------------
    def collect(self) -> int:
        """Sweep finished requests from every replica's ``done`` dict into
        ``self.done`` keyed by cluster id.  Returns how many moved this
        sweep.  Non-router-owned requests are left in place."""
        n = 0
        for i, eng in enumerate(self.replicas):
            for rid in [r for r in eng.done if (i, r) in self._origin]:
                crid = self._origin.pop((i, rid))
                self.done[crid] = eng.done.pop(rid)
                del self._local[crid]
                self._moves.pop(crid, None)
                n += 1
        return n

    # -- introspection --------------------------------------------------------
    @property
    def in_flight(self) -> int:
        """Router-owned requests admitted but not yet collected."""
        return len(self._local)

    def assert_drained(self) -> None:
        """Invariant check for a fully-drained trace: every admitted
        request was collected and every per-request bookkeeping dict
        (``_local``, ``_origin`` and the ``_moves`` reroute counters —
        all pruned by ``collect``) is empty.  A leftover entry means a
        per-request leak: the dicts would grow without bound on a
        long-running cluster.  Call after ``run_until_done`` /
        a drained acceptance trace; raises AssertionError with the
        leaked ids."""
        leaks = {name: d for name, d in (("_local", self._local),
                                         ("_origin", self._origin),
                                         ("_moves", self._moves)) if d}
        assert not leaks, (
            "router bookkeeping leaked after drain: "
            + "; ".join(f"{k}={sorted(v)!r}" for k, v in leaks.items()))

    def queue_depths(self) -> List[int]:
        return [len(eng.queue) for eng in self.replicas]

    def predicted_waits(self) -> List[float]:
        return [predicted_queue_seconds(eng) for eng in self.replicas]
