"""Traffic generation and the cluster trace driver.

:func:`skewed_trace` builds the campaign's adversarial workload: every
``period``-th request is LONG (big prompt, many new tokens), the rest
short.  With ``period == n_replicas`` a round-robin router lands every
long request on the same replica — the pathological case the
cost-model-aware policy is supposed to dissolve — while arrival times
stay a deterministic function of the offered ``load``.

:func:`serve_trace` is the cluster analogue of ``serve.sim.drive``,
with one extra idea: the PARALLEL-REPLICA CLOCK.  Each tick steps every
replica once, measures each replica's step wall (``perf_counter`` on
real arrays, or a deterministic ``step_seconds`` price under the
frozen-clock sim), and advances the SHARED clock by the MAX of the
per-replica walls — replicas are independent chips running
concurrently, so cluster time is the slowest replica's time, not the
sum.  Latency and tok/s read off that virtual clock, which is what lets
one host benchmark an N-replica cluster honestly.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

Arrival = Tuple[float, list, int, Optional[int]]   # (t, prompt, max_new, eos)


def skewed_trace(n_requests: int, *, vocab: int = 97, period: int = 4,
                 long_len: int = 48, short_len: int = 4,
                 long_new: int = 24, short_new: int = 4,
                 interval_s: float = 1.0, load: float = 1.0,
                 t0: float = 0.0) -> List[Arrival]:
    """Deterministic skewed arrivals: request ``i`` is long iff
    ``i % period == 0``; arrivals are evenly spaced at
    ``interval_s / load`` (load > 1 = overload).  Prompts are fixed
    arithmetic sequences so every run of the trace is byte-identical."""
    if n_requests < 1 or period < 1:
        raise ValueError("need n_requests >= 1 and period >= 1")
    if load <= 0 or interval_s <= 0:
        raise ValueError("need positive load and interval_s")
    out: List[Arrival] = []
    gap = interval_s / load
    for i in range(n_requests):
        n = long_len if i % period == 0 else short_len
        new = long_new if i % period == 0 else short_new
        prompt = [(7 * i + j) % vocab for j in range(n)]
        out.append((t0 + i * gap, prompt, new, None))
    return out


def unit_latency(decode_s: float, chunk_s: float, overhead_s: float = 0.0):
    """Deterministic per-step wall price for :func:`serve_trace` under
    sim: the same unit costs as ``sim.work_latency_model``, but read
    from the engine's cumulative counters instead of a StepRecord (the
    driver may run without telemetry)."""

    def step_seconds(engine, chunks_delta: int,
                     dispatched_decode: bool) -> float:
        s = overhead_s + chunk_s * chunks_delta
        if dispatched_decode:
            s += decode_s
        return s

    return step_seconds


def _prefill_units(engine) -> int:
    """Cumulative prefill work counter: chunks on the paged engine,
    whole prefills on the slot engine."""
    st = engine.stats
    return st.prefill_chunks if getattr(engine, "chunk_size", None) else \
        st.prefills


def serve_trace(cluster, arrivals: List[Arrival], clock=None, *,
                max_ticks: int = 10_000,
                step_seconds: Optional[Callable] = None,
                min_dt: float = 0.0) -> Dict[int, float]:
    """Drive a :class:`ServingCluster` through a scripted trace.

    Per tick: submit every due arrival through the router, step each
    replica once (measuring its wall), advance the shared clock by the
    max per-replica wall (see module docstring), sweep completions.
    Stops when the trace is exhausted and nothing is in flight.

    ``step_seconds(engine, chunks_delta, dispatched_decode)`` prices a
    replica's step deterministically (sim mode); when None the wall is
    measured with ``time.perf_counter`` (real arrays).  ``min_dt`` puts
    a floor under idle ticks so a frozen SimClock still advances while
    replicas wait for the next arrival.

    Returns ``{crid: arrival_t}`` for every ADMITTED request; shed
    requests are counted in ``cluster.stats.shed`` but absent here.
    """
    if clock is None:
        clock = time
    pending = deque(sorted(arrivals, key=lambda a: a[0]))
    admitted: Dict[int, float] = {}
    for _ in range(max_ticks):
        now = clock.time()
        while pending and pending[0][0] <= now:
            t, prompt, max_new, eos = pending.popleft()
            crid = cluster.submit(np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new, eos_id=eos)
            if crid is not None:
                admitted[crid] = t
        dt = min_dt
        for eng in cluster.replicas:
            chunks0 = _prefill_units(eng)
            wall0 = time.perf_counter()
            eng.step()
            if step_seconds is None:
                wall = time.perf_counter() - wall0
            else:
                wall = step_seconds(eng, _prefill_units(eng) - chunks0,
                                    eng._pending is not None)
            dt = max(dt, wall)
        if clock is not time:
            clock.advance(dt)
        cluster.router.collect()
        if not pending and cluster.router.in_flight == 0 \
                and not any(len(eng.queue) for eng in cluster.replicas):
            break
    # flush one-step-ahead pipelines so the last tokens land
    for eng in cluster.replicas:
        if eng._pending is not None:
            eng._drain(eng._pending)
            eng._pending = None
    cluster.router.collect()
    return admitted
