"""Admission policy for the paged engine: chunked prefill as a policy
object.

The slot engine admits a request by prefilling its whole prompt in one
call; a long prompt therefore stalls every in-flight decode behind a wall
of prefill compute.  :class:`ChunkedPrefillScheduler` instead splits each
prompt into fixed-size chunks and interleaves at most a budgeted amount of
prefill work with every decode step:

* **FIFO admission** — work items are ordered by request id: first the
  chunks of requests already placed in rows (admitted earlier, smaller
  rids), then new admissions from the queue head, capped by free rows.
* **Cost-model gating** — each chunk is priced through the engine's
  ``_predict_prefill`` path (``CostModel.predict`` over an analytic
  census) and the planned iteration time (decode step + admitted chunks)
  must stay under ``step_budget_s``.  The first chunk of an iteration is
  always admitted, so a too-tight budget degrades to one-chunk-per-step
  instead of starving prefill.
* **Exact deferral accounting** — ``deferred`` counts only candidates
  that had capacity this step (a row, or a free row for queued requests)
  and were rejected by the budget.  Candidates waiting on row capacity
  are not "deferred by the budget" and are not counted — the corrected
  semantics of the slot engine's ``deferred_prefills`` fix.  (Chunks are
  uniformly priced, so unlike the slot engine's per-prompt-length
  prefills, a budget gate rejects every remaining candidate at once.)

Preemption is the engine's job (it owns the allocator); the scheduler
only owns the queue and exposes ``requeue`` so an evicted request goes
back to the queue *front* and is replayed from scratch (greedy decode is
deterministic, so a restart reproduces the same tokens).  A
``requeue_policy`` hook lets an external owner — the cluster router
(``serve.cluster``) — *reclaim* an evicted request instead (re-route it
to another replica); with no hook installed the front-requeue behavior
is byte-identical to the single-replica engine.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ChunkItem:
    """One planned unit of prefill work.  ``row`` is None for a fresh
    admission (the engine places the request into a free row first);
    ``rid`` pins the identity so a mid-step eviction can be detected."""
    rid: int
    row: Optional[int]
    request: object


@dataclasses.dataclass
class StepPlan:
    """What one engine iteration should do, and what it will cost."""
    items: List[ChunkItem]
    run_decode: bool
    predicted_s: float
    deferred: int


class ChunkedPrefillScheduler:
    """Chunked-prefill admission policy (see module docstring)."""

    def __init__(self, chunk_size: int = 32, *,
                 step_budget_s: Optional[float] = None,
                 requeue_policy: Optional[Callable[[object], bool]] = None):
        if chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        self.chunk_size = chunk_size
        self.step_budget_s = step_budget_s
        self.requeue_policy = requeue_policy
        self.queue: Deque = deque()

    # -- queue ownership ------------------------------------------------------
    def submit(self, req) -> None:
        self.queue.append(req)

    def requeue(self, req) -> None:
        """Re-enqueue an evicted request at the FRONT: it was admitted
        before anything still waiting, so it keeps its FIFO priority.

        When a ``requeue_policy`` hook is installed (the cluster router's
        reclaim point) and it returns True, the request has been CLAIMED
        by the hook's owner — typically re-routed to another replica —
        and does not re-enter this queue.  A hook returning False (or no
        hook, the default) preserves the single-replica front-requeue
        byte-for-byte."""
        if self.requeue_policy is not None and self.requeue_policy(req):
            return
        self.queue.appendleft(req)

    def take(self, req) -> None:
        """Remove a specific planned request from the queue (by identity —
        evictions may have prepended other requests since the plan was
        made, so popleft would grab the wrong one)."""
        self.queue.remove(req)

    def __len__(self) -> int:
        return len(self.queue)

    # -- the policy -----------------------------------------------------------
    def plan(self, *, unfinished: Sequence[Tuple[int, int, object]],
             n_free_rows: int, any_ready: bool,
             decode_s: float, chunk_s: float,
             gated: bool, budget_s: Optional[float] = None) -> StepPlan:
        """Choose this iteration's prefill chunks.

        unfinished   (row, rid, request) for rows mid-prefill, FIFO order
        n_free_rows  rows a fresh admission could take
        any_ready    True when a decode step will run this iteration
        decode_s     predicted decode-step time (0.0 without a cost model)
        chunk_s      predicted time of one prefill chunk
        gated        True when a cost model + step budget are attached
        budget_s     per-call budget override: the engine passes its
                     effective budget here (the SLO token bucket's
                     adaptive per-step allowance, ``serve.telemetry``)
                     instead of the static ``step_budget_s``
        """
        budget = self.step_budget_s if budget_s is None else budget_s
        cands: List[ChunkItem] = [
            ChunkItem(rid, row, req) for row, rid, req in unfinished]
        for req in list(self.queue)[:max(n_free_rows, 0)]:
            cands.append(ChunkItem(req.rid, None, req))

        planned = decode_s if any_ready else 0.0
        items: List[ChunkItem] = []
        deferred = 0
        for c in cands:
            if gated and items and planned + chunk_s > budget:
                # budget gate: every remaining candidate had capacity (a
                # row, or a free row by the queue cap above) and — chunks
                # being uniformly priced, unlike the slot engine's
                # per-prompt-length prefills — every one of them is
                # budget-rejected, so all count as deferred
                deferred = len(cands) - len(items)
                break
            items.append(c)
            planned += chunk_s
        return StepPlan(items=items, run_decode=any_ready,
                        predicted_s=planned, deferred=deferred)
