"""Paged KV-cache bookkeeping: block allocator, per-request block tables,
and copy-on-retire compaction planning.

The physical KV store is a fixed pool of ``n_blocks`` blocks of
``block_size`` token slots each (one pool shared by every layer — the
jax-side arrays are ``[L, n_blocks, block_size, KH, hd]``, allocated once
by ``Model.init_paged_cache``).  A request owns a *block table*: the list
of physical block ids backing its logical token positions, grown one block
at a time as prefill chunks land and decode extends the context.  Slot
granularity therefore drops from ``max_len`` tokens (the slot engine's
per-sequence stripe) to ``block_size`` tokens, which is exactly the access
granularity the paper's hierarchy tables say governs realized memory cost.

Everything in this module is host-side Python over plain ints — no jax —
so the allocator can be property-tested exhaustively and the engine's
device arrays stay pure data.  Determinism: ``alloc`` always hands out the
lowest free block id, so identical request traces produce identical block
tables (and identical gather indices) run over run.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to back ``n_tokens`` logical slots."""
    return -(-max(n_tokens, 0) // block_size)


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks.

    ``alloc`` pops the lowest free id (deterministic layouts);
    ``free`` returns blocks to the pool; ``check`` asserts the
    free/allocated sets always partition the pool (the leak invariant the
    property tests and the CI smoke step pin down).
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError("n_blocks and block_size must be positive")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(n_blocks))
        heapq.heapify(self._free)
        self._allocated: set[int] = set()
        self.peak_in_use = 0

    # -- core -----------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return len(self._allocated)

    @property
    def occupancy(self) -> float:
        return self.n_in_use / self.n_blocks

    def alloc(self) -> Optional[int]:
        """Lowest free block id, or None when the pool is exhausted."""
        if not self._free:
            return None
        b = heapq.heappop(self._free)
        self._allocated.add(b)
        self.peak_in_use = max(self.peak_in_use, self.n_in_use)
        return b

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise ValueError(f"double free / foreign block {b}")
            self._allocated.remove(b)
            heapq.heappush(self._free, b)

    def check(self) -> None:
        """Assert the pool invariant: free ⊎ allocated == [0, n_blocks)."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate ids on the free list")
        if free & self._allocated:
            raise AssertionError("block both free and allocated")
        if free | self._allocated != set(range(self.n_blocks)):
            raise AssertionError("pool leaked or grew")

    # -- compaction -----------------------------------------------------------
    def watermark(self) -> int:
        """1 + the highest allocated block id (0 when empty): the span of
        the physical pool that decode gathers can touch."""
        return max(self._allocated) + 1 if self._allocated else 0

    def compaction_plan(self) -> Optional[Tuple[List[int], List[int]]]:
        """Plan a copy-on-retire compaction: map the allocated blocks,
        in ascending id order, onto the lowest ids.  Returns ``(src, dst)``
        move lists (only ids that actually move), or None when the
        allocation is already dense.  The caller must copy the physical
        pages ``src -> dst`` (gather-then-scatter, so overlap is safe),
        remap every live block table through :func:`apply_remap`, and then
        call :meth:`commit_compaction`.
        """
        used = sorted(self._allocated)
        moves = [(s, d) for d, s in enumerate(used) if s != d]
        if not moves:
            return None
        return [s for s, _ in moves], [d for _, d in moves]

    def commit_compaction(self) -> None:
        """Re-key the pool after the physical copy: allocated blocks become
        ``[0, n_in_use)`` and everything above is free again."""
        n = self.n_in_use
        self._allocated = set(range(n))
        self._free = list(range(n, self.n_blocks))
        heapq.heapify(self._free)


def remap_table(table: Sequence[int], src: Sequence[int],
                dst: Sequence[int]) -> List[int]:
    """Rewrite one block table through a compaction move list (-1 entries —
    unbacked logical blocks — pass through untouched)."""
    m: Dict[int, int] = dict(zip(src, dst))
    return [m.get(b, b) for b in table]
