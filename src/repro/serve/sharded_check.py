"""The sharded replica's acceptance check, runnable in-process or as a
subprocess with a forced multi-device CPU host.

The tentpole contract (docs/architecture.md, "Sharded replicas"): a
:class:`~repro.serve.engine.PagedServingEngine` built on a
``('data', 'model')`` mesh must produce greedy tokens BYTE-IDENTICAL to
the single-device engine on the acceptance trace — sharding the KV pool
over KV heads and the loop state over batch rows is a layout change,
never a numerics change — while keeping the fused path's invariants
(<= 1 host sync per step, donated pool).

CPU hosts have one device unless XLA is told otherwise, and the flag
must be set BEFORE jax initializes — so the check ships a subprocess
runner (``run_subprocess``) that re-enters this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and parses the
JSON the child prints.  Three consumers share it: the
``test_sharded_decode.py`` suite, the ``sharded_decode`` campaign
experiment (measured-vs-predicted step time per factorization), and the
CI multi-device smoke job (which sets the flag itself and runs
``python -m repro.serve.sharded_check``).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

ENGINE_KW = dict(max_batch=4, max_len=48, block_size=8, n_blocks=10,
                 chunk_size=8)   # tight pool: evictions + compactions fire


def acceptance_trace(cfg, n_req: int = 32, seed: int = 11,
                     max_prompt: int = 31) -> List[np.ndarray]:
    """THE 32-request acceptance trace (same generator as the
    decode-hotpath suite): random prompts of 1..max_prompt tokens."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(1, max_prompt))
                         ).astype(np.int32) for _ in range(n_req)]


def _run_trace(eng, prompts, max_new: int):
    rids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_until_done(max_steps=20_000)
    return [eng.done[r].tokens for r in rids]


def parse_shapes(text: str) -> List[Tuple[int, int]]:
    """'1x1,2x1,2x2' -> [(1, 1), (2, 1), (2, 2)] (data x model)."""
    out = []
    for part in text.split(","):
        d, m = part.lower().split("x")
        out.append((int(d), int(m)))
    return out


def _kernel_check(devs) -> Optional[bool]:
    """Cross-check ``paged_attention_sharded``'s shard_map route against
    the unsharded kernel on a (2, 2) mesh — the head/batch index-space
    split must be invisible in the outputs.  None when the host has too
    few devices to build the mesh (nothing to check)."""
    if len(devs) < 4:
        return None
    import jax.numpy as jnp

    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_sharded)
    from repro.launch.mesh import make_host_mesh

    rng = np.random.default_rng(3)
    B, H, KH, D, bs, pages = 4, 8, 4, 16, 8, 12
    q = jnp.asarray(rng.normal(size=(B, H, D)) * 0.3, jnp.float32)
    kp = jnp.asarray(rng.normal(size=(pages, bs, KH, D)) * 0.3, jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pages, bs, KH, D)) * 0.3, jnp.float32)
    bt = jnp.asarray(rng.permutation(pages)[:B * 3].reshape(B, 3), jnp.int32)
    ctx = jnp.asarray([5, 24, 17, 1], jnp.int32)
    mesh = make_host_mesh(model_axis=2, devices=devs[:4])
    o = paged_attention_sharded(q, kp, vp, bt, ctx, mesh, interpret=True)
    r = paged_attention(q, kp, vp, bt, ctx, interpret=True)
    return bool(np.allclose(np.asarray(o), np.asarray(r), atol=1e-5))


def run_check(shapes: Sequence[Tuple[int, int]], *, n_req: int = 32,
              max_new: int = 4, predict: bool = True) -> dict:
    """Run the acceptance comparison in THIS process (the caller is
    responsible for the device count — see ``run_subprocess``).

    Returns a JSON-able doc: the single-device reference run plus, per
    (data, model) shape, token equality, the sync/donation invariants,
    eviction/compaction coverage, measured wall-clock per step and the
    cost model's predicted step time for that factorization."""
    import jax

    from repro.configs import ARCHS, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models.zoo import build_model
    from repro.serve.engine import PagedServingEngine

    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = acceptance_trace(cfg, n_req=n_req)
    devs = jax.devices()

    t0 = time.perf_counter()
    ref_eng = PagedServingEngine(model, params, fused=True, **ENGINE_KW)
    ref = _run_trace(ref_eng, prompts, max_new)
    ref_wall = time.perf_counter() - t0

    doc = {"devices": len(devs), "arch": cfg.name, "n_req": n_req,
           "max_new": max_new,
           "reference": {"steps": ref_eng.stats.steps,
                         "host_syncs": ref_eng.stats.host_syncs,
                         "wall_s": ref_wall,
                         "step_s": ref_wall / max(ref_eng.stats.steps, 1)},
           "shapes": [], "ok": True}

    preds = {}
    if predict:
        from repro.configs.base import ShapeCell
        from repro.sharding.plans import rank_plans
        cell = ShapeCell("sharded", "decode", ENGINE_KW["max_len"],
                         ENGINE_KW["max_batch"])
        for n in {d * m for d, m in shapes}:
            for plan in rank_plans(cfg, cell, n):
                preds[(plan.data, plan.model)] = plan.step_s

    for d, m in shapes:
        need = d * m
        if need > len(devs):
            doc["shapes"].append({"data": d, "model": m,
                                  "skipped": f"needs {need} devices, "
                                             f"have {len(devs)}"})
            continue
        mesh = make_host_mesh(model_axis=m, devices=devs[:need])
        t0 = time.perf_counter()
        eng = PagedServingEngine(model, params, fused=True, mesh=mesh,
                                 **ENGINE_KW)
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        old_pool = jax.tree.leaves(eng.cache)
        with jax.transfer_guard_device_to_host("disallow"):
            eng.step()
            donated = all(x.is_deleted() for x in old_pool)
            eng.run_until_done(max_steps=20_000)
        wall = time.perf_counter() - t0
        toks = [eng.done[r].tokens for r in sorted(eng.done)]
        entry = {
            "data": d, "model": m,
            "identical": toks == ref,
            "steps": eng.stats.steps,
            "host_syncs": eng.stats.host_syncs,
            "sync_per_step_ok": eng.stats.host_syncs <= eng.stats.steps,
            "donated": donated,
            "preemptions": eng.stats.preemptions,
            "compactions": eng.stats.compactions,
            "wall_s": wall,
            "step_s": wall / max(eng.stats.steps, 1),
            "predicted_step_s": preds.get((d, m)),
            "sharding_log": eng.sharding_log,
        }
        entry["ok"] = bool(entry["identical"] and entry["sync_per_step_ok"]
                           and entry["donated"])
        doc["ok"] = doc["ok"] and entry["ok"]
        doc["shapes"].append(entry)
    doc["kernel_sharded_ok"] = _kernel_check(devs)
    doc["ok"] = doc["ok"] and doc["kernel_sharded_ok"] is not False
    return doc


def run_subprocess(shapes: Sequence[Tuple[int, int]], *, devices: int = 8,
                   n_req: int = 32, max_new: int = 4,
                   timeout_s: float = 1200.0) -> dict:
    """Re-enter this module in a child process with
    ``--xla_force_host_platform_device_count=<devices>`` set before jax
    initializes there, and return the parsed JSON doc."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = " ".join(f for f in flags.split()
                     if not f.startswith("--xla_force_host_platform"))
    env["XLA_FLAGS"] = (flags + " "
                       f"--xla_force_host_platform_device_count={devices}"
                       ).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    shape_arg = ",".join(f"{d}x{m}" for d, m in shapes)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.serve.sharded_check",
         "--shapes", shape_arg, "--n-req", str(n_req),
         "--max-new", str(max_new)],
        capture_output=True, text=True, env=env, timeout=timeout_s)
    if proc.returncode not in (0, 1):   # 1 = ran but a contract failed
        raise RuntimeError(
            f"sharded_check subprocess died (rc={proc.returncode}):\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="sharded-replica acceptance check (JSON to stdout)")
    ap.add_argument("--shapes", default="1x1,2x1,1x2,2x2",
                    help="comma-separated dataxmodel factorizations")
    ap.add_argument("--n-req", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--no-predict", action="store_true",
                    help="skip cost-model predictions (faster)")
    args = ap.parse_args(argv)
    doc = run_check(parse_shapes(args.shapes), n_req=args.n_req,
                    max_new=args.max_new, predict=not args.no_predict)
    print(json.dumps(doc))
    return 0 if doc["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
