"""Fault injection: what goes wrong, scripted and replayable.

A :class:`FaultSpec` names one fault; a :class:`FaultPlan` is the full
scripted schedule for a drill — either hand-written or drawn from a
seeded RNG (:meth:`FaultPlan.random`), so a chaos run replays
byte-for-byte from ``(plan, trace)`` alone.  Faults are realized by
wrapping each replica engine in a :class:`FaultyReplica`: the wrapper
delegates every attribute to the engine (the router, scheduler hooks and
trace drivers all see a normal replica) and intercepts only ``step()``,
where the plan can

* **crash** — the replica stops dead at step N: no more stepping, no
  more heartbeats, its in-flight pipeline never drains.  The process is
  gone; recovery may not ask it to clean up.
* **hang** (straggle) — steps keep completing but take ``factor``×
  longer for ``duration`` steps: the heartbeat carries the inflated
  step time, which is exactly what the straggler detector eats.
* **corrupt** — one step's ``[2, B]`` token echo is poisoned (negative
  ids — what NaN logits argmax into after a device fault) so the
  engine-side integrity probe must catch it at drain time.

``crashloop`` is a crash that RECURS on every restart generation —
:meth:`FaultPlan.wrap` re-arms it on the rewrapped engine, driving the
``RestartPolicy`` crash-loop breaker; every other fault fires only in
generation 0 (a restarted replica is healthy).
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Tuple

import numpy as np

KINDS = ("crash", "hang", "corrupt", "crashloop")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault on one replica.

    ``at_step`` counts the WRAPPER's ``step()`` calls (a replica steps
    once per cluster tick, so this is also the tick index for a replica
    present from tick 0).  ``duration``/``factor`` only apply to
    ``hang``.
    """
    kind: str                  # one of KINDS
    replica: int
    at_step: int
    duration: int = 4          # hang: steps the slowdown lasts
    factor: float = 8.0        # hang: step-time multiplier

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(want one of {KINDS})")
        if self.at_step < 0 or self.replica < 0:
            raise ValueError("at_step and replica must be >= 0")
        if self.kind == "hang" and (self.duration < 1 or self.factor <= 1):
            raise ValueError("hang needs duration >= 1 and factor > 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """The drill's whole fault schedule; pure data, hashable, replayable."""
    specs: Tuple[FaultSpec, ...]

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    @classmethod
    def random(cls, kind: str, n_replicas: int, seed: int = 0, *,
               step_range: Tuple[int, int] = (2, 8)) -> "FaultPlan":
        """One seeded fault of ``kind`` on a seeded replica — the
        campaign's grid axis.  Same ``(kind, n_replicas, seed)`` ⇒ same
        plan, byte-for-byte."""
        rng = random.Random((seed, kind, n_replicas).__repr__())
        return cls((FaultSpec(kind, rng.randrange(n_replicas),
                              rng.randrange(*step_range)),))

    def for_replica(self, i: int, generation: int) -> List[FaultSpec]:
        """Specs live on replica ``i`` at restart ``generation`` (0 =
        the original process).  Only ``crashloop`` survives a restart,
        and a restarted crash-looper dies ON STARTUP (``at_step=0``) —
        that is what crash-looping means, and it guarantees the
        ``RestartPolicy`` breaker trips instead of the loop racing the
        end of the trace."""
        out = []
        for s in self.specs:
            if s.replica != i:
                continue
            if generation == 0:
                out.append(s)
            elif s.kind == "crashloop":
                out.append(dataclasses.replace(s, at_step=0))
        return out

    def wrap(self, engine, i: int, generation: int,
             clock=None) -> "FaultyReplica":
        return FaultyReplica(engine, self.for_replica(i, generation),
                             clock=clock)


class FaultyReplica:
    """Transparent engine wrapper that executes a replica's FaultSpecs.

    Everything except the intercepted surface (``step``, fault state)
    delegates to the wrapped engine, both reads AND writes — the router
    installs its reclaim closure on ``wrapper.scheduler``, the trace
    driver clears ``wrapper._pending``, and both reach the real engine.
    """

    # attributes owned by the wrapper itself; everything else delegates
    _OWN = frozenset({"engine", "specs", "clock", "calls", "crashed",
                      "wall_scale", "injected", "fired"})

    def __init__(self, engine, specs: List[FaultSpec], clock=None):
        object.__setattr__(self, "engine", engine)
        object.__setattr__(self, "specs", list(specs))
        object.__setattr__(self, "clock", clock)
        object.__setattr__(self, "calls", 0)
        object.__setattr__(self, "crashed", False)
        object.__setattr__(self, "wall_scale", 1.0)
        object.__setattr__(self, "injected", [])  # (kind, call#) audit trail
        object.__setattr__(self, "fired", set())  # spec indices already run

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def __setattr__(self, name, value):
        if name in self._OWN:
            object.__setattr__(self, name, value)
        else:
            setattr(self.engine, name, value)

    # -- the intercepted step -------------------------------------------------
    def step(self) -> int:
        """One engine step, with this replica's faults applied.  A
        crashed replica returns 0 forever without touching the engine —
        its queue, rows and pending pipeline freeze exactly as a dead
        process leaves them."""
        if self.crashed:
            return 0
        call = self.calls
        self.calls = call + 1
        scale = 1.0
        for s in self.specs:
            if s.kind in ("crash", "crashloop") and call >= s.at_step:
                self.crashed = True
                self.injected.append((s.kind, call))
                return 0
            if s.kind == "hang" and s.at_step <= call < s.at_step + s.duration:
                scale = max(scale, s.factor)
        self.wall_scale = scale
        produced = self.engine.step()
        for k, s in enumerate(self.specs):
            if (s.kind == "corrupt" and call >= s.at_step
                    and k not in self.fired and self._poison_pending()):
                self.fired.add(k)
                self.injected.append((s.kind, call))
        return produced

    def _poison_pending(self) -> bool:
        """Corrupt the in-flight step's token echo: pull the device
        array, overwrite the output row with negative ids (the host-side
        face of NaN logits), and leave the poisoned host array in
        ``_pending`` for the next drain to choke on.  With nothing in
        flight the fault stays ARMED (returns False) and fires on the
        replica's next busy step — a bit flip in an idle buffer that
        nobody ever reads is not an observable fault."""
        eng = self.engine
        if eng._pending is None:
            return False
        import jax
        io, snap = eng._pending
        arr = np.array(jax.device_get(io))
        arr[1, :] = -1
        eng._pending = (arr, snap)
        return True
