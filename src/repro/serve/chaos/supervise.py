"""Failure detection and crash-consistent recovery for a ServingCluster.

:class:`ChaosSupervisor` installs itself on a cluster
(``cluster.supervisor = self``) and takes over per-replica stepping:
each live replica's step is priced (sim) or measured (wall), beaten into
the repo's existing :class:`~repro.distributed.fault_tolerance.
HeartbeatRegistry`, and the detection sweep runs once per cluster tick:

* **dead** — a crashed replica stops beating; ``registry.sweep`` trips
  after ``miss_limit`` missed intervals.
* **straggler** — a hung replica keeps beating but its step-time EWMA
  crosses ``straggler_abs_limit_s`` (or the MAD criterion on >= 3
  replicas).  Synchronous serving makes one straggler everyone's
  straggler, so the verdict is the same as death: evict and recover.
* **corrupt** — the engine's drain-side integrity probe
  (``EngineStats.integrity_failures``) moved, or the block pool fails
  ``BlockAllocator.check`` after an eviction/compaction.

Recovery is crash-consistent because prompts are retained on every
``Request``: the router reclaims the dead replica's in-flight requests
(:meth:`~repro.serve.cluster.router.Router.reclaim_replica`) and
re-places each on a survivor under its original cluster id and
``submitted_s``, with a per-request retry budget and exponential
backoff between attempts; requests over budget are abandoned (shed
after admission — loud in ``RouteStats.abandoned``, never silent).
Admission meanwhile brownouts: every surviving controller's SLO token
bucket is tightened to the surviving-capacity fraction.  The failed
replica restarts under a per-replica
:class:`~repro.distributed.fault_tolerance.RestartPolicy` — the
crash-loop breaker quarantines a flapping replica instead of letting it
rejoin forever — and warm-rejoins via the caller's ``engine_factory``
(re-JIT hits the persistent tuning cache), a fresh telemetry bind, a
fresh heartbeat identity, and the router resuming placement to it.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional

from repro.distributed.fault_tolerance import (HeartbeatRegistry,
                                               RestartPolicy)


@dataclasses.dataclass
class FailureRecord:
    """One detected failure and what recovery did about it."""
    replica: int
    kind: str                      # "dead" | "straggler" | "corrupt"
    t_detect_s: float
    generation: int                # which incarnation failed (0 = original)
    n_reclaimed: int = 0
    n_resubmitted: int = 0
    n_abandoned: int = 0
    t_rejoin_s: Optional[float] = None   # None while down / if quarantined
    quarantined: bool = False

    @property
    def recovery_s(self) -> Optional[float]:
        return (None if self.t_rejoin_s is None
                else self.t_rejoin_s - self.t_detect_s)


@dataclasses.dataclass
class _Retry:
    ready_s: float
    crid: int
    req: object
    failure: "FailureRecord"


class ChaosSupervisor:
    """Detection + recovery policy over one ServingCluster.

    Parameters
    ----------
    cluster:
        The :class:`~repro.serve.cluster.cluster.ServingCluster` to
        supervise; ``cluster.supervisor`` is set to this object.
    clock:
        The shared clock (``SimClock`` or the ``time`` module).
    engine_factory:
        ``factory(i, generation, controller) -> engine`` builds the
        restarted replica ``i`` (wrap it in the fault plan yourself for
        crash-loop drills).  ``None`` disables rejoin: failed replicas
        stay down and the cluster runs degraded.
    step_seconds:
        Optional deterministic step pricer
        (``traffic.unit_latency``-shaped); when None the step wall is
        measured.  A replica's ``wall_scale`` (hang injection) scales
        the priced wall.
    heartbeat_interval_s / miss_limit:
        Failure-detector cadence: a silent replica is dead after
        ``miss_limit`` missed intervals.
    straggler_abs_limit_s:
        Absolute step-time EWMA ceiling (works at any fleet size; the
        MAD criterion also runs when >= 3 replicas are live).  None
        disables straggler eviction.
    retry_budget:
        Cross-failure resubmission attempts per request before it is
        abandoned.
    resubmit_backoff_s:
        Base of the per-request exponential backoff between reclaim and
        resubmit (doubles per attempt).
    """

    def __init__(self, cluster, clock=None, *,
                 engine_factory: Optional[Callable] = None,
                 step_seconds: Optional[Callable] = None,
                 heartbeat_interval_s: float = 1.0,
                 miss_limit: int = 3,
                 straggler_abs_limit_s: Optional[float] = None,
                 retry_budget: int = 3,
                 resubmit_backoff_s: float = 0.5,
                 restart_policy: Optional[Callable[[], RestartPolicy]]
                 = None):
        self.cluster = cluster
        self.clock = clock if clock is not None else _time
        self.engine_factory = engine_factory
        self.step_seconds = step_seconds
        self.straggler_abs_limit_s = straggler_abs_limit_s
        self.retry_budget = retry_budget
        self.resubmit_backoff_s = resubmit_backoff_s
        n = len(cluster.replicas)
        self.registry = HeartbeatRegistry(
            interval_s=heartbeat_interval_s, miss_limit=miss_limit)
        make_policy = restart_policy or (lambda: RestartPolicy(
            backoff_base_s=heartbeat_interval_s, backoff_cap_s=60.0,
            crash_loop_limit=3))
        self.restart_policies = [make_policy() for _ in range(n)]
        self.generation = [0] * n
        self.alive = [True] * n
        self.failures: List[FailureRecord] = []
        self.walls = [0.0] * n
        self._stepped = [False] * n
        self._int_seen = [0] * n          # integrity_failures watermark
        self._pool_seen = [(0, 0)] * n    # (preemptions, compactions)
        self._retries: List[_Retry] = []
        self._attempts: Dict[int, int] = {}     # crid -> resubmit attempts
        self._rejoin_at: Dict[int, float] = {}  # replica -> ready time
        self._open_failure: Dict[int, FailureRecord] = {}
        now = self.clock.time()
        for i in range(n):
            self.registry.register(self._host(i), now=now)
        cluster.supervisor = self

    def _host(self, i: int) -> str:
        return f"replica-{i}.g{self.generation[i]}"

    # -- stepping -------------------------------------------------------------
    def step_replica(self, i: int) -> int:
        """Step replica ``i`` if it is live; price/measure its wall.
        Returns the engine's step() result (0 for a dead replica)."""
        if not self.alive[i]:
            self.walls[i] = 0.0
            self._stepped[i] = False
            return 0
        eng = self.cluster.replicas[i]
        chunks0 = _prefill_units(eng)
        wall0 = _time.perf_counter()
        produced = eng.step()
        if getattr(eng, "crashed", False):
            # the process died inside this tick: no beat, no wall
            self.walls[i] = 0.0
            self._stepped[i] = False
            return produced
        if self.step_seconds is None:
            wall = _time.perf_counter() - wall0
        else:
            wall = self.step_seconds(eng, _prefill_units(eng) - chunks0,
                                     eng._pending is not None)
        self.walls[i] = wall * getattr(eng, "wall_scale", 1.0)
        self._stepped[i] = True
        return produced

    # -- the per-tick sweep ---------------------------------------------------
    def after_tick(self) -> List[FailureRecord]:
        """Heartbeats, detection, recovery and rejoin — run once per
        cluster tick AFTER the shared clock advanced, so the failure
        detector sees the tick's time passing."""
        now = self.clock.time()
        newly: List[FailureRecord] = []
        for i in range(len(self.cluster.replicas)):
            if self.alive[i] and self._stepped[i]:
                self.registry.beat(self._host(i), self.walls[i], now=now)
        # corrupt: drain-probe watermark + pool audit on eviction traffic
        for i, eng in enumerate(self.cluster.replicas):
            if not self.alive[i]:
                continue
            if getattr(eng.stats, "integrity_failures", 0) > self._int_seen[i]:
                newly.append(self._fail(i, "corrupt", now))
                continue
            if not self._pool_ok(i, eng):
                newly.append(self._fail(i, "corrupt", now))
        # dead: missed heartbeats
        host_to_i = {self._host(i): i
                     for i in range(len(self.cluster.replicas))
                     if self.alive[i]}
        for host in self.registry.sweep(now=now):
            i = host_to_i.get(host)
            if i is not None and self.alive[i]:
                newly.append(self._fail(i, "dead", now))
        # stragglers: inflated-but-beating replicas.  Only the ABSOLUTE
        # ceiling votes here: the registry's MAD criterion assumes the
        # near-uniform step walls of synchronous SPMD training, and a
        # serving fleet under skewed load legitimately has one busy
        # replica walking away from idle peers — MAD would evict the
        # healthy busy one.  The cost model gives us the healthy step
        # price, so the ceiling is the calibrated signal.
        if self.straggler_abs_limit_s is not None:
            for host in self.registry.stragglers(
                    z_threshold=float("inf"),
                    abs_limit_s=self.straggler_abs_limit_s):
                i = host_to_i.get(host)
                if i is not None and self.alive[i]:
                    newly.append(self._fail(i, "straggler", now))
        self._pump_retries(now)
        self._pump_rejoins(now)
        # hygiene: retry counters for requests that completed (collected
        # by the router) or were abandoned must not accumulate forever
        tracked = (set(self.cluster.router._local)
                   | {r.crid for r in self._retries})
        self._attempts = {c: a for c, a in self._attempts.items()
                          if c in tracked}
        return newly

    def _pool_ok(self, i: int, eng) -> bool:
        """Audit the block pool when eviction/compaction traffic moved
        (the cheap moments a poisoned free list becomes reachable)."""
        alloc = getattr(eng, "allocator", None)
        if alloc is None:
            return True
        st = eng.stats
        marks = (st.preemptions, st.compactions)
        if marks == self._pool_seen[i]:
            return True
        self._pool_seen[i] = marks
        try:
            alloc.check()
            return True
        except AssertionError:
            return False

    # -- failure --------------------------------------------------------------
    def _fail(self, i: int, kind: str, now: float) -> FailureRecord:
        """Declare replica ``i`` failed: stop routing to it, reclaim its
        requests, brownout admission, schedule restart."""
        router = self.cluster.router
        self.alive[i] = False
        router.set_live(i, False)
        self.registry.deregister(self._host(i))
        rec = FailureRecord(i, kind, now, self.generation[i])
        tel = self.cluster.telemetry
        if tel is not None and hasattr(tel, "tag_dead"):
            tel.tag_dead(i, now, kind)
        # reclaim + resubmit-with-backoff (or abandon over budget)
        reclaimed = router.reclaim_replica(i)
        rec.n_reclaimed = len(reclaimed)
        for crid, req in reclaimed:
            attempts = self._attempts.get(crid, 0)
            if attempts >= self.retry_budget:
                router.abandon(crid)
                self._attempts.pop(crid, None)
                rec.n_abandoned += 1
                continue
            self._attempts[crid] = attempts + 1
            delay = self.resubmit_backoff_s * (2 ** attempts)
            self._retries.append(_Retry(now + delay, crid, req, rec))
        # brownout: tighten every surviving bucket to surviving capacity
        live = router.live_indices()
        if tel is not None and live:
            frac = len(live) / len(self.cluster.replicas)
            for j in live:
                ctrl = tel.controllers[j]
                if getattr(ctrl, "bucket", None) is not None:
                    ctrl.bucket.tighten(frac)
        # restart under the crash-loop breaker
        if self.engine_factory is not None:
            backoff = self.restart_policies[i].on_failure(now)
            if backoff is None:
                rec.quarantined = True
            else:
                self._rejoin_at[i] = now + backoff
        self.failures.append(rec)
        self._open_failure[i] = rec
        return rec

    # -- recovery pumps -------------------------------------------------------
    def _pump_retries(self, now: float) -> None:
        due = [r for r in self._retries if r.ready_s <= now]
        if not due:
            return
        self._retries = [r for r in self._retries if r.ready_s > now]
        router = self.cluster.router
        for r in due:
            if router.resubmit(r.crid, r.req):
                r.failure.n_resubmitted += 1
                continue
            # no live capacity: retry again later (or abandon over budget)
            attempts = self._attempts.get(r.crid, 0)
            if attempts >= self.retry_budget:
                router.abandon(r.crid)
                self._attempts.pop(r.crid, None)
                r.failure.n_abandoned += 1
            else:
                self._attempts[r.crid] = attempts + 1
                delay = self.resubmit_backoff_s * (2 ** attempts)
                self._retries.append(_Retry(now + delay, r.crid, r.req,
                                            r.failure))

    def _pump_rejoins(self, now: float) -> None:
        for i in [i for i, t in list(self._rejoin_at.items()) if t <= now]:
            del self._rejoin_at[i]
            self._rejoin(i, now)

    def _rejoin(self, i: int, now: float) -> None:
        """Warm-rejoin a restarted replica: fresh engine (re-JIT against
        the persistent tuning cache), fresh telemetry bind, fresh
        heartbeat identity, router routing to it again."""
        self.generation[i] += 1
        tel = self.cluster.telemetry
        ctrl = (tel.rebind(i) if tel is not None and hasattr(tel, "rebind")
                else None)
        eng = self.engine_factory(i, self.generation[i], ctrl)
        self.cluster.replace_replica(i, eng)
        self.registry.register(self._host(i), now=now)
        self.cluster.router.set_live(i, True)
        self.alive[i] = True
        self._int_seen[i] = 0
        self._pool_seen[i] = (0, 0)
        rec = self._open_failure.pop(i, None)
        if rec is not None:
            rec.t_rejoin_s = now

    # -- introspection --------------------------------------------------------
    @property
    def idle(self) -> bool:
        """No recovery work outstanding (retries queued or rejoins
        scheduled)."""
        return not self._retries and not self._rejoin_at

    def resubmitted_count(self) -> int:
        return self.cluster.router.stats.recovered


def _prefill_units(engine) -> int:
    st = engine.stats
    return st.prefill_chunks if getattr(engine, "chunk_size", None) else \
        st.prefills
