"""One chaos drill, end to end, with the invariants checked.

:func:`run_chaos_drill` plays the SAME deterministic skewed trace twice
— once on a fault-free twin cluster, once under a :class:`FaultPlan`
with a :class:`ChaosSupervisor` — and gates the recovery claims the
campaign and CI rely on:

* ``survivors_identical`` — every request the chaos run completed whose
  cluster id also completed fault-free has byte-identical tokens, and
  every completed request matches ``expected_tokens`` exactly (recovery
  replays from the retained prompt, so even a twice-moved request must
  land on the same ids).
* ``tokens_lost == 0`` — completed requests are never short a token:
  the drain-drop + replay path recomputes, it never truncates.
* ``blocks_leaked == 0`` and ``BlockAllocator.check`` on every LIVE
  replica after the final flush (a dead replica's pool died with its
  process — it is replaced, not audited).
* ``assert_drained`` — router bookkeeping is empty: everything admitted
  was collected or loudly abandoned within the retry budget.

Everything runs under a :class:`~repro.serve.sim.SimClock` with the
``unit_latency`` step pricer, so the whole drill — fault instant,
detection latency, backoff, rejoin — is an exact computation.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Optional

import numpy as np

from repro.serve.chaos.faults import FaultPlan
from repro.serve.chaos.supervise import ChaosSupervisor
from repro.serve.cluster.cluster import ServingCluster
from repro.serve.cluster.traffic import skewed_trace, unit_latency
from repro.serve.sim import (FakeCostModel, FakeModel, SimClock,
                             expected_tokens)

# one shared sim shape for every drill (mirrors tests/test_cluster.py)
VOCAB = 97
ENGINE_KW = dict(max_batch=4, max_len=64, n_blocks=24, block_size=8,
                 chunk_size=8)
DECODE_S, CHUNK_S, OVERHEAD_S = 0.5, 0.25, 0.01


def _build(n_replicas: int, clock, plan: Optional[FaultPlan],
           telemetry=None, policy: str = "cost_aware"):
    """A paged cluster over FakeModel replicas, optionally fault-wrapped."""
    from repro.serve.engine import PagedServingEngine
    model = FakeModel(vocab=VOCAB)
    cost = FakeCostModel(decode_s=DECODE_S, prefill_s=CHUNK_S)

    def make_engine(i: int, controller=None):
        return PagedServingEngine(model, None, clock=clock, cost_model=cost,
                                  telemetry=controller, **ENGINE_KW)

    replicas = []
    for i in range(n_replicas):
        ctrl = telemetry.controller(i) if telemetry is not None else None
        eng = make_engine(i, ctrl)
        if plan is not None:
            eng = plan.wrap(eng, i, 0, clock=clock)
        replicas.append(eng)
    cluster = ServingCluster(replicas, policy=policy, telemetry=telemetry)
    return cluster, make_engine


def _armed_crash(eng) -> bool:
    """True while a wrapped replica still carries an unfired crash spec
    (it steps every tick, so the spec WILL fire in bounded ticks)."""
    specs = getattr(eng, "specs", None)
    if not specs:
        return False
    return any(s.kind in ("crash", "crashloop") and eng.calls <= s.at_step
               for s in specs)


def _drive(cluster, arrivals, clock, *, supervisor: Optional[ChaosSupervisor],
           max_ticks: int, min_dt: float = 0.25) -> Dict[int, int]:
    """serve_trace with the supervisor in the loop: per tick, submit due
    arrivals, step every replica through the supervisor (priced walls,
    heartbeats), advance the shared clock by the max wall, then run the
    detection/recovery sweep.  Returns ``{crid: trace_index}``."""
    step_seconds = unit_latency(DECODE_S, CHUNK_S, OVERHEAD_S)
    pending = deque(sorted(enumerate(arrivals), key=lambda a: a[1][0]))
    admitted: Dict[int, int] = {}
    router = cluster.router
    for _ in range(max_ticks):
        now = clock.time()
        while pending and pending[0][1][0] <= now:
            k, (t, prompt, max_new, eos) = pending.popleft()
            crid = cluster.submit(np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new, eos_id=eos)
            if crid is not None:
                admitted[crid] = k
        dt = min_dt
        if supervisor is not None:
            for i in range(len(cluster.replicas)):
                supervisor.step_replica(i)
                dt = max(dt, supervisor.walls[i])
        else:
            from repro.serve.cluster.traffic import _prefill_units
            for eng in cluster.replicas:
                c0 = _prefill_units(eng)
                eng.step()
                dt = max(dt, step_seconds(eng, _prefill_units(eng) - c0,
                                          eng._pending is not None))
        clock.advance(dt)
        router.collect()
        if supervisor is not None:
            supervisor.after_tick()
        live = (cluster.replicas if supervisor is None
                else [cluster.replicas[j] for j in router.live_indices()])
        # an exit while a crashed replica is still awaiting its death
        # verdict — or while a crash spec is armed but unfired (a
        # replica rejoined on this very tick hasn't stepped yet) —
        # would end the drill mid-detection and the crash-loop breaker
        # would never trip; keep ticking until the failure detector has
        # nothing left to say
        undetected = any(getattr(eng, "crashed", False) or _armed_crash(eng)
                         for eng in live)
        if (not pending and router.in_flight == 0
                and not any(len(eng.queue) for eng in live)
                and not undetected
                and (supervisor is None or supervisor.idle)):
            break
    for eng in (cluster.replicas if supervisor is None
                else [cluster.replicas[j] for j in router.live_indices()]):
        if eng._pending is not None:
            eng._drain(eng._pending)
            eng._pending = None
    router.collect()
    return admitted


def run_chaos_drill(fault: str, n_replicas: int, *, n_requests: int = 12,
                    seed: int = 0, max_ticks: int = 600) -> Dict[str, object]:
    """Run one ``{fault} x {n_replicas}`` drill; returns the flat metrics
    dict the campaign cell and bench report consume."""
    from repro.serve.cluster.metrics import ClusterTelemetry
    from repro.serve.sim import work_latency_model
    from repro.serve.telemetry.slo import SLO

    trace = skewed_trace(n_requests, vocab=VOCAB, period=2, long_len=24,
                         short_len=4, long_new=12, short_new=4,
                         interval_s=1.0, load=2.0)
    plan = FaultPlan.random(fault, n_replicas, seed)

    # --- fault-free twin -----------------------------------------------------
    clock0 = SimClock()
    base, _ = _build(n_replicas, clock0, plan=None)
    base_admitted = _drive(base, trace, clock0, supervisor=None,
                           max_ticks=max_ticks)
    base_tokens = {k: list(base.done[crid].tokens)
                   for crid, k in base_admitted.items()}

    # --- the chaos run -------------------------------------------------------
    clock = SimClock()
    latency = work_latency_model(DECODE_S, CHUNK_S, OVERHEAD_S)
    tel = ClusterTelemetry(n_replicas, latency_model=latency,
                           slo=SLO(target_p99_s=60.0))
    cluster, make_engine = _build(n_replicas, clock, plan=plan, telemetry=tel)

    def factory(i: int, generation: int, controller):
        return plan.wrap(make_engine(i, controller), i, generation,
                         clock=clock)

    sup = ChaosSupervisor(
        cluster, clock, engine_factory=factory,
        step_seconds=unit_latency(DECODE_S, CHUNK_S, OVERHEAD_S),
        heartbeat_interval_s=1.0, miss_limit=3,
        straggler_abs_limit_s=4.0 * (DECODE_S + OVERHEAD_S),
        retry_budget=3, resubmit_backoff_s=0.5)
    admitted = _drive(cluster, trace, clock, supervisor=sup,
                      max_ticks=max_ticks)

    # --- the invariants ------------------------------------------------------
    router = cluster.router
    done_tokens = {admitted[crid]: list(req.tokens)
                   for crid, req in router.done.items() if crid in admitted}
    exact = all(
        toks == expected_tokens(trace[k][1], trace[k][2], VOCAB, trace[k][3])
        for k, toks in done_tokens.items())
    survivors_identical = exact and all(
        done_tokens[k] == base_tokens[k]
        for k in done_tokens if k in base_tokens)
    tokens_lost = sum(
        max(0, len(expected_tokens(trace[k][1], trace[k][2], VOCAB,
                                   trace[k][3])) - len(toks))
        for k, toks in done_tokens.items())
    router.assert_drained()
    live = router.live_indices()
    blocks_leaked = 0
    for j in live:
        eng = cluster.replicas[j]
        eng.allocator.check()
        blocks_leaked += eng.allocator.n_in_use
    recoveries = [f.recovery_s for f in sup.failures
                  if f.recovery_s is not None]
    completed_or_abandoned = (len(done_tokens) + router.stats.abandoned
                              >= len(admitted))
    return {
        "fault": fault,
        "replicas": n_replicas,
        "n_requests": n_requests,
        "admitted": len(admitted),
        "completed": len(done_tokens),
        "shed": router.stats.shed,
        "abandoned": router.stats.abandoned,
        "recovered": router.stats.recovered,
        "failures": len(sup.failures),
        "failure_kinds": ",".join(sorted({f.kind for f in sup.failures})),
        "quarantined": any(f.quarantined for f in sup.failures),
        "reclaimed": sum(f.n_reclaimed for f in sup.failures),
        "recovery_latency_s": max(recoveries) if recoveries else 0.0,
        "survivors_identical": bool(survivors_identical),
        "all_accounted": bool(completed_or_abandoned),
        "tokens_lost": int(tokens_lost),
        "blocks_leaked": int(blocks_leaked),
        "live_replicas": len(live),
        "t_end_s": clock.time(),
    }
