"""Deterministic fault injection and recovery over the cluster tier.

Three layers, one per module:

* :mod:`~repro.serve.chaos.faults` — WHAT goes wrong: a seeded
  :class:`FaultPlan` of :class:`FaultSpec` entries, realized as
  :class:`FaultyReplica` wrappers around the engines (crash-at-step-N,
  hang/straggle step-time multiplier, corrupted-step token echo).
  Replayable byte-for-byte: same plan, same trace, same tokens.
* :mod:`~repro.serve.chaos.supervise` — WHO notices and what happens
  next: :class:`ChaosSupervisor` wires the repo's existing
  ``distributed.fault_tolerance`` policy layer (heartbeats, straggler
  MAD/ceiling verdicts, restart budget with crash-loop breaker) into
  ``ServingCluster.step``, reclaims a dead replica's requests through
  the router, brownouts admission to surviving capacity, and
  warm-rejoins restarted replicas.
* :mod:`~repro.serve.chaos.drill` — the PROOF: :func:`run_chaos_drill`
  plays one deterministic trace against a fault-free twin and gates
  token byte-identity, zero lost tokens, zero leaked blocks, and a
  drained router after every recovery.
"""
from repro.serve.chaos.faults import FaultPlan, FaultSpec, FaultyReplica
from repro.serve.chaos.supervise import ChaosSupervisor, FailureRecord
from repro.serve.chaos.drill import run_chaos_drill

__all__ = ["FaultPlan", "FaultSpec", "FaultyReplica", "ChaosSupervisor",
           "FailureRecord", "run_chaos_drill"]
