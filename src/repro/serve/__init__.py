from repro.serve.engine import (EngineStats, PagedServingEngine,  # noqa
                                Request, ServingEngine)
from repro.serve.paging import BlockAllocator, blocks_for_tokens  # noqa
from repro.serve.scheduler import ChunkedPrefillScheduler  # noqa
