"""The serving layer: engines, paging, scheduling, telemetry, and the
deterministic simulation harness.

Names resolve lazily (PEP 562, the ``repro.core`` idiom): the engines
import jax eagerly, but ``repro.serve.telemetry`` (metrics schema, drift
detector, SLO bucket) and ``repro.serve.paging`` are host-side — the
docs CI job imports the telemetry schema without paying accelerator-
runtime startup, and log tooling can load snapshots on machines without
jax.
"""
import importlib

# public name -> defining submodule
_EXPORTS = {
    "EngineStats": "engine",
    "PagedServingEngine": "engine",
    "Request": "engine",
    "ServingEngine": "engine",
    "BlockAllocator": "paging",
    "blocks_for_tokens": "paging",
    "ChunkedPrefillScheduler": "scheduler",
    "Router": "cluster",
    "ServingCluster": "cluster",
}
_SUBMODULES = ("cluster", "engine", "paging", "scheduler", "sim",
               "telemetry")

__all__ = sorted(_EXPORTS) + list(_SUBMODULES)


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module(f"repro.serve.{_EXPORTS[name]}")
        return getattr(mod, name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.serve.{name}")
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS) | set(_SUBMODULES))
