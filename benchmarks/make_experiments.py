"""Regenerate EXPERIMENTS.md from the dry-run artifacts + the perf log.

Run after `python -m repro.launch.dryrun --all [--opt]`:
  PYTHONPATH=src python -m benchmarks.make_experiments [--results-dir D] [--out F]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.perfmodel.roofline import from_dryrun, roofline_fraction  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results" / "dryrun"

HEADER = """# EXPERIMENTS

Paper: *Demystifying the Nvidia Ampere Architecture through Microbenchmarking
and Instruction-level Analysis* (Abdelkhalik et al., 2022).
Target hardware: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, 16 GiB HBM,
4 x ~50 GB/s ICI links per chip).  Production meshes: one pod = (16,16) over
('data','model') = 256 chips; multi-pod = (2,16,16) over
('pod','data','model') = 512 chips.  This container is CPU-only: every cell
is lower()+compile()'d against ShapeDtypeStructs (no allocation), and all
performance numbers are MODELLED from the compiled artifact per §Roofline.

## §Paper-validation (the reproduction itself)

The paper's experiments are reproduced as a methodology on this backend and
as a calibration dataset:

* **Table I (chain-length CPI convergence)** — `benchmarks/paper_tables.py
  table1`: t(K)/K falls to a steady state as K grows, exactly the paper's
  "1 instruction costs 5 cycles, >=3 cost 2" effect (here the first-call
  inflation is dispatch overhead; the regression intercept isolates it the
  way the paper subtracts the 2-cycle clock overhead).
* **Table II (dependent vs independent)** — measured on this host:
  transcendental ops show ~5x dependent/independent ratios (e.g. exp.f32
  ~113us dep vs ~19us ind per chain step at the benchmark tile), the same
  ILP effect the paper measures on the GPU pipelines; MXU-class ops are
  issue-limited either way.
* **Table III (tensor core / MXU)** — `table3`: per dtype x tile shape,
  dependent-chain latency and throughput; the dtype hierarchy
  (bf16 > f32) reproduces the paper's TC ordering on every backend.
* **Table IV (memory hierarchy)** — `table4`: the pointer chase resolves
  this host's L1/L2/DRAM at ~4.5/9.7/21-37 ns per hop; on TPU the same
  harness (plus `kernels/microbench_chase`) resolves VMEM vs HBM, the
  memory-space sweep that replaces the paper's .cv/.cg/.ca cache-operator
  sweep (TPU has no hardware caches to bypass).
* **Table V (PTX->SASS map)** — `table5`: per op class, the
  StableHLO -> optimized-HLO expansion (e.g. softmax.f32: 16 portable ops ->
  42 optimized ops across 6 fusions; scan8: 11 -> 28 with the while-loop
  machinery), our analogue of the paper's instruction-mapping table,
  verified "dynamically" on the compiled module like the paper's SASS trace.
* The paper's OWN numbers ship as `repro/core/calibration/ampere_a100.json`;
  unit tests (`tests/test_census_and_perfmodel.py`) check its internal
  consistency relations (SASS expansion x per-SASS cycles == WMMA cycles;
  dependent >= independent CPI; >=3-chain convergence) — all pass.

## §Dry-run

Every (architecture x shape) cell — 34 runnable cells per DESIGN.md's
long_500k policy — is compiled for BOTH production meshes with full
sharding: 68 baseline compilations and 68 with the beyond-paper optimization
plan, all succeeding (`python -m repro.launch.dryrun --all [--opt]`).
Artifacts: results/dryrun/*.json with memory_analysis, cost_analysis, the
instruction census, itemized top collectives, and sharding-sanitation logs.

Compile health: all cells lower+compile in 1.4-60s on one CPU core; scanned
layer stacks keep the HLO small enough that the 512-way SPMD partition of a
60-layer 236B-parameter MoE compiles in ~20s.

{dryrun_table}

Memory notes: `temp+args` is the modelled per-device HBM watermark.  Cells
above 16 GiB in the BASELINE are exactly the pathological shardings the
§Perf pass attacks (yi-34b/llava train: attention-weight replication from
56 heads vs 16-way TP; deepseek train: EP gathers; all reduced by the
optimization plan, e.g. yi-34b train 20.2 -> 15.0 GiB).  The remaining
over-budget cell (deepseek-v2 train at 24.7 GiB modelled) is a known
limitation documented in §Perf iteration D3.

## §Roofline

Three terms per cell, from the compiled artifact (per device):

    compute_s    = census FLOPs / 197e12        (trip-count-aware census;
                                                 XLA's cost_analysis counts
                                                 loop bodies ONCE and is kept
                                                 in the JSON for reference)
    memory_s     = analytic HBM bytes / 819e9   (weights+optimizer+activation
                                                 checkpoints+caches+logits; the
                                                 census op-boundary bytes are
                                                 reported as an upper bound)
    collective_s = TPU-adjusted wire bytes / (4 x 50e9)
                   (ring (n-1)/n factors per op; f32 collectives on values
                    that are bf16 in the source program are halved — XLA:CPU
                    legalizes bf16 dots to f32, which on the TPU target they
                    are not; raw numbers retained in the JSON)

`useful` = MODEL_FLOPS / census FLOPs where MODEL_FLOPS = 6·N_active·D
(train) or 2·N_active·D (serve); it exposes remat recompute (~1.33x),
attention quadratic terms, head-padding waste and dispatch overheads.
`roofline%` = (MODEL_FLOPS-ideal time) / max(term) — the dry-run MFU
analogue.  For decode cells this metric is intentionally brutal (one token's
FLOPs against the whole machine); the bottleneck column is the informative
part there: a healthy decode is MEMORY-bound (cache+weight streaming), and
the §Perf pass moves the broken cells from collective- to memory-bound.

### Baseline (paper-faithful sharding plan)

{roofline_baseline}

### Optimized (beyond-paper plan: --opt)

{roofline_opt}

### Baseline vs optimized (single-pod summary)

{opt_compare}

Reading the table:
* Dense-TP archs whose heads divide 16 (internlm2: 48H) hit ~65% of
  roofline at train out of the box — the framework's sharding plan is sound;
  the interesting cells are the ones that DON'T divide.
* Multi-pod rows halve roofline% by construction: the global batch is fixed
  (weak scaling), so per-device MODEL_FLOPS halves while activation
  collectives stay constant.  Cross-pod gradient traffic is the term the
  int8 error-feedback compressor (distributed/compression.py) addresses.
* rwkv6/hymba cells price the paper's core point: their census op mix is
  dominated by NON-matmul VPU chains (the wkv/ssm recurrences), where
  per-instruction latency tables — not peak FLOPs — decide the model.
  useful>1 for rwkv6 decode (1.09) flags that 2·N·D under-counts a
  recurrence's real work — exactly the class of model error the paper's
  tables exist to correct.

## §Perf — hillclimbing log

Method: per the task spec — three cells (worst roofline fraction, most
collective-bound, most latency/paper-representative), iterated as
hypothesis -> change -> before/after -> confirmed/refuted.  The optimization
plan is OFF by default (`ModelCfg` flags), so the paper-faithful baseline
and the beyond-paper plan are both always reproducible; numerics of every
optimization were verified exact (logit max-err 0.0) before adoption.

### Cell 1: yi-34b x train_4k (worst big-model roofline; TP-pathological)

* **Baseline**: compute 9.05s / memory 0.42s / collective 6.61s (adj);
  compute-bound; roofline 47.4%; census 1.78e15 FLOPs/dev vs 8.5e14 ideal.
* **Iteration Y1 — head padding.**  Hypothesis: 56 q-heads % 16 != 0 makes
  the sanitizer replicate all attention weights over the model axis ->
  replicated attention compute (x16 on those einsums) + cross-shard weight
  grads.  Change: `head_pad_multiple=16` (64 padded heads, exact
  original-GQA kv mapping, dead heads masked; bit-exact logits).
  Measured: census FLOPs 1.78e15 -> 1.32e15 (-26%), per-device args
  6.73 -> 2.73 GiB.  CONFIRMED for compute+memory; REFUTED for collectives
  (itemization showed the dominant wires are Megatron-style activation
  psums, not weight grads).
* **Iteration Y2 — activation-collective width.**  Hypothesis: the
  f32[1,4096,7168] psums (4/layer fwd+bwd x 60L x 16 accum) are bf16 on the
  TPU target (CPU dot-legalization artifact).  Change: census
  `collective_bytes_total_tpu` adjustment (tool-side; documented above) +
  `cast_params_once` so FSDP weight gathers move bf16 hoisted out of the
  accumulation loop.  Measured: adjusted collectives 2337 -> 1145 GiB/dev;
  roofline 47.4% -> 65.6%.  CONFIRMED (the cast-hoist itself is invisible
  on the CPU backend — XLA folds the converts into its f32 dots — a
  TPU-only win, recorded as such).
* **Iteration Y3 — save_attn remat policy.**  Hypothesis: keeping attention
  outputs cuts the ~33% remat recompute.  Measured: census FLOPs -2% only
  (attention internals must be recomputed for its own gradients regardless)
  at +6.7 GiB temp.  REFUTED -> reverted.  Lesson: remat savings need
  policies keyed on what the BACKWARD consumes, not on layer outputs.
* **Net: 47.4% -> 65.6% roofline, fits 16 GiB (20.2 -> 15.0).**

### Cell 2: deepseek-v2-236b x train_4k (most collective-bound)

* **Baseline**: collective 24.4s dominates (compute 5.8s); roofline 10.9%.
  Itemized: per-layer-per-microstep expert-weight FSDP gathers + the
  all-gather that re-replicates expert outputs for the combine (the 'gather'
  EP design), x59 layers x16 accum steps.
* **Iteration D1 — cast_params_once + head padding**: NO measurable change.
  REFUTED on this backend: 128 heads already divide 16, and the cast-hoist
  is folded by CPU legalization (see Y2).  Kept (TPU-relevant), not counted.
* **Iteration D2 — sharded-EP MoE (`moe_impl="shard"`)**.  Hypothesis:
  activations are replicated over 'model', so expert outputs never need
  gathering — dispatch per shard to LOCAL experts only, combine locally,
  ONE bf16 psum of partials per layer; weight gathers become explicit
  `jax.lax.all_gather` on bf16 values under `jax.shard_map`.
  Napkin: AG 2x~262 MiB/layer/micro -> one 80 MiB psum (+grads RS).
  Measured: raw collectives 6100 -> 3715 GiB/dev (-39%), step collective
  24.4 -> 17.9s, roofline 10.9% -> 14.9%; prefill collective 3.32 -> 1.41s.
  Numerics exact vs the dense path (max err 3.4e-8).  CONFIRMED.
* **Iteration D3 — optimization_barrier'd bf16 weight gathers**: no change
  measured — the f32 gathers that remain are regenerated inside the remat'd
  backward where CPU legalization again pins f32.  REFUTED-on-CPU and
  documented; on TPU the explicit bf16 gathers stand (estimated additional
  ~1.9x on the weight-gather component).  Remaining known limitation: ZeRO-3
  expert-weight streaming x accum is the irreducible term of this design
  point; the production fix is token-sharded EP (a2a over an expert axis),
  sketched in DESIGN.md as future work.
* **Net: 10.9% -> 14.9% roofline at train; prefill 2.4x less collective.**

### Cell 3: gemma3-1b x decode_32k (latency-critical; paper-representative —
matmuls vanish at one token/step, so per-instruction and per-collective
latencies dominate, the paper's exact regime)

* **Baseline**: 66 ms/token modelled, COLLECTIVE-bound (12.35 GiB wire per
  single token!); compute 0.2ms.  SPMD warnings showed "involuntary full
  rematerialization" on every cache update.
* **Iteration G1 — scatter cache updates.**  Hypothesis: the vmapped
  dynamic-update-slice on the (batch, seq)-sharded KV cache forces the
  partitioner to replicate-and-reshard the whole 32k cache each step;
  a scatter with explicit (row, slot) indices partitions shard-locally.
  Change: `scatter_cache_update=True` (+`mode="drop"`), decode-equivalence
  verified exact.  Measured: wire 12.35 -> 0.21 GiB (58x), step
  66 -> 1.1 ms/token, bottleneck flips to MEMORY — the correct regime for
  decode.  CONFIRMED.  Same change: yi-34b decode 0.62s -> 0.021s (30x),
  llava decode likewise.
* **Iteration G2 — bandwidth accounting.**  With the collective fixed, the
  step models at ~1.2 ms/token ~= (bf16 weights/16 + KV read)/819GB/s with
  ~80% of bytes in weight streaming at batch 128: the cell is within ~2x of
  the decode bandwidth roofline; the remaining gap is the (small) residual
  collective.  Further levers (ring-latency hiding, weight-quantized
  decode) are noted, not implemented.
* **Multi-pod decode caveat** (from the full table): decoding ACROSS pods
  pays cross-pod wire for zero model benefit — production serving should
  replicate per pod (DP serving), which the engine supports by
  construction; recorded as a deployment rule rather than a code change.

### Beyond-paper optimizations applied fleet-wide (--opt)

head padding (hymba 25->32: prefill 2.2% -> 21.7%, train 9.0% -> 17.9%),
sharded-EP MoE (olmoe train 12.0% -> 33.5%, prefill 13.7% -> 39.3%),
scatter cache updates (all decode cells -> memory-bound), cast-once bf16
weight gathers (TPU-only), prefill last-token unembed (seamless prefill
temp 63.8 -> 2.2 GiB), encoder remat + vocab sharding (seamless train 55.9 -> 4.7 GiB, roofline 12.3% -> 20.4%),
vocab padding to /128 (seamless/hymba logits shard; was replicating
15.6 GiB logits per device).

### Stopping criterion

Per the method: three consecutive <5% iterations on the dominant term.
Y3/D3 and two accounting-only iterations closed the three cells; the
remaining largest known lever (token-sharded a2a EP for deepseek) is
designed but unimplemented, documented above.
"""


def _fmt_row(d, r):
    frac = roofline_fraction(r)
    return (f"| {r.arch} | {r.cell} | {r.mesh} | {r.compute_s:.3f} | "
            f"{r.memory_s:.3f} | {r.collective_s:.3f} | {r.bottleneck} | "
            f"{r.useful_ratio:.3f} | {100*frac:.2f}% |")


TBL_HDR = ("| arch | cell | mesh | compute_s | memory_s | collective_s | "
           "bottleneck | useful | roofline% |\n"
           "|---|---|---|---|---|---|---|---|---|")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results-dir", type=Path, default=RESULTS)
    ap.add_argument("--out", type=Path, default=ROOT / "EXPERIMENTS.md")
    args = ap.parse_args(argv)

    base_rows, opt_rows, dry_rows = [], [], []
    pairs = {}
    for p in sorted(args.results_dir.glob("*.json")):
        d = json.loads(p.read_text())
        r = from_dryrun(d)
        if "__opt" in d["mesh"]:
            opt_rows.append(_fmt_row(d, r))
            pairs.setdefault((d["arch"], d["cell"],
                              d["mesh"].replace("__opt", "")), [None, None])[1] = (d, r)
        else:
            base_rows.append(_fmt_row(d, r))
            pairs.setdefault((d["arch"], d["cell"], d["mesh"]),
                             [None, None])[0] = (d, r)
            m = d["memory_analysis"]
            dry_rows.append(
                f"| {d['arch']} | {d['cell']} | {d['mesh']} | "
                f"{m.get('argument_size_in_bytes', 0)/2**30:.2f} | "
                f"{m.get('temp_size_in_bytes', 0)/2**30:.2f} | "
                f"{d['compile_s']:.1f} | {d['accum_steps']} |")

    comp = ["| arch | cell | baseline RL% | opt RL% | baseline coll_s | "
            "opt coll_s | bottleneck base -> opt |",
            "|---|---|---|---|---|---|---|"]
    for (arch, cell, mesh), (b, o) in sorted(pairs.items()):
        if b is None or o is None or mesh != "pod16x16":
            continue
        (db, rb), (do, ro) = b, o
        comp.append(
            f"| {arch} | {cell} | {100*roofline_fraction(rb):.2f}% | "
            f"{100*roofline_fraction(ro):.2f}% | {rb.collective_s:.3f} | "
            f"{ro.collective_s:.3f} | {rb.bottleneck} -> {ro.bottleneck} |")

    dry_tbl = ("| arch | cell | mesh | args GiB | temp GiB | compile s | "
               "accum |\n|---|---|---|---|---|---|---|\n"
               + "\n".join(dry_rows))
    text = HEADER.format(
        dryrun_table=dry_tbl,
        roofline_baseline=TBL_HDR + "\n" + "\n".join(base_rows),
        roofline_opt=TBL_HDR + "\n" + "\n".join(opt_rows),
        opt_compare="\n".join(comp),
    )
    args.out.write_text(text)
    print(f"wrote {args.out.name}: {len(base_rows)} baseline rows, "
          f"{len(opt_rows)} opt rows")


if __name__ == "__main__":
    main()
