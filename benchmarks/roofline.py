"""§Roofline: the three-term roofline table over every dry-run artifact.

Reads results/dryrun/*.json (produced by `python -m repro.launch.dryrun
--all`), derives compute/memory/collective seconds per (arch x cell x mesh),
identifies the dominant term and the MODEL_FLOPS/HLO_FLOPs useful ratio, and
prints the table §Roofline of EXPERIMENTS.md is generated from.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.core.perfmodel.roofline import from_dryrun, roofline_fraction

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def load_all(mesh_filter: str | None = None):
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh_filter and d["mesh"] != mesh_filter:
            continue
        rows.append(d)
    return rows


def render(rows, file=sys.stdout):
    hdr = (f"{'arch':22s} {'cell':12s} {'mesh':11s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'bottleneck':>11s} {'useful':>7s} {'roofline%':>9s}")
    print(hdr, file=file)
    print("-" * len(hdr), file=file)
    out = []
    for d in rows:
        r = from_dryrun(d)
        frac = roofline_fraction(r)
        out.append((r, frac))
        print(f"{r.arch:22s} {r.cell:12s} {r.mesh:11s} "
              f"{r.compute_s:10.4f} {r.memory_s:10.4f} "
              f"{r.collective_s:10.4f} {r.bottleneck:>11s} "
              f"{r.useful_ratio:7.3f} {100*frac:8.2f}%", file=file)
    return out


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else None
    rows = load_all(mesh)
    if not rows:
        print("no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    out = render(rows)
    # summary: the three most interesting cells for §Perf
    single = [(r, f) for r, f in out if r.mesh == "pod16x16"]
    if single:
        worst = min(single, key=lambda rf: rf[1])
        coll = max(single, key=lambda rf: rf[0].collective_s
                   / max(rf[0].step_s, 1e-12))
        print("\nworst roofline fraction :",
              worst[0].arch, worst[0].cell, f"{100*worst[1]:.2f}%")
        print("most collective-bound   :",
              coll[0].arch, coll[0].cell,
              f"{coll[0].collective_s:.3f}s of {coll[0].step_s:.3f}s")


if __name__ == "__main__":
    main()
