"""§Roofline CLI: the three-term roofline table over dry-run artifacts,
plus the measured roofline-calibration peaks from the campaign runner.

  python benchmarks/roofline.py [--mesh pod16x16] [--results-dir DIR]
      render compute/memory/collective seconds per (arch x cell x mesh)
      from results/dryrun/*.json and flag the §Perf focus cells.

  python benchmarks/roofline.py --calibration
      show the achieved peaks measured by the `roofline_calibration`
      campaign next to the hardware-spec peaks they anchor.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.perfmodel.roofline import from_dryrun, roofline_fraction  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results" / "dryrun"


def load_all(mesh_filter: str | None = None, results_dir: Path = RESULTS):
    rows = []
    for p in sorted(Path(results_dir).glob("*.json")):
        d = json.loads(p.read_text())
        if mesh_filter and d["mesh"] != mesh_filter:
            continue
        rows.append(d)
    return rows


def render(rows, file=sys.stdout):
    hdr = (f"{'arch':22s} {'cell':12s} {'mesh':11s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'bottleneck':>11s} {'useful':>7s} {'roofline%':>9s}")
    print(hdr, file=file)
    print("-" * len(hdr), file=file)
    out = []
    for d in rows:
        r = from_dryrun(d)
        frac = roofline_fraction(r)
        out.append((r, frac))
        print(f"{r.arch:22s} {r.cell:12s} {r.mesh:11s} "
              f"{r.compute_s:10.4f} {r.memory_s:10.4f} "
              f"{r.collective_s:10.4f} {r.bottleneck:>11s} "
              f"{r.useful_ratio:7.3f} {100*frac:8.2f}%", file=file)
    return out


def focus_cells(out, file=sys.stdout) -> None:
    """The three most interesting single-pod cells for §Perf."""
    single = [(r, f) for r, f in out if r.mesh == "pod16x16"]
    if not single:
        return
    worst = min(single, key=lambda rf: rf[1])
    coll = max(single, key=lambda rf: rf[0].collective_s
               / max(rf[0].step_s, 1e-12))
    print("\nworst roofline fraction :",
          worst[0].arch, worst[0].cell, f"{100*worst[1]:.2f}%", file=file)
    print("most collective-bound   :",
          coll[0].arch, coll[0].cell,
          f"{coll[0].collective_s:.3f}s of {coll[0].step_s:.3f}s", file=file)


def show_calibration(campaign_dir: Path) -> int:
    """Measured achieved peaks vs the hardware-spec peaks they anchor."""
    from repro.core.campaign.results import load_results_dir
    from repro.core.perfmodel.hardware import TPU_V5E

    docs = load_results_dir(campaign_dir, ("roofline_calibration",))
    doc = docs.get("roofline_calibration")
    if not doc:
        print("no roofline_calibration results; run "
              "`python -m repro.core.campaign run roofline_calibration`")
        return 1
    spec = {"mxu_peak_tflops": TPU_V5E.peak_flops_bf16 / 1e12,
            "hbm_stream_gbs": TPU_V5E.hbm_bandwidth / 1e9,
            "dispatch_overhead_us": None}
    print(f"backend: {doc.get('backend', '?')}   "
          f"(spec column: {TPU_V5E.name})")
    print(f"{'term':24s} {'measured':>12s} {'unit':>8s} {'spec':>10s}")
    for key in sorted(doc["cells"]):
        rec = doc["cells"][key]
        if rec.get("status") != "ok":
            continue
        term = rec["params"]["term"]
        ref = spec.get(term)
        print(f"{term:24s} {rec['metrics']['value']:12.3f} "
              f"{rec['metrics']['unit']:>8s} "
              f"{ref if ref is not None else '-':>10}")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("mesh", nargs="?", default=None,
                   help="optional mesh filter, e.g. pod16x16")
    p.add_argument("--results-dir", type=Path, default=RESULTS,
                   help="dry-run artifact directory")
    p.add_argument("--campaign-dir", type=Path,
                   default=ROOT / "results" / "campaign")
    p.add_argument("--calibration", action="store_true",
                   help="show measured roofline-calibration peaks instead")
    args = p.parse_args(argv)

    if args.calibration:
        return show_calibration(args.campaign_dir)
    rows = load_all(args.mesh, args.results_dir)
    if not rows:
        print("no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all` first")
        return 0
    focus_cells(render(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
