"""Serving-throughput benchmark — the perf-trajectory recorder.

Plays one deterministic mixed-length trace through BOTH engines (slot and
paged), each on its legacy blocking path (``fused=False``) and on the
fused decode hot path (on-device sampling, donated caches, pipelined
steps), then replays the telemetry acceptance scenarios (drift ->
recalibration, SLO overload) on the sim harness, and emits one
schema-versioned ``BENCH_<n>.json`` so the repo's serving-performance
trajectory is recorded per change instead of living in commit messages:

  python benchmarks/bench_serve.py --quick \\
      --out benchmarks/trajectory/BENCH_7.json

``<n>`` is the PR index the snapshot was taken at; one file per PR that
moves serving performance lands in ``benchmarks/trajectory/`` (see
benchmarks/README.md for the convention).

Fields per engine: baseline/fused tok/s + speedup, steps, host syncs per
step, resident KV bytes, ``identical_tokens`` (greedy ids must match
byte-for-byte — the hot path is an implementation detail, not a
semantics change), and the cost model's predicted per-step HBM / host-
transfer byte savings.  The ``telemetry`` block records the drift
scenario (events fired, error before/after the 10% gate) and the
overload scenario (p99 vs SLO target vs the ungated baseline).  The
``longctx`` block (schema v3) records the split-KV flash-decoding
scenario: tuned vs unsplit lane-utilization proxy tok/s at the longest
swept context, the tuned split factor, and token equality vs the
oracle.  The ``cluster`` block (schema v4) records the traffic-scaling
scenario at one and at several replicas: round-robin vs cost-aware
placement tok/s, p50/p99 latency, shed rate, reroutes, token
conservation, and the cost-model-chosen topology.  The ``sharded``
block (schema v5) records the sharded intra-replica decode scenario on
a forced multi-device CPU host: per (data, model) factorization, token
byte-identity vs the single-device engine, the one-sync and donation
invariants, and measured vs cost-model-predicted step time.  The
``chaos`` block (schema v6) records one recovery drill per fault kind
(crash, hang, corrupt, crash-loop) on a 2-replica SimClock cluster:
detection-to-rejoin latency, requests recovered/abandoned, and the
recovery invariants (byte-identical survivors, ``tokens_lost=0``,
``blocks_leaked=0``, quarantine on crash-loop).  CI runs
``--quick`` and fails (rc=1) when any engine's ``identical_tokens`` is
False, when the drift scenario does not recalibrate back under the
gate, when the token bucket misses its SLO, when the tuned split stops
beating the unsplit kernel (``longctx_ok``), when the cluster loses
tokens / single-replica byte-identity (``cluster_ok``), when any
sharded replica's tokens diverge (``sharded_ok``), or when any chaos
drill breaks a recovery invariant (``chaos_ok``).
``benchmarks/trajectory/compare.py`` then gates tok/s against the
previous committed snapshot.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

SCHEMA = "bench_serve/v6"
BENCH_ID = 10         # the PR index this snapshot records


def validate_bench_doc(doc: dict) -> dict:
    """Refuse non-bench / newer-versioned JSON loudly (the
    ``telemetry.validate_snapshot`` discipline)."""
    if not isinstance(doc, dict):
        raise ValueError("bench document must be a JSON object")
    schema = doc.get("schema", "")
    if not schema.startswith("bench_serve/"):
        raise ValueError(f"not a bench_serve document "
                         f"(schema={schema!r}, expected {SCHEMA!r})")
    version = int(schema.rsplit("/v", 1)[-1] or 0)
    if version > int(SCHEMA.rsplit("/v", 1)[-1]):
        raise ValueError(
            f"bench_serve schema v{version} is newer than supported "
            f"{SCHEMA!r}; upgrade the repo to read this file")
    blocks = ("engines",) + (("cluster",) if version >= 4 else ()) \
        + (("sharded",) if version >= 5 else ()) \
        + (("chaos",) if version >= 6 else ())
    for block in blocks:
        if block not in doc:
            raise ValueError(f"bench_serve document is missing its "
                             f"{block!r} block")
    return doc


def run(quick: bool) -> dict:
    from repro.core.campaign.registry import (run_decode_hotpath_cell,
                                              run_decode_longctx_cell,
                                              run_traffic_scaling_cell)
    from repro.serve.telemetry.scenarios import (run_drift_scenario,
                                                 run_overload_scenario)
    doc = {"schema": SCHEMA, "bench_id": BENCH_ID, "quick": bool(quick),
           "engines": {}}
    for engine in ("slot", "paged"):
        doc["engines"][engine] = run_decode_hotpath_cell(
            {"engine": engine}, quick=quick)
    drift = run_drift_scenario()
    drift.pop("events", None)
    overload = run_overload_scenario()
    doc["telemetry"] = {"drift": drift, "overload": overload}
    # split-KV flash-decoding at the longest swept context (v3): the
    # cell measures its tuned pick against the unsplit kernel, so one
    # cell carries the whole tuned-vs-unsplit scenario
    lc = run_decode_longctx_cell(
        {"ctx": 512 if quick else 4096, "num_splits": 4}, quick=quick)
    doc["longctx"] = lc
    doc["longctx_ok"] = bool(lc["identical_tokens"]
                             and lc["tuned_speedup"] > 1.0)
    # cluster traffic-scaling at 2x offered load (v4): one replica must
    # be byte-identical to the bare engine, several replicas must
    # conserve every admitted token under preemption + re-route; the
    # full run additionally demands cost-aware placement beat
    # round-robin on the skewed trace (quick traces are too short for a
    # robust ordering, so CI gates correctness and the committed
    # full-mode snapshot carries the perf evidence)
    doc["cluster"] = {}
    for r in (1, 2):
        doc["cluster"][f"r{r}"] = run_traffic_scaling_cell(
            {"replicas": r, "load": 2.0}, quick=quick)
    cl_ok = all(m["identical_tokens"] and m["rr_conserved"]
                and m["ca_conserved"] and m["rr_shed_rate"] <= 0.5
                and m["ca_shed_rate"] <= 0.5
                for m in doc["cluster"].values())
    if not quick:
        m = doc["cluster"]["r2"]
        cl_ok = cl_ok and m["speedup_tok_s"] > 1.0 and m["p99_ratio"] > 1.0
    doc["cluster_ok"] = bool(cl_ok)
    # sharded intra-replica decode (v5): a paged replica on each
    # (data, model) mesh of a forced-8-device CPU host must be
    # byte-identical to the single-device engine with the one-sync and
    # donation invariants intact; the measured-vs-predicted step time
    # per factorization rides along for the trajectory record
    from repro.core.campaign.registry import run_sharded_decode_cell
    doc["sharded"] = run_sharded_decode_cell(
        {"shapes": "1x1,2x1,1x2,2x2"}, quick=quick)
    doc["sharded_ok"] = bool(doc["sharded"]["identical_all"])
    # chaos drills (v6): every fault kind injected into a 2-replica
    # cluster under SimClock must recover crash-consistently — fault-
    # untouched requests byte-identical to the fault-free twin, zero
    # lost tokens, zero leaked blocks, drained router, and the crash-
    # looping replica quarantined by the restart budget
    from repro.core.campaign.registry import run_chaos_serving_cell
    doc["chaos"] = {}
    for fault in ("crash", "hang", "corrupt", "crashloop"):
        doc["chaos"][fault] = run_chaos_serving_cell(
            {"fault": fault, "replicas": 2}, quick=quick)
    doc["chaos_ok"] = bool(all(m["ok"] for m in doc["chaos"].values()))
    doc["identical_tokens"] = bool(
        all(m["identical_tokens"] for m in doc["engines"].values())
        and lc["identical_tokens"])
    doc["telemetry_ok"] = (
        drift["n_events"] == 1
        and drift["post_error"] is not None
        and drift["post_error"] < drift["gate"]
        and drift["tokens_ok"]
        and overload["slo_held"] and overload["tokens_ok"])
    return doc


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--quick", action="store_true",
                   help="short trace (the CI smoke mode)")
    p.add_argument("--out",
                   default=f"results/bench/BENCH_{BENCH_ID}.json",
                   help="artifact path (schema-versioned JSON)")
    args = p.parse_args(argv)

    doc = run(quick=args.quick)
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=1, sort_keys=True))
    for engine, m in doc["engines"].items():
        print(f"{engine}: baseline={m['baseline_tok_per_s']:.1f} tok/s "
              f"fused={m['fused_tok_per_s']:.1f} tok/s "
              f"(x{m['speedup']:.2f}) "
              f"syncs/step {m['baseline_syncs_per_step']:.2f} -> "
              f"{m['fused_syncs_per_step']:.2f}  "
              f"kv_bytes={m['fused_kv_bytes']}  "
              f"identical_tokens={m['identical_tokens']}")
    d, o = doc["telemetry"]["drift"], doc["telemetry"]["overload"]
    print(f"telemetry: drift events={d['n_events']} "
          f"err {d['pre_error']:.2f} -> {d['post_error']:.3f} "
          f"(gate {d['gate']:.2f})  "
          f"overload p99={o['p99_s']:.2f}s target={o['target_p99_s']:.2f}s "
          f"baseline={o['baseline_p99_s']:.2f}s deferred={o['deferred']}")
    lc = doc["longctx"]
    print(f"longctx: ctx={lc['ctx']} tuned_splits={lc['tuned_splits']} "
          f"unsplit={lc['unsplit_proxy_tok_s']:.1f} tok/s "
          f"tuned={lc['tuned_proxy_tok_s']:.1f} tok/s "
          f"(x{lc['tuned_speedup']:.2f}) "
          f"identical_tokens={lc['identical_tokens']}")
    for tag, m in doc["cluster"].items():
        print(f"cluster/{tag}: rr={m['rr_tok_per_s']:.1f} tok/s "
              f"ca={m['ca_tok_per_s']:.1f} tok/s "
              f"(x{m['speedup_tok_s']:.2f}) "
              f"p99 {m['rr_p99_s']:.2f}s -> {m['ca_p99_s']:.2f}s  "
              f"shed={m['ca_shed_rate']:.2f} reroutes={m['ca_reroutes']} "
              f"identical_tokens={m['identical_tokens']} "
              f"conserved={m['rr_conserved'] and m['ca_conserved']}")
    sh = doc["sharded"]
    for key in sorted(k[:-7] for k in sh if k.endswith("_step_s")
                      and not k.endswith("_pred_step_s")
                      and k != "ref_step_s"):
        print(f"sharded/{key}: step={sh[f'{key}_step_s'] * 1e3:.1f}ms "
              f"(ref {sh['ref_step_s'] * 1e3:.1f}ms, "
              f"pred {sh[f'{key}_pred_step_s'] * 1e6:.2f}us) "
              f"identical_tokens={sh[f'{key}_identical']} "
              f"sync_ok={sh[f'{key}_sync_ok']} "
              f"donated={sh[f'{key}_donated']}")
    for fault, m in doc["chaos"].items():
        print(f"chaos/{fault}: failures={m['failures']} "
              f"recovery_s={m['recovery_latency_s']:.2f} "
              f"survivors_identical={m['survivors_identical']} "
              f"tokens_lost={m['tokens_lost']} "
              f"blocks_leaked={m['blocks_leaked']} "
              f"quarantined={m['quarantined']} ok={m['ok']}")
    print(f"wrote {out}")
    return 0 if (doc["identical_tokens"] and doc["telemetry_ok"]
                 and doc["longctx_ok"] and doc["cluster_ok"]
                 and doc["sharded_ok"] and doc["chaos_ok"]) else 1


if __name__ == "__main__":
    raise SystemExit(main())
