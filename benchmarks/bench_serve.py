"""Serving-throughput benchmark — the perf-trajectory recorder.

Plays one deterministic mixed-length trace through BOTH engines (slot and
paged), each on its legacy blocking path (``fused=False``) and on the
fused decode hot path (on-device sampling, donated caches, pipelined
steps), and emits a schema-versioned ``BENCH_5.json`` so the repo's
serving-performance trajectory is recorded per change instead of living
in commit messages:

  python benchmarks/bench_serve.py --quick --out results/bench/BENCH_5.json

Fields per engine: baseline/fused tok/s + speedup, steps, host syncs per
step, resident KV bytes, ``identical_tokens`` (greedy ids must match
byte-for-byte — the hot path is an implementation detail, not a
semantics change), and the cost model's predicted per-step HBM / host-
transfer byte savings.  CI runs ``--quick`` and fails when any engine's
``identical_tokens`` is False (rc=1).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

SCHEMA = "bench_serve/v1"
BENCH_ID = 5          # the PR index this artifact started recording at


def run(quick: bool) -> dict:
    from repro.core.campaign.registry import run_decode_hotpath_cell
    doc = {"schema": SCHEMA, "bench_id": BENCH_ID, "quick": bool(quick),
           "engines": {}}
    for engine in ("slot", "paged"):
        doc["engines"][engine] = run_decode_hotpath_cell(
            {"engine": engine}, quick=quick)
    doc["identical_tokens"] = all(
        m["identical_tokens"] for m in doc["engines"].values())
    return doc


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--quick", action="store_true",
                   help="short trace (the CI smoke mode)")
    p.add_argument("--out", default="results/bench/BENCH_5.json",
                   help="artifact path (schema-versioned JSON)")
    args = p.parse_args(argv)

    doc = run(quick=args.quick)
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=1, sort_keys=True))
    for engine, m in doc["engines"].items():
        print(f"{engine}: baseline={m['baseline_tok_per_s']:.1f} tok/s "
              f"fused={m['fused_tok_per_s']:.1f} tok/s "
              f"(x{m['speedup']:.2f}) "
              f"syncs/step {m['baseline_syncs_per_step']:.2f} -> "
              f"{m['fused_syncs_per_step']:.2f}  "
              f"kv_bytes={m['fused_kv_bytes']}  "
              f"identical_tokens={m['identical_tokens']}")
    print(f"wrote {out}")
    return 0 if doc["identical_tokens"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
