"""One benchmark per paper table, emitting `name,us_per_call,derived` CSV.

Table I   -> chain-length CPI convergence (first-op overhead amortization)
Table II  -> dependent vs independent per-op latency
Table III -> matrix-unit (MXU) latency/throughput per dtype x shape
Table IV  -> memory-hierarchy pointer-chase latencies
Table V   -> ISA mapping: StableHLO -> optimized-HLO expansion per op class

On this CPU container the numbers characterize the host (the methodology is
the deliverable; the TPU numbers come from running the same suite on real
hardware).  The A100 columns from the paper ship in
repro/core/calibration/ampere_a100.json and are cross-checked by unit tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.microbench import harness, memory, mxu
from repro.core.isa import hlo_census as hc

ROWS = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}")


def table1_chain_convergence():
    r = harness.run_chain(harness.OPS["add"], "add",
                          lengths=(1, 2, 3, 4, 16, 64))
    for k in sorted(r.cpi_curve):
        emit(f"table1/add.f32/K={k}", r.times_s[r.lengths.index(k)] * 1e6,
             f"t(K)/(K*t_inf)={r.cpi_curve[k]:.2f}")


def table2_dep_vs_indep():
    ops = ["add", "mul", "fma", "div", "rsqrt", "exp", "tanh"]
    for dt in ("float32", "int32"):
        for op in ops:
            if dt == "int32" and op in harness.FLOAT_ONLY:
                continue
            for dep in (True, False):
                r = harness.run_chain(harness.OPS[op], op, jnp.dtype(dt),
                                      lengths=(4, 16, 64), dependent=dep)
                tag = "dep" if dep else "ind"
                emit(f"table2/{op}.{dt}.{tag}", r.per_op_s * 1e6,
                     f"overhead_us={r.overhead_s*1e6:.2f}")


def table3_mxu():
    for dt in ("bfloat16", "float32", "int8"):
        real_dt = dt if dt != "int8" else "bfloat16"  # CPU backend: no s8 dot
        for shape in ((128, 128, 128), (256, 256, 256), (512, 512, 128)):
            dep = shape[0] == shape[2]   # a dependent chain needs square A
            r = mxu.run_mxu(real_dt, shape, dependent=dep, lengths=(1, 2, 4))
            tag = "dep" if dep else "ind"
            emit(f"table3/{dt}.m{shape[0]}n{shape[1]}k{shape[2]}.{tag}",
                 r.per_op_s * 1e6, f"tflops={r.tflops:.3f}")


def table4_memory():
    for size in (16 * 2**10, 256 * 2**10, 4 * 2**20, 64 * 2**20):
        r = memory.run_chase(size, hop_counts=(256, 1024, 4096))
        emit(f"table4/chase_{size//1024}KiB", r.per_hop_s * 1e6,
             f"per_hop_ns={r.per_hop_s*1e9:.1f}")
    bw = memory.streaming_bandwidth()
    emit("table4/streaming_read", 0.0, f"GBps={bw/1e9:.2f}")


def table5_isa_mapping():
    """StableHLO -> optimized HLO per op class (the PTX->SASS table)."""
    cases = {
        "add.f32": lambda x: x + 1.0,
        "mul.f32": lambda x: x * 1.5,
        "fma.f32": lambda x: x * 1.5 + 2.0,
        "div.f32": lambda x: x / 1.5,
        "rsqrt.f32": lambda x: jax.lax.rsqrt(jnp.abs(x) + 1e-3),
        "exp.f32": lambda x: jnp.exp(x * 1e-3),
        "tanh.f32": lambda x: jnp.tanh(x),
        "softmax.f32": lambda x: jax.nn.softmax(x, axis=-1),
        "matmul.f32": lambda x: x @ x.T,
        "reduce.f32": lambda x: jnp.sum(x, axis=-1),
        "gather": lambda x: x[jnp.arange(8) % x.shape[0]],
        "scan8": lambda x: jax.lax.scan(lambda c, _: (c * 1.01, ()), x,
                                        None, length=8)[0],
    }
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    for name, fn in cases.items():
        lowered = jax.jit(fn).lower(x)
        compiled = lowered.compile()
        m = hc.op_mapping_table(lowered.as_text(), compiled.as_text())
        c = hc.census(compiled.as_text())
        top = ",".join(f"{k}x{int(v)}" for k, v in
                       list(c["op_histogram"].items())[:3])
        emit(f"table5/{name}", 0.0,
             f"src_ops={m['n_source_ops']};opt_ops={m['n_optimized_ops']};"
             f"top={top};flops={int(c['flops'])}")


def run_all():
    table1_chain_convergence()
    table2_dep_vs_indep()
    table3_mxu()
    table4_memory()
    table5_isa_mapping()
    return ROWS
