"""One benchmark per paper table — a thin CLI over the campaign subsystem.

Table I   -> chain-length CPI convergence      (campaign: alu_chain)
Table II  -> dependent vs independent latency  (campaign: alu_chain)
Table III -> matrix-unit latency/throughput    (campaign: mxu_shapes)
Table IV  -> memory-hierarchy pointer chase    (campaign: memory_chase)
Table V   -> StableHLO -> optimized-HLO map    (campaign: isa_mapping)

Measurement lives in `repro.core.campaign`; this script either runs the
campaigns (resumable) and prints the tables, or — with `--from-results` —
REGENERATES the tables from existing schema-versioned result files alone,
with no re-measurement:

  python benchmarks/paper_tables.py --from-results results/campaign/alu_chain.json

On this CPU container the numbers characterize the host (the methodology is
the deliverable; TPU numbers come from the same campaigns on real hardware).
The paper's own A100 columns ship in repro/core/calibration/ampere_a100.json
and are cross-checked by unit tests.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.campaign import report, runner  # noqa: E402
from repro.core.campaign.results import load_results  # noqa: E402

# paper-table order (Tables I/II share the alu_chain campaign)
TABLE_EXPERIMENTS = ("alu_chain", "mxu_shapes", "memory_chase", "isa_mapping")


def run_all(quick: bool = True,
            out_dir: str = str(runner.DEFAULT_RESULTS_DIR)):
    """Run every paper-table campaign (resuming completed cells) and print
    the tables; kept for `benchmarks.run` and interactive use."""
    rows = []
    for name in TABLE_EXPERIMENTS:
        rep = runner.run(name, out_dir=out_dir, quick=quick)
        print(f"# {rep.summary()}", file=sys.stderr)
        doc = load_results(rep.path)
        rows.extend(report.table_for(doc))
    report.render_rows(rows)
    return rows


def from_results(paths) -> None:
    """Regenerate paper tables from result files alone (no measurement)."""
    report.render_result_files(paths)


def prediction_error(calibration: str) -> None:
    """The cost-model validation table: predict a calibration's rows back
    through the layer stack (``repro.core.costmodel``) and print errors."""
    from repro.core.costmodel.calibration import load_calibration
    cal = load_calibration(calibration)
    report.render_rows(report.prediction_error_table(cal, name=cal.name))


def main(argv=None) -> int:
    import signal
    if hasattr(signal, "SIGPIPE"):   # die quietly when piped into `grep -q`
        signal.signal(signal.SIGPIPE, signal.SIG_DFL)
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--from-results", nargs="+", metavar="RESULT_JSON",
                   help="regenerate tables from these campaign result files "
                        "without running anything")
    p.add_argument("--prediction-error", metavar="CALIBRATION",
                   help="print the cost-model validation table for this "
                        "calibration (shipped name, JSON path, or campaign "
                        "results dir) instead of running campaigns")
    p.add_argument("--quick", action="store_true", default=True,
                   help="reduced grids (default on; use --full to override)")
    p.add_argument("--full", dest="quick", action="store_false")
    p.add_argument("--results-dir", default=str(runner.DEFAULT_RESULTS_DIR))
    args = p.parse_args(argv)

    if args.prediction_error:
        prediction_error(args.prediction_error)
        return 0
    if args.from_results:
        from_results(args.from_results)
        return 0
    run_all(quick=args.quick, out_dir=args.results_dir)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
