"""Benchmark driver — a thin CLI over the campaign runner.

  python benchmarks/run.py --experiment alu_chain --quick
      run (or resume) one named campaign; results land as schema-versioned
      JSON + CSV under results/campaign/ and completed cells are skipped on
      rerun.

  python benchmarks/run.py --experiment all --quick
      the full paper-table suite in CI smoke mode.

  python benchmarks/run.py
      legacy behaviour: run every campaign, print the paper tables as
      `name,us_per_call,derived` CSV, then the roofline summary over any
      existing dry-run artifacts.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

# allow `python benchmarks/run.py` from a checkout without PYTHONPATH=src
try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.campaign import registry, report, runner  # noqa: E402


def run_experiments(names, *, quick: bool, force: bool, out_dir: str,
                    verbose: bool) -> int:
    rc = 0
    for name in names:
        rep = runner.run(name, out_dir=out_dir, quick=quick, force=force,
                         progress=print if verbose else None)
        print(f"# {rep.summary()}", file=sys.stderr)
        if rep.failed:
            rc = 1
    report.render_result_files(Path(out_dir) / f"{n}.json" for n in names)
    return rc


def roofline_summary() -> None:
    """Model-level roofline over dry-run artifacts (skipped if absent)."""
    try:
        import roofline as roofline_cli
    except ImportError:
        from benchmarks import roofline as roofline_cli
    try:
        rows = roofline_cli.load_all("pod16x16")
        if rows:
            print()
            roofline_cli.render(rows)
    except Exception as e:  # noqa: BLE001  (summary is best-effort)
        print(f"roofline-summary-skipped,0.0,{e!r}"[:120])


def main(argv=None) -> int:
    import signal
    if hasattr(signal, "SIGPIPE"):   # die quietly when piped into `grep -q`
        signal.signal(signal.SIGPIPE, signal.SIG_DFL)
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--experiment", action="append", default=None,
                   help="named campaign to run (repeatable, or 'all'); "
                        f"known: {', '.join(registry.names())}")
    p.add_argument("--quick", action="store_true", default=True,
                   help="reduced grids + shorter sweeps (the default; "
                        "full sweeps take minutes per campaign)")
    p.add_argument("--full", dest="quick", action="store_false",
                   help="run the full grids instead of the quick sweeps")
    p.add_argument("--force", action="store_true",
                   help="re-measure already-completed cells")
    p.add_argument("--results-dir", default=str(runner.DEFAULT_RESULTS_DIR))
    p.add_argument("--verbose", "-v", action="store_true")
    args = p.parse_args(argv)

    if args.experiment:
        names = (registry.names() if "all" in args.experiment
                 else args.experiment)
        unknown = [n for n in names if n not in registry.REGISTRY]
        if unknown:
            p.error(f"unknown experiment(s) {', '.join(unknown)}; "
                    f"known: {', '.join(registry.names())} (or 'all')")
        return run_experiments(names, quick=args.quick, force=args.force,
                               out_dir=args.results_dir, verbose=args.verbose)

    # legacy: full paper-table suite + roofline summary
    rc = run_experiments(registry.names(), quick=args.quick, force=args.force,
                         out_dir=args.results_dir, verbose=args.verbose)
    roofline_summary()
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
