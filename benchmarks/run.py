"""Benchmark driver: one function per paper table (CSV: name,us_per_call,
derived) plus the model-level roofline summary over any existing dry-run
artifacts.  `python -m benchmarks.run`"""
from __future__ import annotations


def main() -> None:
    from benchmarks import paper_tables
    print("name,us_per_call,derived")
    paper_tables.run_all()

    # roofline summary (skipped silently if no dry-run artifacts exist)
    try:
        from benchmarks import roofline
        rows = roofline.load_all("pod16x16")
        if rows:
            print()
            roofline.render(rows)
    except Exception as e:  # noqa
        print(f"roofline-summary-skipped,0.0,{e!r}"[:120])


if __name__ == "__main__":
    main()
