"""Serving-performance trajectory gate.

Walks every ``BENCH_<n>.json`` in this directory in ``<n>`` order and
compares each snapshot's throughput scenarios against the previous
one.  A scenario regresses when its tok/s drops below ``tolerance``
times the prior value (default 0.6 — the committed snapshots come from
different machines and ``--quick`` runs, so only a collapse should
fail, not jitter).  Improvements and new scenarios never fail; a
scenario is only compared when BOTH consecutive snapshots carry it,
which is what lets the schema grow (v2 -> v3 added ``longctx``,
v3 -> v4 added ``cluster``, v4 -> v5 added ``sharded``) without
breaking the walk.

  python benchmarks/trajectory/compare.py            # gate the dir
  python benchmarks/trajectory/compare.py --tolerance 0.5

rc=0 when no scenario regressed past tolerance (or there are fewer
than two snapshots to compare); rc=1 otherwise.  CI runs this over the
*committed* trajectory only — the fresh snapshot a CI run produces
lands in an artifact, not in the comparison, so cross-machine speed
deltas cannot flake the gate.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

_BENCH_RE = re.compile(r"^BENCH_(\d+)\.json$")


def load_trajectory(dirpath: Path) -> list[tuple[int, dict]]:
    """All (bench_id, document) pairs in the directory, id-ascending."""
    out = []
    for f in dirpath.iterdir():
        m = _BENCH_RE.match(f.name)
        if not m:
            continue
        doc = json.loads(f.read_text())
        out.append((int(m.group(1)), doc))
    out.sort(key=lambda p: p[0])
    return out


def scenarios(doc: dict) -> dict[str, float]:
    """Flatten one snapshot into {scenario_name: tok_per_s}."""
    s: dict[str, float] = {}
    for engine, m in doc.get("engines", {}).items():
        for key in ("baseline_tok_per_s", "fused_tok_per_s"):
            if key in m:
                s[f"{engine}.{key[:-len('_tok_per_s')]}"] = float(m[key])
    lc = doc.get("longctx")
    if lc:
        ctx = lc.get("ctx", "?")
        for key in ("unsplit_proxy_tok_s", "tuned_proxy_tok_s"):
            if key in lc:
                name = key[: -len("_proxy_tok_s")]
                s[f"longctx.ctx{ctx}.{name}"] = float(lc[key])
    for tag, m in doc.get("cluster", {}).items():   # v4: traffic scaling
        for key in ("rr_tok_per_s", "ca_tok_per_s"):
            if key in m:
                s[f"cluster.{tag}.{key[:-len('_tok_per_s')]}"] = float(m[key])
    sh = doc.get("sharded") or {}                   # v5: sharded replica
    for key, v in sh.items():
        # step_s is lower-is-better; gate its inverse so the shared
        # "rate must not collapse" rule applies unchanged
        if (key.endswith("_step_s") and not key.endswith("_pred_step_s")
                and key != "ref_step_s" and v):
            s[f"sharded.{key[:-len('_step_s')]}.steps_per_s"] = \
                1.0 / float(v)
    if sh.get("ref_step_s"):
        s["sharded.ref.steps_per_s"] = 1.0 / float(sh["ref_step_s"])
    return s


def compare(trajectory: list[tuple[int, dict]],
            tolerance: float) -> list[str]:
    """Regression messages across every consecutive snapshot pair."""
    failures = []
    for (prev_id, prev_doc), (cur_id, cur_doc) in zip(trajectory,
                                                      trajectory[1:]):
        prev_s, cur_s = scenarios(prev_doc), scenarios(cur_doc)
        for name in sorted(set(prev_s) & set(cur_s)):
            before, after = prev_s[name], cur_s[name]
            floor = tolerance * before
            status = "ok" if after >= floor else "REGRESSED"
            print(f"BENCH_{prev_id} -> BENCH_{cur_id}  {name}: "
                  f"{before:.1f} -> {after:.1f} tok/s "
                  f"(floor {floor:.1f})  {status}")
            if after < floor:
                failures.append(
                    f"{name}: {after:.1f} < {floor:.1f} tok/s "
                    f"({tolerance:.0%} of BENCH_{prev_id}'s {before:.1f})")
    return failures


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--dir", default=str(Path(__file__).resolve().parent),
                   help="directory holding BENCH_<n>.json snapshots")
    p.add_argument("--tolerance", type=float, default=0.6,
                   help="pass while new >= tolerance * previous "
                        "(default 0.6)")
    args = p.parse_args(argv)

    trajectory = load_trajectory(Path(args.dir))
    if len(trajectory) < 2:
        print(f"{len(trajectory)} snapshot(s) in {args.dir} — "
              "nothing to compare, passing")
        return 0
    failures = compare(trajectory, args.tolerance)
    if failures:
        print(f"\n{len(failures)} scenario(s) regressed past "
              f"tolerance {args.tolerance}:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\ntrajectory ok: {len(trajectory)} snapshots, "
          f"no scenario below {args.tolerance:.0%} of its predecessor")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
