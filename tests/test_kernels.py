"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode on CPU, per the task spec)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core.microbench.memory import _random_cycle
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 5e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 128, 4, 2, 32), (2, 256, 4, 4, 64)])
@pytest.mark.parametrize("kw", [dict(causal=True),
                                dict(causal=True, window=64),
                                dict(causal=False),
                                dict(causal=True, softcap=30.0)])
def test_flash_attention_sweep(dtype, shape, kw):
    B, S, H, KH, D = shape
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, KH, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, KH, D)), dtype)
    o = ops.flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    r = ref.flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=4 * _tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("di,n,block", [(256, 8, 128), (512, 16, 256)])
def test_ssm_scan_sweep(dtype, di, n, block):
    Bt, S = 2, 32
    x = jnp.asarray(RNG.normal(size=(Bt, S, di)) * 0.2, dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(Bt, S, di)), dtype)
    Bm = jnp.asarray(RNG.normal(size=(Bt, S, n)) * 0.2, dtype)
    Cm = jnp.asarray(RNG.normal(size=(Bt, S, n)) * 0.2, dtype)
    A = -jnp.abs(jnp.asarray(RNG.normal(size=(di, n)), jnp.float32))
    o = ops.ssm_scan(x, dt, Bm, Cm, A, block_d=block)
    r = ref.ssm_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=10 * _tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("h,n", [(2, 32), (4, 64)])
def test_wkv6_sweep(dtype, h, n):
    B, S = 2, 24
    r_ = jnp.asarray(RNG.normal(size=(B, S, h, n)) * 0.3, dtype)
    k_ = jnp.asarray(RNG.normal(size=(B, S, h, n)) * 0.3, dtype)
    v_ = jnp.asarray(RNG.normal(size=(B, S, h, n)) * 0.3, dtype)
    w_ = jnp.asarray(RNG.uniform(0.7, 0.999, size=(B, S, h, n)), dtype)
    u_ = jnp.asarray(RNG.normal(size=(h, n)) * 0.3, dtype)
    o = ops.wkv6(r_, k_, v_, w_, u_)
    rr = ref.wkv6_ref(r_, k_, v_, w_, u_)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(rr, np.float32),
                               atol=10 * _tol(dtype))


@pytest.mark.parametrize("sq,skv,bq,bk", [
    (12, 13, 8, 8),        # kv tail: 13 % 8 != 0 (the silently-dropped case)
    (100, 100, 64, 64),    # both tails ragged
    (5, 9, 128, 128),      # blocks larger than the problem
    (37, 53, 16, 32),      # coprime everything
])
def test_flash_attention_ragged_tails(sq, skv, bq, bk):
    """seq % block != 0 must pad+mask, not drop the tail (regression: the
    old kernel computed n_blocks = seq_kv // block_k and lost the rest)."""
    q = jnp.asarray(RNG.normal(size=(2, sq, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, skv, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, skv, 2, 16)), jnp.float32)
    for kw in (dict(causal=False), dict(causal=True),
               dict(causal=True, window=7)):
        o = ops.flash_attention(q, k, v, block_q=bq, block_k=bk, **kw)
        r = ref.flash_attention_ref(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(skv=st.integers(1, 70), bk=st.sampled_from([8, 16, 32, 64, 128]),
       causal=st.booleans())
def test_flash_attention_kv_boundary_property(skv, bk, causal):
    """Property: any (seq_kv, block_k) pair matches the reference — the
    padded tail is masked, never attended, never dropped."""
    rng = np.random.default_rng(skv * 1000 + bk)
    sq = max(skv - 2, 1)
    q = jnp.asarray(rng.normal(size=(1, sq, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, skv, 1, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, skv, 1, 8)), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=bk)
    r = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-4)


@pytest.mark.parametrize("op", ["add", "mul", "fma", "max", "div", "rsqrt",
                                "exp", "tanh", "select"])
@pytest.mark.parametrize("dependent", [True, False])
def test_alu_chain_sweep(op, dependent):
    x = jnp.asarray(RNG.normal(size=(8, 128)) + 2.0, jnp.float32)
    o = ops.alu_chain(x, 1.0009765625, op=op, length=12, dependent=dependent)
    r = ref.alu_chain_ref(x, jnp.float32(1.0009765625), op=op, length=12,
                          dependent=dependent)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-4,
                               atol=1e-4)


@pytest.mark.parametrize("n", [64, 512, 4096])
def test_pointer_chase_sweep(n):
    nxt = jnp.asarray(_random_cycle(n, seed=n))
    o = ops.pointer_chase(nxt, 0, hops=min(n, 257))
    r = ref.pointer_chase_ref(nxt, jnp.int32(0), min(n, 257))
    assert int(o) == int(r)


def test_pointer_chase_visits_whole_cycle():
    n = 128
    nxt = jnp.asarray(_random_cycle(n))
    assert int(ops.pointer_chase(nxt, 0, hops=n)) == 0  # full cycle


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,chain", [(128, 128, 128, 1),
                                         (128, 128, 128, 4),
                                         (256, 256, 128, 1)])
def test_mxu_probe_sweep(dtype, m, k, n, chain):
    a = jnp.asarray(RNG.normal(size=(m, k)) * 0.1, dtype)
    b = jnp.asarray(RNG.normal(size=(k, n)) * 0.1, dtype)
    o = ops.mxu_probe(a, b, chain=chain)
    r = ref.mxu_probe_ref(a, b, chain=chain)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=5 * _tol(dtype), rtol=2e-2)


# ---------------------------------------------------------------------------
# paged attention (decode through a block table)
# ---------------------------------------------------------------------------


def _paged_case(B, H, KH, D, bs, ctxs, n_pages, seed=0):
    """Random pages + per-row dense shuffled block tables for given
    context lengths."""
    rng = np.random.default_rng(seed)
    NB = max(-(-c // bs) for c in ctxs)
    q = jnp.asarray(rng.normal(size=(B, H, D)) * 0.3, jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, bs, KH, D)) * 0.3, jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, bs, KH, D)) * 0.3, jnp.float32)
    perm = rng.permutation(n_pages)
    bt = np.full((B, NB), -1, np.int32)
    used = 0
    for b, c in enumerate(ctxs):
        nb = -(-c // bs)
        bt[b, :nb] = perm[used:used + nb]
        used += nb
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(ctxs, jnp.int32)


@pytest.mark.parametrize("hbm", [False, True])
@pytest.mark.parametrize("kw", [dict(), dict(window=5), dict(softcap=8.0),
                                dict(window=3, softcap=4.0)])
@pytest.mark.parametrize("bs,ctxs", [(4, (1, 7, 18)), (8, (8, 3, 21))])
def test_paged_attention_kernel_matches_ref(kw, bs, ctxs, hbm):
    """Both lowerings — the VMEM-staged pool and the HBM-resident one
    (pages double-buffered in via async copies) — against the oracle."""
    q, kp, vp, bt, ctx = _paged_case(3, 4, 2, 16, bs, ctxs, n_pages=16)
    o = ops.paged_attention(q, kp, vp, bt, ctx, hbm=hbm, **kw)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx, **kw)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)


def test_paged_attention_hbm_bf16_pool_and_single_page_context():
    """The HBM lowering at the serving dtype (bf16 pool) and at the
    single-page boundary (no double-buffer handoff at all)."""
    q, kp, vp, bt, ctx = _paged_case(2, 4, 2, 16, 4, (3, 4), n_pages=8)
    kp16, vp16 = kp.astype(jnp.bfloat16), vp.astype(jnp.bfloat16)
    o = ops.paged_attention(q, kp16, vp16, bt, ctx, hbm=True)
    r = ref.paged_attention_ref(q, kp16.astype(jnp.float32),
                                vp16.astype(jnp.float32), bt, ctx)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-2)


def test_paged_attention_hbm_zero_context_and_unbacked_page():
    """HBM lowering edge cases: ctx == 0 rows are all-masked zeros, and a
    -1 table entry inside the context masks instead of attending the
    clipped page."""
    q, kp, vp, bt, _ = _paged_case(2, 2, 1, 8, 4, (4, 8), n_pages=6)
    ctx = jnp.asarray([0, 8], jnp.int32)
    o = ops.paged_attention(q, kp, vp, bt, ctx, hbm=True)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    assert np.abs(np.asarray(o)[0]).max() == 0.0
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)
    bt2 = jnp.asarray([[-1, 2]], jnp.int32)
    ctx2 = jnp.asarray([8], jnp.int32)
    o2 = ops.paged_attention(q[:1], kp, vp, bt2, ctx2, hbm=True)
    r2 = ref.paged_attention_ref(q[:1], kp, vp, bt2, ctx2)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r2), atol=1e-5)


def test_paged_attention_matches_contiguous_flash_decode():
    """Ground truth: the paged gather over shuffled pages must equal plain
    single-query attention over the contiguous K/V it represents."""
    B, H, KH, D, bs = 2, 4, 2, 16, 4
    ctxs = (11, 18)
    q, kp, vp, bt, ctx = _paged_case(B, H, KH, D, bs, ctxs, n_pages=12)
    o = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    kp_n, vp_n, bt_n = map(np.asarray, (kp, vp, bt))
    for b, c in enumerate(ctxs):
        nb = -(-c // bs)
        ks = np.concatenate([kp_n[bt_n[b, j]] for j in range(nb)])[:c]
        vs = np.concatenate([vp_n[bt_n[b, j]] for j in range(nb)])[:c]
        # one query at position c-1 against its full causal context
        r = ref.flash_attention_ref(
            np.asarray(q)[b][None, None],            # [1,1,H,D]
            ks[None], vs[None], causal=False)[0, 0]
        np.testing.assert_allclose(np.asarray(o)[b], r, atol=1e-5)


def test_paged_attention_zero_context_rows_are_zero():
    q, kp, vp, bt, _ = _paged_case(2, 2, 1, 8, 4, (4, 8), n_pages=6)
    ctx = jnp.asarray([0, 8], jnp.int32)
    o = ops.paged_attention(q, kp, vp, bt, ctx)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    assert np.abs(np.asarray(o)[0]).max() == 0.0
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)


# ---------------------------------------------------------------------------
# split-KV flash decoding: every split factor must be invisible to the caller
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hbm", [False, True])
@pytest.mark.parametrize("kw", [dict(), dict(window=5), dict(softcap=8.0),
                                dict(window=3, softcap=4.0)])
@pytest.mark.parametrize("ns", [2, 3, 5])
def test_paged_attention_split_matches_unsplit_and_ref(kw, ns, hbm):
    """Both lowerings, ragged contexts not divisible by num_splits, GQA
    (H=4 over KH=2): the two-pass log-sum-exp merge must reproduce the
    unsplit kernel and the oracle."""
    q, kp, vp, bt, ctx = _paged_case(3, 4, 2, 16, 4, (1, 7, 18), n_pages=16)
    o_split = ops.paged_attention(q, kp, vp, bt, ctx, num_splits=ns,
                                  hbm=hbm, **kw)
    o_unsplit = ops.paged_attention(q, kp, vp, bt, ctx, num_splits=1,
                                    hbm=hbm, **kw)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx, **kw)
    np.testing.assert_allclose(np.asarray(o_split), np.asarray(o_unsplit),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_split), np.asarray(r), atol=1e-5)


@pytest.mark.parametrize("hbm", [False, True])
def test_paged_attention_more_splits_than_pages(hbm):
    """num_splits > n_valid_pages: surplus splits get empty [lo, hi)
    ranges and must contribute identity partials (zero merge weight),
    not NaNs or garbage."""
    q, kp, vp, bt, ctx = _paged_case(2, 2, 1, 8, 4, (3, 8), n_pages=6)
    o = ops.paged_attention(q, kp, vp, bt, ctx, num_splits=16, hbm=hbm)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)


@pytest.mark.parametrize("hbm", [False, True])
def test_paged_attention_split_zero_ctx_and_unbacked_page(hbm):
    """Split path edge cases: a ctx == 0 row stays all-zero after the
    merge, and a -1 block-table entry inside the context masks its
    positions in whichever split owns that page."""
    q, kp, vp, bt, _ = _paged_case(2, 2, 1, 8, 4, (4, 8), n_pages=6)
    ctx = jnp.asarray([0, 8], jnp.int32)
    o = ops.paged_attention(q, kp, vp, bt, ctx, num_splits=2, hbm=hbm)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    assert np.abs(np.asarray(o)[0]).max() == 0.0
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)
    bt2 = jnp.asarray([[-1, 2]], jnp.int32)
    ctx2 = jnp.asarray([8], jnp.int32)
    o2 = ops.paged_attention(q[:1], kp, vp, bt2, ctx2, num_splits=2, hbm=hbm)
    r2 = ref.paged_attention_ref(q[:1], kp, vp, bt2, ctx2)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r2), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(ctx0=st.integers(0, 40), ctx1=st.integers(1, 40),
       ns=st.integers(1, 12), bs=st.sampled_from([4, 8]),
       window=st.sampled_from([None, 5]),
       softcap=st.sampled_from([None, 8.0]))
def test_paged_attention_split_equivalence_property(ctx0, ctx1, ns, bs,
                                                    window, softcap):
    """Property: ANY (context lengths, block size, split factor, masking
    flags) combination — ragged contexts, splits exceeding the page
    count, GQA heads — yields split == unsplit == ref, and identical
    greedy argmax decisions."""
    seed = ctx0 * 9973 + ctx1 * 389 + ns * 31 + bs
    n_pages = -(-max(ctx0, 1) // bs) + -(-ctx1 // bs) + 2
    q, kp, vp, bt, ctx = _paged_case(2, 4, 2, 16, bs, (ctx0, ctx1),
                                     n_pages=n_pages, seed=seed)
    kw = {}
    if window is not None:
        kw["window"] = window
    if softcap is not None:
        kw["softcap"] = softcap
    o_split = ops.paged_attention(q, kp, vp, bt, ctx, num_splits=ns, **kw)
    o_unsplit = ops.paged_attention(q, kp, vp, bt, ctx, num_splits=1, **kw)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx, **kw)
    np.testing.assert_allclose(np.asarray(o_split), np.asarray(o_unsplit),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_split), np.asarray(r), atol=1e-5)
    # the serving gate: greedy decisions downstream of the kernel must
    # not depend on the split factor
    rng = np.random.default_rng(seed)
    readout = rng.normal(size=(np.asarray(q).shape[1] * 16, 64))
    ids = lambda o: np.argmax(np.asarray(o).reshape(2, -1) @ readout, -1)  # noqa: E731
    np.testing.assert_array_equal(ids(o_split), ids(o_unsplit))


def test_paged_attention_unbacked_page_inside_context_is_masked():
    """Regression: a -1 block-table entry WITHIN the context range must
    mask its positions (the kernel used to clip it to page 0 and attend
    that page's unrelated K/V; the ref always masked)."""
    q, kp, vp, _, _ = _paged_case(1, 2, 1, 8, 4, (8,), n_pages=6)
    bt = jnp.asarray([[-1, 2]], jnp.int32)
    ctx = jnp.asarray([8], jnp.int32)
    o = ops.paged_attention(q, kp, vp, bt, ctx)
    r = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)
    # and only page 2's positions contribute: equal to ctx starting there
    o2 = ops.paged_attention(q, kp, vp, jnp.asarray([[2]], jnp.int32),
                             jnp.asarray([4], jnp.int32))
    # positions differ (4..7 vs 0..3) but with no window/rope the scores
    # depend only on content, so outputs match
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), atol=1e-5)
