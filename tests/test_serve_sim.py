"""Deterministic engine-simulation scenarios.

The harness itself (SimClock / FakeModel / FakeCostModel /
expected_tokens / drive) started life in this file and was promoted to
``repro.serve.sim`` in the telemetry PR so the drift/overload scenarios,
the CI smoke, and the campaign replay can share it — these tests now
import it from there and pin the scheduler invariants on top:
no request lost, FIFO admission, exact deferral accounting, every
evicted request eventually completes, and the slot engine's corrected
``deferred_prefills`` semantics (the regression from the old
``min(len(queue), len(free)-idx)`` over-count).
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.zoo import build_model
from repro.serve import PagedServingEngine, ServingEngine
from repro.serve.sim import (FakeCostModel, FakeModel, SimClock, drive,
                             expected_tokens)


def paged(model, clock=None, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("chunk_size", 4)
    return PagedServingEngine(model, params=None, clock=clock, **kw)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------


def test_no_request_lost_and_outputs_exact():
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock)
    rng = np.random.default_rng(0)
    arrivals = [(float(i // 3), rng.integers(0, 97, size=int(l)), 4, None)
                for i, l in enumerate(rng.integers(1, 12, size=9))]
    rids = drive(eng, clock, arrivals)
    assert eng.stats.completed == len(arrivals)      # no request lost
    assert sorted(eng.done) == sorted(rids)
    for rid, t in rids.items():
        req = eng.done[rid]
        assert req.tokens == expected_tokens(req.prompt, 4, 97)
        # timestamps are scripted values, not wall time
        assert req.submitted_s == t
        assert req.finished_s == int(req.finished_s) >= t


def test_fifo_admission_and_eos_retire():
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock)
    arrivals = [(0.0, [5, 6, 7], 8, 10),     # eos after 3 tokens (8,9,10)
                (0.0, [20], 8, None),
                (1.0, [40, 41], 8, None)]
    rids = drive(eng, clock, arrivals)
    assert eng.stats.completed == 3
    # FIFO: admission order == submission (rid) order, no preemption here
    assert eng.stats.admission_order == sorted(rids)
    first = eng.done[min(rids)]
    assert first.tokens == [8, 9, 10]
    assert first.eos_id == 10


def test_recorded_shapes_are_the_two_engine_calls():
    """The fake model's trace census: chunked prefill runs [1, chunk] and
    batched decode [max_batch, 1], each against a full-width block table —
    and nothing else."""
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock, max_batch=3, chunk_size=4)
    drive(eng, clock, [(0.0, list(range(1, 7)), 3, None)])
    nb = eng.max_blocks_per_seq
    assert set(model.decode_shapes) == {((1, 4), (1, nb)),
                                        ((3, 1), (3, nb))}


def test_deferred_prefills_exact_accounting():
    """Hand-checkable budget arithmetic (decode=1.0, chunk=1.0,
    budget=2.5): 3 requests of exactly 2 chunks each defer one candidate
    in each of the first two planning steps and nothing afterwards."""
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock, chunk_size=4,
                cost_model=FakeCostModel(decode_s=1.0, prefill_s=1.0),
                step_budget_s=2.5)
    prompts = [list(range(10, 18)), list(range(30, 38)),
               list(range(50, 58))]           # 8 tokens = 2 chunks each
    for p in prompts:
        eng.submit(np.asarray(p, np.int32), max_new_tokens=3)

    eng.step()   # chunks r0+r1 fit (0+1+1 <= 2.5); r2 deferred
    assert eng.stats.deferred_prefills == 1
    assert eng.stats.prefill_chunks == 2
    eng.step()   # r0+r1 final chunks; r2 deferred again
    assert eng.stats.deferred_prefills == 2
    assert eng.stats.prefills == 2            # r0, r1 ready
    eng.step()   # decode(1.0) + r2 first chunk (always-admit-one)
    assert eng.stats.deferred_prefills == 2
    assert eng.stats.prefill_chunks == 5
    eng.run_until_done()
    assert eng.stats.completed == 3
    assert eng.stats.deferred_prefills == 2   # nothing counted after
    assert eng.stats.predicted_step_s[:3] == [2.0, 2.0, 2.0]
    for rid, req in eng.done.items():
        assert req.tokens == expected_tokens(req.prompt, 3, 97)


def test_evicted_requests_eventually_complete():
    """Pool of exactly one max_len sequence: concurrent requests must
    preempt each other, and every evicted request still completes with
    the right tokens (greedy replay is deterministic)."""
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock, max_batch=2, max_len=16, block_size=4,
                n_blocks=4, chunk_size=4)
    arrivals = [(0.0, list(range(10, 18)), 4, None),
                (0.0, list(range(30, 38)), 4, None),
                (2.0, list(range(50, 57)), 4, None)]
    rids = drive(eng, clock, arrivals, max_steps=200)
    assert eng.stats.completed == 3
    assert eng.stats.preemptions > 0          # evictions actually happened
    for rid in rids:
        req = eng.done[rid]
        assert req.tokens == expected_tokens(req.prompt, 4, 97)
    # leak-free teardown: every block back on the free list
    eng.allocator.check()
    assert eng.allocator.n_free == eng.n_blocks
    assert eng.stats.peak_blocks_in_use == eng.n_blocks


def test_decode_phase_eviction_of_collected_row_does_not_crash():
    """Regression: a ready row already collected for this decode step can
    be evicted by a LATER ready row's block growth in the same loop — the
    engine must drop it from the batch, not dereference its cleared row
    (the original code crashed with AttributeError on rows[i].last_tok).
    Also pins delivered-token accounting: eviction replays must not
    double-count decoded_tokens."""
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock, max_batch=3, max_len=16, block_size=4,
                n_blocks=6, chunk_size=4)
    rng = np.random.default_rng(1)
    arrivals = [(float(i // 3), rng.integers(0, 97, size=int(l)), 4, None)
                for i, l in enumerate(rng.integers(4, 13, size=9))]
    rids = drive(eng, clock, arrivals, max_steps=400)
    assert eng.stats.completed == 9
    assert eng.stats.preemptions > 0
    for rid in rids:
        req = eng.done[rid]
        assert req.tokens == expected_tokens(req.prompt, 4, 97)
    # delivered tokens == what completed requests actually hold: replays
    # of evicted work were rolled back, not counted twice
    delivered = sum(len(r.tokens) - 1 for r in eng.done.values())
    assert eng.stats.decoded_tokens == delivered
    assert eng.stats.prefills == 9
    assert eng.allocator.n_free == eng.n_blocks


def test_overlong_prompts_rejected_at_submit(tiny_lm):
    """A prompt that cannot fit max_len must be rejected at submit on
    BOTH engines — mid-trace it would overrun the paged engine's fixed-
    width block table and strand an allocated block outside any table."""
    model, params = tiny_lm
    eng = PagedServingEngine(model, params, max_batch=2, max_len=16,
                             block_size=4)
    with pytest.raises(ValueError, match="cannot fit"):
        eng.submit(np.arange(16, dtype=np.int32))
    slot = ServingEngine(model, params, max_batch=2, max_len=16)
    with pytest.raises(ValueError, match="cannot fit"):
        slot.submit(np.arange(20, dtype=np.int32))
    # one-under-the-cap is fine and completes
    rid = eng.submit(np.arange(15, dtype=np.int32), max_new_tokens=4)
    eng.run_until_done()
    assert rid in eng.done


def test_block_occupancy_stats_tracked():
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock)
    drive(eng, clock, [(0.0, [3, 4, 5, 6, 7], 4, None)])
    assert eng.stats.peak_blocks_in_use >= 2
    assert len(eng.stats.block_occupancy) == eng.stats.steps
    assert all(0.0 <= o <= 1.0 for o in eng.stats.block_occupancy)
    assert max(eng.stats.block_occupancy) > 0


def test_fused_path_skips_predictable_shadow_steps():
    """Regression: a row whose retirement is already host-computable
    (token budget exhausted by in-flight dispatches) must NOT be
    dispatched again — a shadow step burns an iteration and can even
    grow a block (evicting a live victim) for output the drain drops.
    Solo 4-token prompt, one chunk, max_new=4: the chunk step also runs
    the first decode, then two more decode steps — exactly 3 steps, no
    trailing shadow (the unguarded pipeline dispatched a 4th)."""
    model = FakeModel()
    clock = SimClock()
    eng = paged(model, clock)
    drive(eng, clock, [(0.0, [10, 11, 12, 13], 4, None)])
    assert eng.stats.completed == 1
    req = next(iter(eng.done.values()))
    assert req.tokens == expected_tokens(req.prompt, 4, 97)
    assert eng.stats.steps == 3          # chunk+decode, decode, decode
    assert eng.stats.decoded_tokens == 3


def test_fused_and_blocking_paths_agree_on_scripted_trace():
    """The fused hot path (on-device argmax, donated pool, pipelined
    drain) against the legacy blocking path on the same scripted trace:
    every request's tokens — computable in closed form for FakeModel —
    must match, and only the fused engine stays at <= 1 sync/step."""
    rng = np.random.default_rng(4)
    arrivals = [(float(i // 2), rng.integers(0, 97, size=int(l)), 4, None)
                for i, l in enumerate(rng.integers(1, 12, size=8))]

    def run(fused):
        model = FakeModel()
        clock = SimClock()
        eng = paged(model, clock, fused=fused)
        rids = drive(eng, clock, arrivals)
        return eng, {eng.done[r].prompt.tobytes(): eng.done[r].tokens
                     for r in rids}

    blocking_eng, blocking = run(False)
    fused_eng, fused = run(True)
    assert fused == blocking
    for req in fused_eng.done.values():
        assert req.tokens == expected_tokens(req.prompt, 4, 97)
    assert fused_eng.stats.host_syncs <= fused_eng.stats.steps
    assert blocking_eng.stats.host_syncs > blocking_eng.stats.steps


# ---------------------------------------------------------------------------
# the slot engine's corrected deferred_prefills semantics (regression)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=64)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_slot_deferred_count_excludes_requests_that_would_fit(tiny_lm):
    """Regression for the old over-count: with a huge prompt at the queue
    head and a tiny one behind it, only the huge one is budget-deferred —
    the tiny one (which would have fit) is blocked by FIFO order, not by
    the budget, and must NOT be counted.  The old code bulk-counted
    min(len(queue), free slots) = 2."""
    model, params = tiny_lm
    # price prefills proportional to prompt length, decode at ~0
    cm = FakeCostModel(decode_s=0.0,
                       predict_fn=lambda census: census["flops"])
    probe = ServingEngine(model, params, max_batch=4, max_len=96,
                          cost_model=cm)
    cost = lambda n: probe._predict_prefill(n).step_s
    budget = cost(4) + cost(6) + 1.0          # fits small+tiny, not huge
    assert cost(64) > budget

    eng = ServingEngine(model, params, max_batch=4, max_len=96,
                        cost_model=cm, step_budget_s=budget)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2)    # admitted
    eng.submit(np.arange(64, dtype=np.int32), max_new_tokens=2)   # too big
    eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=2)    # would fit
    eng.step()
    assert eng.stats.prefills == 1
    assert eng.stats.deferred_prefills == 1   # old code counted 2
    # FIFO is preserved: the tiny request is NOT admitted around the head
    assert len(eng.queue) == 2
    stats = eng.run_until_done()
    assert stats.completed == 3
