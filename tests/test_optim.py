import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.train import optim as O


def _quadratic_losses(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((256, 3)) + jnp.asarray([5.0, 5.0, 5.0])}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean(jnp.square(p["w"] - target))

    losses = []
    for _ in range(steps):
        l, g = jax.value_and_grad(loss_fn)(params)
        upd, state, _ = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
        losses.append(float(l))
    return losses


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends(name):
    lr = lambda s: 0.3
    opt = (O.make_adamw(lr, weight_decay=0.0) if name == "adamw"
           else O.make_adafactor(lr))
    losses = _quadratic_losses(opt, steps=120)
    assert losses[-1] < 0.05 * losses[0]


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, gn = O.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    np.testing.assert_allclose(float(O.global_norm(clipped)), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    s = O.warmup_cosine(1e-3, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1e-3, rtol=1e-5)
    assert float(s(jnp.asarray(50))) < 1e-3
    assert float(s(jnp.asarray(100))) >= 1e-4 * 0.99  # floor


def test_adamw_state_specs_mirror_params():
    opt = O.make_optimizer("adamw")
    specs = opt.state_specs({"w": P("data", "model")}, None)
    assert specs["m"]["w"] == P("data", "model")
    assert specs["v"]["w"] == P("data", "model")


def test_adafactor_factored_shapes_and_specs():
    opt = O.make_optimizer("adafactor")
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
    st = opt.init(params)
    assert st["slots"]["big"]["vr"].shape == (256,)
    assert st["slots"]["big"]["vc"].shape == (512,)
    assert st["slots"]["small"]["v"].shape == (8,)
    shapes = jax.eval_shape(lambda: params)
    specs = opt.state_specs({"big": P("data", "model"), "small": P(None)},
                            shapes)
    assert specs["slots"]["big"]["vr"] == P("data")
    assert specs["slots"]["big"]["vc"] == P("model")
    # memory win: factored slots are ~(m+n)/(m*n) of adam's second moment
    adam_bytes = 256 * 512 * 4
    fact_bytes = (256 + 512) * 4
    assert fact_bytes < adam_bytes / 80
