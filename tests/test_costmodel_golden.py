"""Golden-file regression for ``CostModel.predict``.

A fixed set of censuses — hand-written decode/prefill/MXU shapes plus the
paged-attention tunable's analytic census — priced against BOTH shipped
calibrations, compared field-by-field against ``tests/golden/
predictions.json``.  Any calibration-loader or layer refactor that shifts
a price now fails loudly instead of silently re-costing the serving
engine's admission decisions.  Intentional changes re-baseline with
``pytest tests/test_costmodel_golden.py --update-golden``.
"""
import json
import math
from pathlib import Path

import pytest

from repro.core.autotune.space import TUNABLES
from repro.core.costmodel import CostModel

GOLDEN = Path(__file__).parent / "golden" / "predictions.json"
CALIBRATIONS = ("ampere_a100", "tpu_v5e")
REL_TOL = 1e-9

# name -> (census, predict kwargs).  Pure literals (no model building), so
# the golden pins the cost model alone, not the architecture zoo.
CENSUSES = {
    "decode_like": (
        {"flops": 2.0e9, "hbm_bytes": 5.0e8,
         "op_histogram": {"fusion": 60.0, "dot": 12.0,
                          "dynamic-update-slice": 4.0, "transpose": 4.0,
                          "reshape": 8.0, "copy": 2.0}},
        {}),
    "prefill_like": (
        {"flops": 5.0e11, "hbm_bytes": 2.0e9,
         "collective_bytes_total": 1.0e6,
         "op_histogram": {"fusion": 90.0, "dot": 18.0, "add": 12.0,
                          "exponential": 6.0, "all-reduce": 4.0}},
        {}),
    "mxu_tile_f32": (
        {"flops": 1.0e12, "hbm_bytes": 1.0e9,
         "op_histogram": {"dot": 64.0, "multiply": 64.0, "fusion": 64.0}},
        {"dtype": "f32", "mxu_shape": (128, 128, 128)}),
    "paged_decode_bs16": (
        TUNABLES["paged_attention"].census(
            {"batch": 8, "heads": 8, "kv_heads": 2, "head_dim": 128,
             "ctx": 2048}, {"block_size": 16}),
        {}),
    "paged_decode_bs128": (
        TUNABLES["paged_attention"].census(
            {"batch": 8, "heads": 8, "kv_heads": 2, "head_dim": 128,
             "ctx": 2048}, {"block_size": 128}),
        {}),
}


def _compute():
    out = {}
    for cal in CALIBRATIONS:
        model = CostModel.from_named(cal)
        for name, (census, kw) in CENSUSES.items():
            p = model.predict(census, **kw)
            out[f"{cal}/{name}"] = {
                "step_s": p.step_s,
                "compute_s": p.compute_s,
                "memory_s": p.memory_s,
                "collective_s": p.collective_s,
                "issue_overhead_s": p.issue_overhead_s,
                "bottleneck": p.bottleneck,
                "defaulted_op_count": p.defaulted_op_count,
            }
    return out


def test_predictions_match_golden(update_golden):
    got = _compute()
    if update_golden:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
        pytest.skip(f"golden rewritten: {GOLDEN}")
    assert GOLDEN.exists(), \
        "no golden file — generate with `pytest --update-golden`"
    want = json.loads(GOLDEN.read_text())
    assert sorted(got) == sorted(want), "census/calibration set changed"
    for key, fields in got.items():
        for f, v in fields.items():
            w = want[key][f]
            if isinstance(v, float):
                assert math.isclose(v, w, rel_tol=REL_TOL, abs_tol=1e-30), \
                    f"{key}.{f}: {v} != golden {w}"
            else:
                assert v == w, f"{key}.{f}: {v!r} != golden {w!r}"


def test_paged_census_prices_the_block_size_trade():
    """Sanity behind the golden: both shipped calibrations must see the
    page-size trade at all (different block sizes -> different prices),
    or tuning block_size through them is meaningless."""
    for cal in CALIBRATIONS:
        model = CostModel.from_named(cal)
        a = model.predict(CENSUSES["paged_decode_bs16"][0]).step_s
        b = model.predict(CENSUSES["paged_decode_bs128"][0]).step_s
        assert a > 0 and b > 0
        assert a != b, cal
