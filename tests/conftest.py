"""Shared pytest wiring: the ``--update-golden`` flag.

``pytest --update-golden`` rewrites the golden files under
``tests/golden/`` from the CURRENT outputs instead of comparing against
them — the escape hatch for intentional calibration-format or model
changes.  Tests that consumed the flag skip with an "updated" notice so a
rewrite run can never silently pass as a verification run.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/* from current outputs (then skip)")


@pytest.fixture
def update_golden(request):
    return request.config.getoption("--update-golden")
