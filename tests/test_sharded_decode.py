"""Sharded intra-replica decode: the tentpole acceptance contract plus
the mesh/plan bugfix sweep that rides along.

The headline: a paged replica spanning ``plan.model_parallel`` chips
(KV heads over 'model', batch rows over 'data', block tables
replicated) must be a pure LAYOUT change — greedy tokens byte-identical
to the single-device engine on the 32-request acceptance trace, with
the fused path's <= 1-host-sync and donated-pool invariants intact, and
eviction + compaction actually exercised while it runs.  CPU hosts own
one device, so the canonical check re-enters ``repro.serve.
sharded_check`` in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (one run,
module-scoped; ~20 s).

The sweep: ``make_host_mesh`` divisibility validation + allow_shrink,
``slice_devices`` replica budgeting, ``candidate_mesh_shapes`` on
headless/duck-typed archs (the ``python -m repro.sharding`` CLI crash),
``strip_axis`` (serving keeps weights replicated over 'data' — the
byte-identity fix), and ``paged_decode_shardings``'s replication
fallbacks.
"""
import logging

import pytest

from repro.launch.mesh import make_host_mesh, slice_devices
from repro.serve.sharded_check import parse_shapes, run_subprocess
from repro.sharding.plans import candidate_mesh_shapes, strip_axis

SHAPES = [(1, 1), (2, 1), (1, 2), (2, 2)]


@pytest.fixture(scope="module")
def check_doc():
    """THE canonical acceptance run: 4 factorizations x 32 requests on a
    forced-8-device CPU host (single subprocess, shared by the tests)."""
    return run_subprocess(SHAPES, devices=8, n_req=32)


# ---------------------------------------------------------------------------
# tentpole: byte-identical tokens + fused-path invariants per mesh shape
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES,
                         ids=[f"d{d}m{m}" for d, m in SHAPES])
def test_sharded_replica_byte_identical_with_invariants(check_doc, shape):
    d, m = shape
    entry = next(e for e in check_doc["shapes"]
                 if (e["data"], e["model"]) == (d, m))
    assert "skipped" not in entry, entry
    assert entry["identical"], \
        f"(data={d}, model={m}) diverged from the single-device engine"
    assert entry["sync_per_step_ok"], entry
    assert entry["donated"], "fused pool donation broke under sharding"
    # layout never changes scheduling: same step count as the reference
    assert entry["steps"] == check_doc["reference"]["steps"]


def test_top_ranked_plan_is_model_parallel_and_identical(check_doc):
    """THE acceptance criterion: the replica built from
    ``rank_plans(...)[0]`` — which must want model parallelism on this
    cell — reproduces the single-device engine byte-for-byte."""
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeCell
    from repro.serve.sharded_check import ENGINE_KW
    from repro.sharding.plans import rank_plans
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    cell = ShapeCell("sharded", "decode", ENGINE_KW["max_len"],
                     ENGINE_KW["max_batch"])
    best = rank_plans(cfg, cell, 4)[0]
    assert best.model >= 2
    entry = next((e for e in check_doc["shapes"]
                  if (e["data"], e["model"]) == best.mesh_shape), None)
    assert entry is not None, \
        f"top plan {best.mesh_shape} not in the checked SHAPES — extend them"
    assert entry["identical"] and entry["ok"]


def test_acceptance_trace_exercises_eviction_and_compaction(check_doc):
    """Token equality is only meaningful if the hard paths ran: the tight
    pool (10 blocks x 8) must preempt and compact under the 32-request
    trace, identically on every shape."""
    for e in check_doc["shapes"]:
        assert e["preemptions"] > 0, e
        assert e["compactions"] > 0, e


def test_cost_model_prices_every_factorization(check_doc):
    for e in check_doc["shapes"]:
        assert e["predicted_step_s"] is not None and e["predicted_step_s"] > 0


def test_sharded_paged_attention_kernel_matches_unsharded(check_doc):
    """``paged_attention_sharded``'s shard_map head/batch split on a
    (2, 2) mesh vs the plain kernel (run inside the 8-device child)."""
    assert check_doc["kernel_sharded_ok"] is True


def test_uneven_heads_fall_back_to_replication_and_stay_identical():
    """model=3 cannot divide the reduced arch's KV heads: the shardings
    must fall back to replication (logged), not crash or diverge."""
    doc = run_subprocess([(1, 3)], devices=4, n_req=6)
    entry = doc["shapes"][0]
    assert entry["identical"] and entry["ok"]
    assert any("replicated KV pool" in line
               for line in entry["sharding_log"])


# ---------------------------------------------------------------------------
# make_host_mesh / slice_devices (satellite: divisibility validation)
# ---------------------------------------------------------------------------


def test_make_host_mesh_defaults_to_all_devices_model_1():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 1


def test_make_host_mesh_rejects_non_divisible_model_axis():
    # 8 % 3 != 0: the old code silently built a (2, 3) mesh and DROPPED
    # two devices — now it must refuse with an actionable message
    with pytest.raises(ValueError, match="does not divide"):
        make_host_mesh(model_axis=3, devices=list(range(8)))


def test_make_host_mesh_allow_shrink_falls_back_to_divisor(caplog):
    import jax
    dev = jax.devices()[0]
    with caplog.at_level(logging.WARNING, logger="repro.launch.mesh"):
        mesh = make_host_mesh(model_axis=5, devices=[dev],
                              allow_shrink=True)
    assert mesh.shape == {"data": 1, "model": 1}
    assert any("shrinking" in r.message for r in caplog.records)


def test_make_host_mesh_rejects_degenerate_inputs():
    with pytest.raises(ValueError, match="at least one device"):
        make_host_mesh(devices=[])
    with pytest.raises(ValueError, match=">= 1"):
        make_host_mesh(model_axis=0, devices=list(range(4)))


def test_slice_devices_carves_disjoint_replica_budgets():
    devs = list(range(8))
    slices = slice_devices(2, 4, devices=devs)
    assert slices == [[0, 1, 2, 3], [4, 5, 6, 7]]
    with pytest.raises(ValueError, match="exceeds"):
        slice_devices(3, 4, devices=devs)


# ---------------------------------------------------------------------------
# candidate_mesh_shapes + CLI (satellite: headless archs must not crash)
# ---------------------------------------------------------------------------


def test_candidate_mesh_shapes_prunes_uneven_heads_for_attention():
    from repro.configs import ARCHS, reduced
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    shapes = candidate_mesh_shapes(8, cfg)
    assert all(d * m == 8 for d, m in shapes)
    for _, m in shapes:
        if m > 1:
            assert cfg.n_heads % m == 0 and cfg.n_kv_heads % m == 0


@pytest.mark.parametrize("cfg", [
    None,
    type("Duck", (), {})(),                 # no head fields at all
], ids=["none", "duck"])
def test_candidate_mesh_shapes_headless_keeps_all_factorizations(cfg):
    assert candidate_mesh_shapes(8, cfg) == [(8, 1), (4, 2), (2, 4), (1, 8)]


def test_candidate_mesh_shapes_rwkv_is_headless():
    from repro.configs import ARCHS
    cfg = ARCHS["rwkv6-1.6b"]               # attn_impl='none', n_kv_heads=0
    assert candidate_mesh_shapes(8, cfg) == [(8, 1), (4, 2), (2, 4), (1, 8)]


def test_sharding_cli_handles_headless_arch(capsys):
    # regression: ranking a state-space arch used to trip over the head
    # divisibility filter; the full table must come back for rwkv
    from repro.sharding.cli import main
    rc = main(["--arch", "rwkv6-1.6b", "--devices", "8",
               "--topology", "4,32,128"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "<- best" in out and "replicas=" in out


# ---------------------------------------------------------------------------
# strip_axis + paged_decode_shardings (serving param/pool layouts)
# ---------------------------------------------------------------------------


def test_strip_axis_removes_fsdp_axis_everywhere():
    from jax.sharding import PartitionSpec as P
    specs = {"wq": P("data", "model", None),
             "wo": P(("data", "model"),),
             "norm": P("data"),
             "bias": P(None, "model")}
    out = strip_axis(specs, "data")
    assert out["wq"] == P(None, "model")
    assert out["wo"] == P(("model",))
    assert out["norm"] == P()               # trailing Nones trimmed
    assert out["bias"] == P(None, "model")  # untouched


def test_paged_decode_shardings_single_device_replicates():
    from repro.configs import ARCHS, reduced
    from repro.sharding.plans import paged_decode_shardings
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    mesh = make_host_mesh()                 # (1, 1): nothing to shard
    log = []
    sh = paged_decode_shardings(cfg, mesh, max_batch=4, log=log)
    assert set(sh) == {"pool", "batch", "io", "repl"}
    assert log == []                        # fallbacks only log when real


def test_parse_shapes_round_trip():
    assert parse_shapes("1x1,2x1,4x2") == [(1, 1), (2, 1), (4, 2)]
