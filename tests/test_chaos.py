"""Chaos-serving tests: fault injection, detection, crash-consistent
recovery (``repro.serve.chaos``) plus the satellites that ride along —
the loud ``ClusterStalled`` outcome, the streaming telemetry sink,
heartbeat membership, brownout, and the pool-integrity property test.

The end-to-end drills are EXPENSIVE (each plays a fault-free twin plus a
chaos run under SimClock), so one drill per fault kind is computed
lazily and shared by every test that reads it.
"""
import heapq
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.distributed.fault_tolerance import (HeartbeatRegistry,
                                               RestartPolicy)
from repro.serve.chaos import (ChaosSupervisor, FaultPlan, FaultSpec,
                               FaultyReplica, run_chaos_drill)
from repro.serve.chaos import drill as drill_mod
from repro.serve.cluster.cluster import ClusterStalled
from repro.serve.cluster.metrics import ClusterTelemetry
from repro.serve.engine import _echo_ok
from repro.serve.paging import BlockAllocator
from repro.serve.sim import SimClock, expected_tokens
from repro.serve.telemetry.metrics import (MetricsSink, RequestRecord,
                                           StepRecord, schema_field_names)
from repro.serve.telemetry.slo import SLO, TokenBucket

# one cached drill per fault kind (n_requests=8 is the bench --quick
# shape; the full 12-request grid runs in the campaign / bench)
_DRILLS = {}


def drill(fault, replicas=2):
    key = (fault, replicas)
    if key not in _DRILLS:
        _DRILLS[key] = run_chaos_drill(fault, replicas, n_requests=8)
    return _DRILLS[key]


def _step(i, **kw):
    base = dict(engine="paged", step=i, t_s=float(i), n_active=1,
                queue_depth=0, predicted_s=0.5, predicted_decode_s=0.5,
                measured_s=0.5, decode_ran=True, n_prefill_units=0,
                bottleneck="compute", budget_s=0.0, host_syncs=i,
                table_uploads=0, blocks_in_use=2, n_blocks=8,
                decoded_tokens=i, preemptions=0, deferred=0,
                kernel_splits=1, integrity_failures=0)
    base.update(kw)
    return StepRecord(**base)


# ---------------------------------------------------------------------------
# fault plans + the wrapper
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("meteor", 0, 1)
    with pytest.raises(ValueError):
        FaultSpec("crash", 0, -1)
    with pytest.raises(ValueError):
        FaultSpec("hang", 0, 2, duration=0)
    with pytest.raises(ValueError):
        FaultSpec("hang", 0, 2, factor=1.0)


def test_fault_plan_random_is_replayable():
    a = FaultPlan.random("crash", 3, seed=7)
    b = FaultPlan.random("crash", 3, seed=7)
    assert a == b
    assert len(a.specs) == 1 and a.specs[0].kind == "crash"
    assert 0 <= a.specs[0].replica < 3
    assert 2 <= a.specs[0].at_step < 8
    # the seed is part of the identity
    assert FaultPlan.random("crash", 3, seed=8) != a or True  # may collide
    assert FaultPlan.random("hang", 3, seed=7).specs[0].kind == "hang"


def test_fault_plan_generation_semantics():
    plan = FaultPlan((FaultSpec("crash", 0, 5), FaultSpec("crashloop", 1, 4)))
    # generation 0: every spec on its own replica
    assert plan.for_replica(0, 0) == [FaultSpec("crash", 0, 5)]
    assert plan.for_replica(1, 0) == [FaultSpec("crashloop", 1, 4)]
    assert plan.for_replica(2, 0) == []
    # a restarted replica is healthy — unless it crash-loops, in which
    # case it dies ON STARTUP (at_step=0) so the breaker must trip
    assert plan.for_replica(0, 1) == []
    regen = plan.for_replica(1, 1)
    assert len(regen) == 1 and regen[0].kind == "crashloop"
    assert regen[0].at_step == 0


class _DummyEngine:
    def __init__(self):
        self.queue = []
        self._pending = None
        self.knob = 1
        self.steps = 0

    def step(self):
        self.steps += 1
        return 1


def test_faulty_replica_delegates_and_crashes():
    eng = _DummyEngine()
    rep = FaultyReplica(eng, [FaultSpec("crash", 0, 2)])
    # reads AND writes reach the engine
    assert rep.knob == 1
    rep.knob = 7
    assert eng.knob == 7
    rep._pending = "x"
    assert eng._pending == "x"
    # two healthy steps, then the process is gone
    assert rep.step() == 1 and rep.step() == 1
    assert rep.step() == 0 and rep.crashed
    assert rep.step() == 0
    assert eng.steps == 2            # the engine is never touched again
    assert ("crash", 2) in rep.injected


def test_faulty_replica_hang_scales_wall():
    eng = _DummyEngine()
    rep = FaultyReplica(eng, [FaultSpec("hang", 0, 1, duration=2,
                                        factor=6.0)])
    rep.step()
    assert rep.wall_scale == 1.0
    rep.step()
    assert rep.wall_scale == 6.0     # inside the hang window
    rep.step()
    assert rep.wall_scale == 6.0
    rep.step()
    assert rep.wall_scale == 1.0     # window over, healthy again


def test_echo_ok_flags_poisoned_tokens():
    good = np.zeros((2, 4), np.int32)
    assert _echo_ok(good)
    bad = good.copy()
    bad[1, :] = -1
    assert not _echo_ok(bad)


# ---------------------------------------------------------------------------
# the end-to-end drills (tentpole proof)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fault,kind", [("crash", "dead"),
                                        ("hang", "straggler"),
                                        ("corrupt", "corrupt")])
def test_drill_recovers_crash_consistently(fault, kind):
    m = drill(fault)
    assert m["failures"] >= 1
    assert kind in m["failure_kinds"].split(",")
    # the recovery invariants the campaign/CI gate on
    assert m["survivors_identical"]
    assert m["all_accounted"]
    assert m["tokens_lost"] == 0
    assert m["blocks_leaked"] == 0
    # the replica warm-rejoined: detection -> rejoin latency is real
    assert m["recovery_latency_s"] > 0
    assert m["live_replicas"] == m["replicas"]
    assert not m["quarantined"]


def test_drill_crash_reclaims_and_resubmits():
    m = drill("crash")
    # the dead replica was carrying work: it was reclaimed and re-placed
    # (or loudly abandoned), never silently lost
    assert m["reclaimed"] >= 1
    assert m["recovered"] + m["abandoned"] >= 1
    assert m["completed"] + m["abandoned"] >= m["admitted"]


def test_drill_crashloop_is_quarantined():
    m = drill("crashloop")
    # the breaker (crash_loop_limit=3) trips on the 4th death
    assert m["failures"] >= 4
    assert m["quarantined"]
    # quarantine means degraded, not broken: every surviving token exact
    assert m["survivors_identical"]
    assert m["all_accounted"]
    assert m["tokens_lost"] == 0 and m["blocks_leaked"] == 0
    assert m["live_replicas"] == m["replicas"] - 1


def test_drill_replays_byte_for_byte():
    again = run_chaos_drill("crash", 2, n_requests=8)
    assert again == drill("crash")


# ---------------------------------------------------------------------------
# satellite: run_until_done stalls loudly
# ---------------------------------------------------------------------------

def test_run_until_done_raises_cluster_stalled():
    """A fault-wrapped replica that stops making progress must not let
    ``run_until_done`` return as if it drained.  (A ``hang`` fault only
    inflates the PRICED wall — the engine still steps — so the fault
    that actually wedges the loop is a crash: step() returns 0 forever
    and the queue freezes.)"""
    clock = SimClock()
    plan = FaultPlan((FaultSpec("crash", 0, 0),))   # dead on arrival
    cluster, _ = drill_mod._build(1, clock, plan=plan)
    crid = cluster.submit(np.arange(4, dtype=np.int32), max_new_tokens=4)
    assert crid is not None
    with pytest.raises(ClusterStalled) as ei:
        cluster.run_until_done(max_steps=8)
    e = ei.value
    assert e.steps == 8 and e.in_flight == 1 and e.queued == 1
    assert "stalled" in str(e)
    # the silent escape hatch for inspecting the wreckage
    assert cluster.run_until_done(max_steps=3, raise_on_stall=False) == 0
    assert cluster.router.in_flight == 1


# ---------------------------------------------------------------------------
# satellite: heartbeat membership + restart breaker
# ---------------------------------------------------------------------------

def test_registry_register_deregister():
    reg = HeartbeatRegistry(interval_s=1.0, miss_limit=3)
    with pytest.raises(KeyError):
        reg.beat("a", now=0.0)       # membership is explicit
    reg.register("a", now=100.0)
    # a just-joined host is not instantly dead off a zero last_heartbeat
    assert reg.sweep(now=100.5) == []
    reg.beat("a", 0.5, now=101.0)
    assert reg.alive_hosts() == ["a"]
    reg.deregister("a")
    assert reg.alive_hosts() == []
    with pytest.raises(KeyError):
        reg.beat("a", now=102.0)
    reg.deregister("a")              # no-op if absent
    # re-register under a fresh identity: clean EWMA, beating again
    reg.register("a", now=200.0)
    reg.beat("a", 0.5, now=200.5)
    assert reg.alive_hosts() == ["a"]
    # the fixed-fleet constructor still works
    assert set(HeartbeatRegistry(["x", "y"]).hosts) == {"x", "y"}


def test_registry_abs_limit_flags_straggler_at_two_hosts():
    reg = HeartbeatRegistry(interval_s=1.0, miss_limit=3)
    reg.register("fast", now=0.0)
    reg.register("slow", now=0.0)
    for t in range(1, 5):
        reg.beat("fast", 0.1, now=float(t))
        reg.beat("slow", 5.0, now=float(t))
    # MAD alone cannot vote with two hosts...
    assert reg.stragglers(z_threshold=4.0) == []
    # ...the absolute ceiling can
    assert reg.stragglers(z_threshold=4.0, abs_limit_s=1.0) == ["slow"]


def test_restart_policy_breaker_trips():
    pol = RestartPolicy(backoff_base_s=1.0, backoff_cap_s=60.0,
                        crash_loop_limit=3)
    assert pol.on_failure(now=0.0) == 1.0
    assert pol.on_failure(now=1.0) == 2.0
    assert pol.on_failure(now=2.0) == 4.0
    assert pol.on_failure(now=3.0) is None   # quarantine


# ---------------------------------------------------------------------------
# satellite: streaming telemetry
# ---------------------------------------------------------------------------

def test_sink_streams_past_ring_capacity(tmp_path):
    path = tmp_path / "stream.jsonl"
    sink = MetricsSink(capacity=2, stream_path=path)
    for i in range(5):
        sink.record_step(_step(i))
    sink.record_request(RequestRecord("paged", 0, 0.0, 1.0, 1.0, 4, 4))
    sink.stream_note({"record": "fault", "kind": "dead"})
    # the ring forgot, the stream did not
    assert len(sink.steps()) == 2
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert [x["record"] for x in lines] == ["step"] * 5 + ["request",
                                                          "fault"]
    assert [x["step"] for x in lines[:5]] == list(range(5))
    sink.close_stream()
    sink.record_step(_step(9))       # closed stream: ring only, no error
    assert len(path.read_text().splitlines()) == 7


def test_sink_stream_redirect_and_off_mode(tmp_path):
    sink = MetricsSink(capacity=4)
    sink.record_step(_step(0))       # no stream: pure ring, no file I/O
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    sink.open_stream(a)
    sink.record_step(_step(1))
    sink.open_stream(b)              # redirect closes the old stream
    sink.record_step(_step(2))
    assert json.loads(a.read_text())["step"] == 1
    assert json.loads(b.read_text())["step"] == 2
    assert sink.stream_path == b


def test_cluster_telemetry_tags_and_rebinds(tmp_path):
    tel = ClusterTelemetry(2, stream_dir=tmp_path)
    tel.sinks[0].record_request(RequestRecord("paged", 0, 0.0, 1.0,
                                              1.0, 4, 4))
    tel.tag_dead(0, 3.5, "dead")
    g0 = [json.loads(x) for x in
          (tmp_path / "replica_0.jsonl").read_text().splitlines()]
    assert g0[-1] == {"record": "fault", "replica": 0, "t_s": 3.5,
                      "kind": "dead"}
    old_sink = tel.sinks[0]
    ctrl = tel.rebind(0)
    assert ctrl is tel.controllers[0]
    assert tel.sinks[0] is not old_sink
    assert tel.retired == [(0, old_sink)]
    # the rejoined incarnation streams to its own generation file
    tel.sinks[0].record_request(RequestRecord("paged", 1, 2.0, 4.0,
                                              2.0, 4, 4))
    g1_path = tmp_path / "replica_0.g1.jsonl"
    assert json.loads(g1_path.read_text())["rid"] == 1
    # merged views count the dead incarnation's records
    s = tel.summary()
    assert s["requests"] == 2
    assert s["faults"] == [{"replica": 0, "t_s": 3.5, "kind": "dead"}]
    assert sorted(tel.request_latencies()) == [1.0, 2.0]
    out = tel.export_jsonl(tmp_path / "all.jsonl")
    recs = [json.loads(x) for x in out.read_text().splitlines()]
    assert [r["record"] for r in recs] == ["request", "request", "fault"]
    assert all(r["replica"] == 0 for r in recs)


def test_step_schema_carries_integrity_probe():
    assert "integrity_failures" in schema_field_names()


# ---------------------------------------------------------------------------
# brownout + supervisor bookkeeping
# ---------------------------------------------------------------------------

def test_token_bucket_tighten():
    b = TokenBucket(SLO(target_p99_s=8.0))
    r0 = b.rate_s
    b.tokens_s = b.burst_s           # full bucket, then brownout
    assert b.tighten(0.5) == pytest.approx(r0 / 2)
    # spill above the NEW burst ceiling is clipped immediately
    assert b.tokens_s == pytest.approx(b.burst_s)
    assert b.rate_trace == [b.rate_s]
    with pytest.raises(ValueError):
        b.tighten(0.0)
    with pytest.raises(ValueError):
        b.tighten(1.5)
    # the floor holds under repeated brownouts
    for _ in range(80):
        b.tighten(0.5)
    assert b.rate_s == pytest.approx(SLO(target_p99_s=8.0).min_rate_s)


def test_supervisor_failure_brownouts_survivors():
    clock = SimClock()
    tel = ClusterTelemetry(2, slo=SLO(target_p99_s=8.0))
    cluster, _ = drill_mod._build(2, clock, plan=None, telemetry=tel)
    sup = ChaosSupervisor(cluster, clock)
    r0 = tel.controllers[1].bucket.rate_s
    rec = sup._fail(0, "dead", clock.time())
    # the survivor's admission rate is cut to surviving capacity
    assert tel.controllers[1].bucket.rate_s == pytest.approx(r0 / 2)
    assert cluster.router.live_indices() == [1]
    assert sup.failures == [rec]
    assert rec.kind == "dead" and rec.generation == 0
    assert rec.recovery_s is None            # no engine_factory: stays down
    assert not rec.quarantined
    assert tel.faults == [{"replica": 0, "t_s": 0.0, "kind": "dead"}]
    assert sup.idle                          # nothing to retry or rejoin
    # the dead host left membership: its beats would now be a KeyError
    assert sup.registry.alive_hosts() == ["replica-1.g0"]


# ---------------------------------------------------------------------------
# router recovery seam (reclaim / resubmit / abandon)
# ---------------------------------------------------------------------------

def test_router_reclaim_resubmit_preserves_tokens():
    clock = SimClock()
    cluster, _ = drill_mod._build(2, clock, plan=None)
    router = cluster.router
    prompts = [np.arange(1, 5 + i, dtype=np.int32) for i in range(4)]
    crids = [cluster.submit(p, max_new_tokens=4) for p in prompts]
    assert all(c is not None for c in crids)
    for _ in range(2):               # let some requests reach the rows
        cluster.step()
    victims = [c for c in crids if router._local[c][0] == 0]
    assert victims, "cost-aware placement left replica 0 empty"
    router.set_live(0, False)
    reclaimed = router.reclaim_replica(0)
    assert sorted(c for c, _ in reclaimed) == sorted(victims)
    survivors = [c for c in crids if c not in victims]
    if survivors:                    # a tracked crid must be reclaimed first
        with pytest.raises(ValueError):
            router.resubmit(survivors[0], reclaimed[0][1])
    for crid, req in reclaimed:
        assert router.resubmit(crid, req)
    assert router.stats.recovered == len(reclaimed)
    cluster.run_until_done(max_steps=400)
    router.assert_drained()
    for crid, p in zip(crids, prompts):
        assert list(router.done[crid].tokens) == expected_tokens(
            list(p), 4, drill_mod.VOCAB)


def test_router_total_outage_sheds_and_abandons():
    clock = SimClock()
    cluster, _ = drill_mod._build(2, clock, plan=None)
    router = cluster.router
    crids = [cluster.submit(np.arange(4, dtype=np.int32), max_new_tokens=4)
             for _ in range(2)]
    router.set_live(0, False)
    router.set_live(1, False)
    # admission at the door: shed
    assert cluster.submit(np.arange(4, dtype=np.int32)) is None
    assert router.stats.shed == 1
    # reclaimed with nowhere to go: resubmit says so, abandon is loud
    reclaimed = router.reclaim_replica(0) + router.reclaim_replica(1)
    assert sorted(c for c, _ in reclaimed) == sorted(crids)
    for crid, req in reclaimed:
        assert not router.resubmit(crid, req)
        router.abandon(crid)
    assert router.stats.abandoned == 2
    router.assert_drained()


# ---------------------------------------------------------------------------
# satellite: pool integrity under fault storms (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 63)),
                min_size=1, max_size=80))
def test_pool_integrity_under_fault_storm(ops):
    """Random admit / evict / compact / crash-reclaim sequences never
    break the free-set-partitions-the-pool invariant, and a full reclaim
    leaks nothing — the allocator-side half of the drill's
    ``blocks_leaked == 0`` gate."""
    alloc = BlockAllocator(24, 8)
    held = []
    for op, k in ops:
        if op == 0:                          # admit: one block for a row
            b = alloc.alloc()
            if b is not None:
                held.append(b)
        elif op == 1 and held:               # evict one victim's block
            alloc.free([held.pop(k % len(held))])
        elif op == 2 and held:               # compaction: free + realloc
            alloc.free([held.pop(k % len(held))])
            b = alloc.alloc()
            if b is not None:
                held.append(b)
        elif op == 3 and held:               # replica death: reclaim all
            alloc.free(held)
            held = []
        alloc.check()
        assert alloc.n_in_use == len(held)
        assert alloc.n_free == alloc.n_blocks - len(held)
    alloc.free(held)
    alloc.check()
    assert alloc.n_in_use == 0


def test_pool_poison_is_caught():
    alloc = BlockAllocator(8, 4)
    a, b = alloc.alloc(), alloc.alloc()
    # a poisoned free list (an allocated id pushed back) fails the audit
    heapq.heappush(alloc._free, a)
    with pytest.raises(AssertionError):
        alloc.check()
    alloc._free.remove(a)
    heapq.heapify(alloc._free)
    alloc.check()
    # double-free and foreign ids are loud at the free() door
    alloc.free([b])
    with pytest.raises(ValueError):
        alloc.free([b])
    with pytest.raises(ValueError):
        alloc.free([999])
