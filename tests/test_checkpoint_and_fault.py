import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.distributed.elastic import plan_downsize
from repro.distributed.fault_tolerance import (FaultTolerantRunner,
                                               HeartbeatRegistry,
                                               RestartPolicy)


def _state(v):
    return {"w": jnp.full((4, 4), float(v)), "step": jnp.asarray(v)}


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(5, _state(5))
    step, got = m.restore_latest(like=_state(0))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["w"]), 5.0)


def test_latest_pointer_and_retention(tmp_path):
    m = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        m.save(s, _state(s))
    assert m.latest_step() == 4
    assert m.all_steps() == [3, 4]   # pruned to keep=2


def test_async_save_blocks_correctly(tmp_path):
    m = CheckpointManager(tmp_path, async_save=True)
    m.save(7, _state(7))
    m.wait()
    assert m.latest_step() == 7


def test_crashed_save_never_visible(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(1, _state(1))
    # simulate a crash mid-save: stray tmp dir with partial contents
    d = tmp_path / ".tmp_save_dead"
    d.mkdir()
    (d / "shard_00000.npy").write_bytes(b"garbage")
    assert m.latest_step() == 1
    step, got = m.restore_latest(like=_state(0))
    assert step == 1


def test_data_resume_determinism():
    cfg = DataConfig(vocab_size=97, seq_len=8, global_batch=2, seed=3)
    a = SyntheticLM(cfg)
    b = SyntheticLM(cfg)
    batches_a = [a.batch(i) for i in range(6)]
    batches_b = [b.batch(i) for i in range(3, 6)]   # "restart" at step 3
    for i, bb in enumerate(batches_b):
        np.testing.assert_array_equal(batches_a[3 + i]["tokens"],
                                      bb["tokens"])


def test_heartbeat_death_detection():
    reg = HeartbeatRegistry(["a", "b"], interval_s=1.0, miss_limit=3)
    t0 = 1000.0
    reg.beat("a", 0.1, now=t0)
    reg.beat("b", 0.1, now=t0)
    dead = []
    for i in range(1, 5):
        reg.beat("a", 0.1, now=t0 + i)
        dead += reg.sweep(now=t0 + i)
    assert dead == ["b"]
    assert reg.alive_hosts() == ["a"]


def test_straggler_detection():
    hosts = [f"h{i}" for i in range(8)]
    reg = HeartbeatRegistry(hosts)
    for _ in range(10):
        for h in hosts:
            reg.beat(h, 1.0 if h != "h3" else 3.0)
    assert reg.stragglers() == ["h3"]


def test_restart_policy_backoff_and_crashloop():
    p = RestartPolicy(backoff_base_s=1.0, crash_loop_limit=3, window_s=100)
    t = 0.0
    b1 = p.on_failure(now=t)
    b2 = p.on_failure(now=t + 1)
    b3 = p.on_failure(now=t + 2)
    assert (b1, b2, b3) == (1.0, 2.0, 4.0)
    assert p.on_failure(now=t + 3) is None       # crash loop broken
    assert p.on_failure(now=t + 500) is not None  # window expired -> retry


def test_fault_runner_emits_events():
    reg = HeartbeatRegistry(["a", "b"], interval_s=1.0, miss_limit=2)
    r = FaultTolerantRunner(reg)
    t0 = 0.0
    r.on_step("a", 0, 0.5, now=t0)
    r.on_step("b", 0, 0.5, now=t0)
    evs = []
    for i in range(1, 4):
        evs += r.on_step("a", i, 0.5, now=t0 + i)
    kinds = [(e.kind, e.host) for e in evs]
    assert ("dead_host", "b") in kinds


def test_elastic_downsize_plan():
    data, total = plan_downsize(512, model_axis=16, global_batch=256)
    assert (data, total) == (32, 512)
    data, total = plan_downsize(496, model_axis=16, global_batch=256)
    assert data * 16 <= 496 and 256 % data == 0
    with pytest.raises(RuntimeError):
        plan_downsize(8, model_axis=16)
