"""Degraded stand-in for `hypothesis` when the `test` extra isn't installed.

Test modules guard their import like::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:          # degrade: property tests skip, the rest run
        from _hypothesis_stub import given, settings, st

With the real package absent, every ``@given`` test calls
``pytest.importorskip("hypothesis")`` at run time — reported as a skip with
an install hint — while plain unit tests in the same module keep running.
That turns the seed suite's three collection *errors* into a handful of
skips (install with ``pip install -e .[test]`` to run everything).
"""
from __future__ import annotations

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        # no functools.wraps: __wrapped__ would make pytest resolve the
        # original (strategy-fed) parameters as fixtures
        def skipper():
            pytest.importorskip(
                "hypothesis",
                reason="property test needs hypothesis "
                       "(pip install -e .[test])")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategy:
    """Inert placeholder so strategy expressions at module scope evaluate."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


class _Strategies:
    def __getattr__(self, name):
        return _Strategy()


st = strategies = _Strategies()
