"""Paged vs slot serving on the real (reduced) model, plus property tests
over the block allocator and random request traces.

The acceptance trace: 32 mixed-length requests through both engines with
the paged pool sized strictly below the slot engine's
``max_batch x max_len`` rectangle — identical greedy token ids, strictly
fewer resident KV bytes, leak-free teardown.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.configs import ARCHS, reduced
from repro.models.zoo import build_model
from repro.serve import PagedServingEngine, ServingEngine
from repro.serve.paging import BlockAllocator, remap_table


@functools.lru_cache(maxsize=None)
def _tiny():
    """Module-cached tiny model (lru_cache, not a fixture, so hypothesis
    can draw examples without fixture-scope health checks)."""
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _run_slot(model, params, prompts, max_new, max_batch=4, max_len=48):
    eng = ServingEngine(model, params, max_batch=max_batch, max_len=max_len)
    rids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_until_done()
    return eng, rids


def _run_paged(model, params, prompts, max_new, max_batch=4, max_len=48,
               **kw):
    eng = PagedServingEngine(model, params, max_batch=max_batch,
                             max_len=max_len, **kw)
    rids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_until_done(max_steps=20_000)
    return eng, rids


# ---------------------------------------------------------------------------
# the acceptance trace
# ---------------------------------------------------------------------------


def test_paged_engine_32_request_trace_identical_in_less_memory():
    """The ISSUE's acceptance bar: a 32-request mixed-length trace, KV
    memory strictly under the slot engine's, identical greedy tokens,
    simulation-verified leak-free teardown."""
    cfg, model, params = _tiny()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(1, 31))).astype(np.int32)
               for _ in range(32)]

    slot, rids_s = _run_slot(model, params, prompts, max_new=4)
    # 10 blocks of 8 tokens vs the slot rectangle's 4 x 48 = 24 blocks
    paged, rids_p = _run_paged(model, params, prompts, max_new=4,
                               block_size=8, n_blocks=10, chunk_size=8)

    assert paged.stats.completed == 32
    assert slot.stats.completed == 32
    assert paged.kv_cache_bytes() < slot.kv_cache_bytes()
    for rs, rp in zip(rids_s, rids_p):
        assert slot.done[rs].tokens == paged.done[rp].tokens, (rs, rp)
    paged.allocator.check()
    assert paged.allocator.n_free == paged.n_blocks   # block-leak free


def test_preemption_under_minimal_pool_still_identical():
    """The smallest legal pool (one max_len sequence) forces eviction
    churn; replayed requests must still produce the slot engine's
    tokens."""
    cfg, model, params = _tiny()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(1, 28))).astype(np.int32)
               for _ in range(8)]
    slot, rids_s = _run_slot(model, params, prompts, max_new=5)
    paged, rids_p = _run_paged(model, params, prompts, max_new=5,
                               block_size=8, n_blocks=6, chunk_size=8)
    assert paged.stats.completed == 8
    assert paged.stats.preemptions > 0
    for rs, rp in zip(rids_s, rids_p):
        assert slot.done[rs].tokens == paged.done[rp].tokens
    assert paged.allocator.n_free == paged.n_blocks


def test_compaction_off_still_correct():
    cfg, model, params = _tiny()
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(s)).astype(np.int32)
               for s in rng.integers(1, 20, size=6)]
    slot, rids_s = _run_slot(model, params, prompts, max_new=4)
    paged, rids_p = _run_paged(model, params, prompts, max_new=4,
                               block_size=8, n_blocks=12, chunk_size=8,
                               compact_on_retire=False)
    assert paged.stats.compactions == 0
    for rs, rp in zip(rids_s, rids_p):
        assert slot.done[rs].tokens == paged.done[rp].tokens


def test_paged_engine_rejects_unpageable_archs():
    cfg = reduced(ARCHS["rwkv6-1.6b"])
    model = build_model(cfg)
    with pytest.raises(NotImplementedError):
        model.init_paged_cache(4, 8)


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 24),
       st.lists(st.tuples(st.booleans(), st.integers(0, 23)),
                max_size=120))
def test_allocator_never_double_allocates_and_frees_everything(n_blocks,
                                                               script):
    """Random alloc/free interleavings: every handed-out id is unique
    among live blocks, the pool partition invariant holds throughout, and
    freeing all live blocks restores the full pool."""
    alloc = BlockAllocator(n_blocks, block_size=4)
    live = []
    for do_alloc, pick in script:
        if do_alloc:
            b = alloc.alloc()
            if b is None:
                assert len(live) == n_blocks     # only fails when full
            else:
                assert b not in live             # never double-allocated
                live.append(b)
        elif live:
            b = live.pop(pick % len(live))
            alloc.free([b])
        alloc.check()
    alloc.free(live)
    alloc.check()
    assert alloc.n_free == n_blocks              # retire frees every block


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 20), st.data())
def test_compaction_plan_densifies_and_remap_is_consistent(n_blocks, data):
    alloc = BlockAllocator(n_blocks, block_size=4)
    blocks = [alloc.alloc() for _ in range(n_blocks)]
    keep = data.draw(st.sets(st.sampled_from(blocks),
                             max_size=n_blocks - 1))
    alloc.free([b for b in blocks if b not in keep])
    plan = alloc.compaction_plan()
    table = sorted(keep) + [-1]
    if plan is None:
        assert sorted(keep) == list(range(len(keep)))    # already dense
        return
    src, dst = plan
    new_table = remap_table(table, src, dst)
    alloc.commit_compaction()
    alloc.check()
    # dense: the kept blocks now occupy exactly [0, len(keep))
    assert sorted(b for b in new_table if b >= 0) == list(range(len(keep)))
    assert new_table[-1] == -1                   # unbacked slots untouched
    assert alloc.watermark() == len(keep)


# ---------------------------------------------------------------------------
# trace property: paged == slot for random traces
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(2, 6),
       st.sampled_from([4, 8]),
       st.sampled_from([4, 8]))
def test_paged_matches_slot_on_random_traces(seed, n_req, block_size,
                                             chunk_size):
    """Greedy decode is deterministic, so for ANY trace the paged engine
    must reproduce the slot engine's token ids exactly — chunk/page size
    are implementation detail, not semantics."""
    cfg, model, params = _tiny()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(1, 25))).astype(np.int32)
               for _ in range(n_req)]
    slot, rids_s = _run_slot(model, params, prompts, max_new=4,
                             max_batch=3)
    paged, rids_p = _run_paged(model, params, prompts, max_new=4,
                               max_batch=3, block_size=block_size,
                               n_blocks=-(-48 // block_size) + 3,
                               chunk_size=chunk_size)
    for rs, rp in zip(rids_s, rids_p):
        assert slot.done[rs].tokens == paged.done[rp].tokens
    paged.allocator.check()
    assert paged.allocator.n_free == paged.n_blocks


def test_decode_chunk_equals_prefill_logits():
    """The chunked-prefill primitive itself: feeding a prompt through the
    decode path in chunks (with overlap and left-padding) must yield the
    prefill path's next-token distribution argmax."""
    cfg, model, params = _tiny()
    rng = np.random.default_rng(3)
    for S, C in [(1, 4), (3, 4), (4, 4), (9, 4), (13, 8)]:
        prompt = rng.integers(0, cfg.vocab_size, size=S).astype(np.int32)
        logits_p, _ = model.prefill(params, {"tokens": prompt[None]},
                                    max_len=32)
        want = int(jnp.argmax(logits_p[0]))

        cache = model.init_paged_cache(8, 4)
        bt = jnp.arange(8, dtype=jnp.int32)[None]
        filled, logits = 0, None
        while filled < S:
            end = min(filled + C, S)
            start = end - C
            toks = np.zeros(C, np.int32)
            lo = max(start, 0)
            toks[C - (end - lo):] = prompt[lo:end]
            logits, cache = model.decode(
                params, cache, jnp.asarray(toks[None]),
                jnp.asarray([start], jnp.int32), bt)
            filled = end
        assert int(jnp.argmax(logits[0])) == want, (S, C)
