"""Teacher-forcing equivalence: prefill + step-by-step decode must reproduce
the full-sequence forward logits (the KV cache's correctness contract).

MoE archs use a high capacity factor here: capacity-based token dropping is
sequence-dependent by construction (train drops, decode doesn't), which is
the documented paper-faithful behaviour; with no drops the paths agree."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import encdec as ed_mod
from repro.models import transformer as lm_mod
from repro.models.zoo import build_model

ALL = sorted(ARCHS)


def _prep(arch):
    cfg = reduced(ARCHS[arch])
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ALL)
def test_decode_matches_full_forward(arch):
    cfg = _prep(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S0, T = 2, 12, 3
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S0 + T), 0, cfg.vocab_size, jnp.int32)
    base = {}
    pfx = cfg.meta_tokens or 0
    if cfg.encdec:
        base["frames"] = jax.random.normal(
            key, (B, S0, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        base["prefix_embeds"] = jax.random.normal(
            key, (B, 4, cfg.d_model)).astype(jnp.bfloat16)
        pfx += 4

    if cfg.encdec:
        full, _, _ = ed_mod.encdec_apply(params, cfg, tokens=toks,
                                         frames=base["frames"], mode="train",
                                         remat=False)
    else:
        full, _, _ = lm_mod.lm_apply(params, cfg, tokens=toks, mode="train",
                                     prefix_embeds=base.get("prefix_embeds"),
                                     remat=False)
    full = np.asarray(full, np.float32)
    scale = max(np.abs(full).max(), 1.0)

    pb = dict(base)
    pb["tokens"] = toks[:, :S0]
    lg, cache = model.prefill(params, pb, max_len=S0 + T + pfx)
    errs = [np.abs(np.asarray(lg) - full[:, S0 - 1]).max()]
    for t in range(T):
        pos = jnp.full((B,), pfx + S0 + t, jnp.int32)
        lg, cache = model.decode(params, cache, toks[:, S0 + t][:, None], pos)
        errs.append(np.abs(np.asarray(lg) - full[:, S0 + t]).max())
    assert max(errs) < 0.05 * scale, f"divergence {max(errs)} vs {scale}"
