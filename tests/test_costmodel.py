"""Unified cost-model subsystem: calibration loaders (all three formats +
canonical round-trip), the three layers' invariants (monotonicity, dtype
ordering, dependent>=independent), defaulted-op tracking, the
prediction-error fixture against the shipped calibrations, plan ranking,
and the measurement-free CLI."""
import json

import pytest

from repro.core.costmodel import (CostModel, Calibration, load_calibration,
                                  prediction_error_rows,
                                  prediction_error_summary, save_calibration)
from repro.core.costmodel import cli as costmodel_cli
from repro.core.microbench import tables
from repro.core.perfmodel.hardware import A100_40G, TPU_V5E

BASE_CENSUS = {
    "flops": 1e12,
    "hbm_bytes": 1e9,
    "collective_bytes_total": 1e8,
    "op_histogram": {"fusion": 100.0, "dot": 10.0, "add": 50.0,
                     "multiply": 20.0, "tanh": 5.0},
}


@pytest.fixture(scope="module", params=["ampere_a100", "tpu_v5e"])
def shipped(request):
    return request.param, CostModel.from_named(request.param)


# ---------------------------------------------------------------------------
# loaders + round-trip
# ---------------------------------------------------------------------------

def test_shipped_calibrations_normalize(shipped):
    name, model = shipped
    assert model.cal.instructions, name
    assert model.cal.clock_hz > 1e8
    assert model.memory.bandwidth_bps > 0
    assert model.mxu.throughput("bf16") > 0


def test_canonical_round_trip_dict(shipped):
    _, model = shipped
    doc = model.cal.to_dict()
    again = Calibration.from_dict(doc)
    assert again.to_dict() == doc


def test_round_trip_through_file_preserves_predictions(tmp_path, shipped):
    name, model = shipped
    path = save_calibration(model.cal, tmp_path / f"{name}.json")
    reloaded = CostModel(load_calibration(path), hw=model.hw)
    a = model.predict(BASE_CENSUS)
    b = reloaded.predict(BASE_CENSUS)
    assert a.step_s == pytest.approx(b.step_s, rel=1e-9)
    assert a.issue_overhead_s == pytest.approx(b.issue_overhead_s, rel=1e-9)
    assert a.defaulted_ops == b.defaulted_ops


def test_campaign_table_loader_converts_ns_to_cycles():
    table = {
        "schema_version": 1, "hardware": "cpu",
        "ops": {"add.float32.dep": {"per_op_ns": 2.0, "overhead_ns": 0.0},
                "add.float32.ind": {"per_op_ns": 1.0, "overhead_ns": 0.0}},
        "memory": {"16384": {"per_hop_ns": 7.5, "overhead_ns": 0.0}},
        "memory_streaming": {"16KiB": {"gbps": 10.0}},
        "mxu": {"float32.m128n128k128.ind":
                {"per_op_us": 1.0, "tflops": 4.0}},
        "vpu": {}, "roofline": {},
    }
    cal = Calibration.from_dict(table)   # default 1 GHz clock
    assert cal.instructions["add.f32"].dependent_cycles == pytest.approx(2.0)
    assert cal.instructions["add.f32"].independent_cycles == pytest.approx(1.0)
    assert cal.memory_levels[0].latency_ns == pytest.approx(7.5)
    assert cal.bandwidth_bps == pytest.approx(10e9)
    m = CostModel(cal, hw=TPU_V5E)
    assert m.mxu.throughput("f32", (128, 128, 128)) == pytest.approx(4e12)


def test_degenerate_zero_rate_mxu_point_does_not_crash():
    """A failed MXU probe (tflops=0.0) must not become a zero peak and
    divide-by-zero the predictor."""
    table = {
        "schema_version": 1, "hardware": "cpu",
        "ops": {"add.float32.dep": {"per_op_ns": 2.0, "overhead_ns": 0.0}},
        "memory": {}, "mxu": {"bfloat16.m128n128k128.ind":
                              {"per_op_us": 0.0, "tflops": 0.0}},
        "vpu": {}, "roofline": {},
    }
    m = CostModel(Calibration.from_dict(table), hw=TPU_V5E)
    p = m.predict(BASE_CENSUS, dtype="bf16")
    assert p.compute_s > 0 and p.step_s > 0


def test_unknown_format_raises():
    with pytest.raises(ValueError, match="unrecognized calibration"):
        Calibration.from_dict({"bogus": 1})


def test_load_calibration_unknown_name():
    with pytest.raises(FileNotFoundError):
        load_calibration("no_such_calibration")


# ---------------------------------------------------------------------------
# the prediction-error fixture (acceptance: within 10% on shipped tables)
# ---------------------------------------------------------------------------

def test_prediction_error_within_10pct(shipped):
    name, model = shipped
    rows = prediction_error_rows(model)
    assert rows, name
    s = prediction_error_summary(rows)
    bad = [r for r in rows if r["err_pct"] > 10.0]
    assert s["max_err_pct"] <= 10.0, bad


def test_prediction_error_table_renders():
    from repro.core.campaign import report
    rows = report.prediction_error_table(tables.ampere_table(),
                                         name="ampere_a100")
    names = [r[0] for r in rows]
    assert any(n.startswith("prederr/instr/") for n in names)
    assert any(n.startswith("prederr/mxu/") for n in names)
    assert names[-1] == "prederr/summary"
    assert "max_err_pct=" in rows[-1][2]


# ---------------------------------------------------------------------------
# layer invariants
# ---------------------------------------------------------------------------

def test_defaulted_ops_tracked_not_silently_priced(shipped):
    _, model = shipped
    census = dict(BASE_CENSUS)
    census["op_histogram"] = {**BASE_CENSUS["op_histogram"],
                              "transpose": 7.0, "reshape": 3.0,
                              "iota": 2.0, "rng": 1.0}
    p = model.predict(census)
    # layout/data-movement kinds must surface as gaps, not price as 'add'
    assert p.defaulted_ops.get("transpose") == 7.0
    assert p.defaulted_ops.get("reshape") == 3.0
    assert p.defaulted_op_count >= 13.0
    # genuinely arithmetic kinds are mapped (and dot is MXU-priced, not a gap)
    assert "add" not in p.defaulted_ops
    assert "dot" not in p.defaulted_ops
    assert p.mapped_op_count > 0


def test_issue_monotonic_in_instruction_count(shipped):
    _, model = shipped
    base = model.predict(BASE_CENSUS)
    more = dict(BASE_CENSUS)
    more["op_histogram"] = {k: v * 3 for k, v
                            in BASE_CENSUS["op_histogram"].items()}
    more["op_histogram"]["transpose"] = 50.0
    grown = model.predict(more)
    assert grown.issue_overhead_s >= base.issue_overhead_s
    assert grown.step_s >= base.step_s


def test_compute_monotonic_in_flops(shipped):
    _, model = shipped
    lo = model.predict(dict(BASE_CENSUS, flops=1e10))
    hi = model.predict(dict(BASE_CENSUS, flops=1e13))
    assert hi.compute_s >= lo.compute_s
    assert hi.step_s >= lo.step_s


def test_mxu_dtype_ordering(shipped):
    """f32 must never be faster than bf16 on the matrix unit (paper
    Table III ordering), for measured, target, and spec-only models."""
    _, model = shipped
    assert model.mxu.time_for_flops(1e12, "f32") >= \
        model.mxu.time_for_flops(1e12, "bf16")


def test_mxu_dtype_ordering_spec_only():
    for hw in (TPU_V5E, A100_40G):
        m = CostModel.from_hardware(hw)
        assert m.mxu.time_for_flops(1e12, "f32") >= \
            m.mxu.time_for_flops(1e12, "bf16")


def test_instruction_dependent_ge_independent():
    model = CostModel.from_named("ampere_a100")
    for e in model.cal.instructions.values():
        assert e.dependent_cycles >= e.independent_cycles, e


def test_memory_layer_hierarchy():
    model = CostModel.from_named("tpu_v5e")
    small = model.memory.access_latency_ns(1024)           # VMEM-resident
    big = model.memory.access_latency_ns(8 * 2**30)        # HBM-resident
    assert small < big
    assert model.memory.transfer_seconds(2**30) == pytest.approx(
        2**30 / model.memory.bandwidth_bps)


def test_validate_against_paper_consistency():
    from repro.core.costmodel import validate_against_paper
    checks = validate_against_paper(tables.ampere_table())
    assert all(checks.values()), \
        {k: v for k, v in checks.items() if not v}


# ---------------------------------------------------------------------------
# plan ranking
# ---------------------------------------------------------------------------

def test_rank_plans_sorted_and_complete():
    from repro.configs import ARCHS, SHAPE_CELLS
    from repro.sharding.plans import rank_plans
    cfg = ARCHS["gemma2-2b"]
    plans = rank_plans(cfg, SHAPE_CELLS["train_4k"], n_devices=16)
    assert plans
    assert all(p.data * p.model == 16 for p in plans)
    steps = [p.step_s for p in plans]
    assert steps == sorted(steps)
    assert plans[0].describe()


def test_rank_plans_model_axis_matters():
    """A pure-DP plan and a TP plan must price differently (the ranker is
    not a constant function of the mesh shape)."""
    from repro.configs import ARCHS, SHAPE_CELLS
    from repro.sharding.plans import rank_plans
    cfg = ARCHS["yi-34b"]
    plans = rank_plans(cfg, SHAPE_CELLS["decode_32k"], n_devices=8)
    by_shape = {p.mesh_shape: p.step_s for p in plans}
    assert len(set(by_shape.values())) > 1


# ---------------------------------------------------------------------------
# compiled-module pricing
# ---------------------------------------------------------------------------

def test_predict_fn_prices_compiled_module():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    model = CostModel.from_named("tpu_v5e")
    x = jnp.ones((64, 64), jnp.float32)
    pred = model.predict_fn(jax.jit(lambda v: jnp.tanh(v @ v)), x,
                            dtype="f32")
    assert pred.step_s > 0
    assert pred.mapped_op_count + pred.defaulted_op_count > 0


# ---------------------------------------------------------------------------
# CLI (measurement-free; the CI smoke path)
# ---------------------------------------------------------------------------

def test_cli_prediction_error_smoke(capsys):
    rc = costmodel_cli.main(["--calibration", "ampere_a100",
                             "--prediction-error"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "prederr/instr/FADD.f32.dep" in out
    assert "max_err_pct=" in out


def test_cli_demo_reports_defaulted_ops(capsys):
    rc = costmodel_cli.main(["--calibration", "tpu_v5e", "--demo"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "defaulted_ops" in out
    assert "defaulted/transpose" in out


def test_cli_export_round_trip(tmp_path, capsys):
    out_path = tmp_path / "cal.json"
    rc = costmodel_cli.main(["--calibration", "tpu_v5e",
                             "--export", str(out_path)])
    assert rc == 0
    doc = json.loads(out_path.read_text())
    assert doc["kind"] == "costmodel_calibration"
    assert CostModel.from_named(out_path).predict(BASE_CENSUS).step_s > 0


# ---------------------------------------------------------------------------
# the fused-decode traffic model (costmodel.analytic)
# ---------------------------------------------------------------------------

def _decode_cell(B=8, S=512):
    from repro.configs.base import ShapeCell
    return ShapeCell("hotpath", "decode", S, B)


def test_analytic_donated_decode_removes_second_cache():
    """Donating the cache must remove (almost) a whole cache worth of
    write traffic from the decode byte model: legacy - donated ==
    cache_bytes - one token slice."""
    from repro.configs import ARCHS, reduced
    from repro.core.costmodel import analytic

    cfg = reduced(ARCHS["gemma2-2b"])
    cell = _decode_cell()
    legacy = analytic.analytic_serve_bytes(cfg, cell, n_devices=1, n_model=1)
    fused = analytic.analytic_serve_bytes(cfg, cell, n_devices=1, n_model=1,
                                          donated=True)
    saved = analytic.cache_bytes(cfg, cell) \
        - analytic.decode_step_token_bytes(cfg, cell)
    assert fused < legacy
    assert abs((legacy - fused) - saved) < 1e-6 * legacy


def test_analytic_device_sampling_shrinks_host_transfer():
    """On-device argmax must shrink the per-step host transfer from the
    [B, vocab] f32 logit matrix to the [2, B] int32 token echo the fused
    engines actually sync (outputs + echoed inputs, one transfer)."""
    from repro.configs import ARCHS, reduced
    from repro.core.costmodel import analytic

    cfg = reduced(ARCHS["gemma2-2b"])
    cell = _decode_cell(B=4)
    legacy = analytic.decode_boundary_bytes(cfg, cell)
    fused = analytic.decode_boundary_bytes(cfg, cell, device_sampling=True)
    assert legacy == 4 * cfg.vocab_size * 4.0
    assert fused == 2 * 4 * 4.0


def test_analytic_census_decode_flags_flow_through():
    """The census carries both knobs: hbm_bytes drops under donation,
    boundary_bytes drops under device sampling, and prefill cells
    (which have no decode hot path) are unaffected."""
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeCell
    from repro.core.costmodel import analytic

    cfg = reduced(ARCHS["gemma2-2b"])
    cell = _decode_cell()
    legacy = analytic.analytic_census(cfg, cell, n_devices=1, n_model=1)
    fused = analytic.analytic_census(cfg, cell, n_devices=1, n_model=1,
                                     donated=True, device_sampling=True)
    assert fused["hbm_bytes"] < legacy["hbm_bytes"]
    assert fused["boundary_bytes"] < legacy["boundary_bytes"]
    # pricing through the model keeps the ordering
    cm = CostModel.from_named("tpu_v5e")
    assert cm.predict(fused).step_s <= cm.predict(legacy).step_s
    pre = ShapeCell("hotpath", "prefill", 128, 1)
    a = analytic.analytic_census(cfg, pre, n_devices=1, n_model=1)
    b = analytic.analytic_census(cfg, pre, n_devices=1, n_model=1,
                                 donated=True)
    assert a["hbm_bytes"] == b["hbm_bytes"]
    assert "boundary_bytes" not in a
