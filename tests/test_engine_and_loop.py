"""End-to-end behaviour: serving engine vs raw decode (with and without
cost-model-gated admission), and the training loop with checkpoint-restart
determinism and predicted-vs-measured step logging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.costmodel import CostModel
from repro.launch.mesh import make_host_mesh
from repro.models.zoo import build_model
from repro.serve.engine import ServingEngine
from repro.train.loop import train


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n, max_len):
    logits, cache = model.prefill(params, {"tokens": prompt[None, :]},
                                  max_len=max_len)
    toks = [int(jnp.argmax(logits[0]))]
    pos0 = prompt.shape[0]
    for t in range(n - 1):
        lg, cache = model.decode(params, cache,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 jnp.asarray([pos0 + t], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def test_engine_matches_raw_greedy_decode(tiny_lm):
    cfg, model, params = tiny_lm
    eng = ServingEngine(model, params, max_batch=2, max_len=48)
    prompts = [np.arange(5, 13, dtype=np.int32) % cfg.vocab_size,
               np.arange(40, 52, dtype=np.int32) % cfg.vocab_size]
    rids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run_until_done()
    for rid, p in zip(rids, prompts):
        got = eng.done[rid].tokens
        want = _greedy_reference(model, params, jnp.asarray(p), 6, 48)
        assert got == want, (got, want)


def test_engine_queues_beyond_batch(tiny_lm):
    cfg, model, params = tiny_lm
    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    for i in range(5):
        eng.submit(np.arange(3 + i, dtype=np.int32), max_new_tokens=4)
    stats = eng.run_until_done()
    assert stats.completed == 5
    assert stats.prefills == 5
    assert all(len(r.tokens) == 4 for r in eng.done.values())


def test_engine_cost_model_admission_defers_but_completes(tiny_lm):
    """With a deliberately tight step budget the engine must stage prefill
    admissions across steps (deferrals observed) yet still finish every
    request with the same greedy tokens."""
    cfg, model, params = tiny_lm
    cm = CostModel.from_named("tpu_v5e")
    eng = ServingEngine(model, params, max_batch=4, max_len=48,
                        cost_model=cm, step_budget_s=0.0)
    prompts = [np.arange(3 + i, dtype=np.int32) % cfg.vocab_size
               for i in range(6)]
    rids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    stats = eng.run_until_done()
    assert stats.completed == 6
    assert stats.deferred_prefills > 0          # the budget actually gated
    assert len(stats.predicted_step_s) == stats.steps
    assert all(s > 0 for s in stats.predicted_step_s)
    for rid, p in zip(rids, prompts):
        want = _greedy_reference(model, params, jnp.asarray(p), 4, 48)
        assert eng.done[rid].tokens == want


def test_engine_cost_model_generous_budget_packs_greedily(tiny_lm):
    """A generous budget must not change the old greedy packing."""
    cfg, model, params = tiny_lm
    cm = CostModel.from_named("tpu_v5e")
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        cost_model=cm, step_budget_s=1e9)
    for i in range(5):
        eng.submit(np.arange(3 + i, dtype=np.int32), max_new_tokens=4)
    stats = eng.run_until_done()
    assert stats.completed == 5
    assert stats.deferred_prefills == 0


def test_train_logs_predicted_vs_measured(tmp_path):
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=64)
    model = build_model(cfg)
    seen = []
    res = train(model, make_host_mesh(), num_steps=3, global_batch=4,
                seq_len=16, cost_model=CostModel.from_named("tpu_v5e"),
                hooks=[lambda step, m: seen.append(m)])
    assert res.predicted_step_s is not None and res.predicted_step_s > 0
    assert len(res.step_times_s) == 3
    assert all("predicted_step_s" in m and "measured_step_s" in m
               for m in seen)
    assert seen[0]["predicted_step_s"] == res.predicted_step_s


def test_train_loss_decreases(tmp_path):
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=97)
    model = build_model(cfg)
    res = train(model, make_host_mesh(), num_steps=30, global_batch=8,
                seq_len=32, lr=5e-3)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.2, (first, last)


def test_train_restart_is_deterministic(tmp_path):
    cfg = reduced(ARCHS["internlm2-20b"], n_layers=2, vocab_size=97)
    model = build_model(cfg)
    mesh = make_host_mesh()
    kw = dict(global_batch=4, seq_len=16, lr=1e-3, seed=11)
    # one uninterrupted 10-step run
    r_full = train(model, mesh, num_steps=10, **kw)
    # 5 steps, "crash", restore, 5 more
    d = tmp_path / "ck"
    r_a = train(model, mesh, num_steps=5, ckpt_dir=str(d), ckpt_every=5, **kw)
    r_b = train(model, mesh, num_steps=10, ckpt_dir=str(d), ckpt_every=5,
                **kw)
    assert r_b.restored_from == 5
    np.testing.assert_allclose(r_full.losses[5:], r_b.losses, rtol=2e-3,
                               atol=2e-3)
