"""Campaign-runner subsystem tests: registry lookup, grid expansion,
resume-skip scheduling, and round-trip of the schema-versioned result
format into the report generator and the perf-model calibration bridge."""
import json

import pytest

from repro.core.campaign import registry, report, runner
from repro.core.campaign import results as results_mod
from repro.core.campaign.results import ResultStore, load_results
from repro.core.campaign.spec import Experiment, cell_key

# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_has_the_paper_campaigns():
    assert {"alu_chain", "memory_chase", "mxu_shapes",
            "roofline_calibration", "isa_mapping"} <= set(registry.names())


def test_registry_lookup_unknown_name_lists_available():
    with pytest.raises(KeyError, match="alu_chain"):
        registry.get("not_an_experiment")


def test_registry_cost_estimates_positive():
    for name in registry.names():
        exp = registry.get(name)
        assert exp.estimated_cost_s() > 0
        assert exp.estimated_cost_s(quick=True) <= exp.estimated_cost_s()


# ---------------------------------------------------------------------------
# grid expansion
# ---------------------------------------------------------------------------


def _toy_experiment(calls=None, fail_on=()):
    def toy_runner(params, quick=False):
        if calls is not None:
            calls.append(dict(params))
        if params["op"] in fail_on:
            raise RuntimeError(f"boom on {params['op']}")
        return {"latency_ns": 10.0 * params["k"], "op": params["op"]}

    return Experiment(
        name="toy", description="test-only",
        grid={"op": ("add", "mul", "div"), "k": (1, 2)},
        quick_grid={"op": ("add",), "k": (1,)},
        constraint=lambda p: not (p["op"] == "div" and p["k"] == 2),
        runner=toy_runner)


def test_grid_expansion_counts_and_constraint():
    exp = _toy_experiment()
    cells = exp.cells()
    assert len(cells) == 5                     # 3*2 minus the (div,2) combo
    assert all(c.params != {"op": "div", "k": 2} for c in cells)
    assert len(exp.cells(quick=True)) == 1


def test_cell_keys_deterministic_and_order_independent():
    assert cell_key({"b": 2, "a": True}) == cell_key({"a": True, "b": 2})
    assert cell_key({"a": True, "shape": (128, 64)}) == "a=true,shape=128x64"
    exp = _toy_experiment()
    keys = [c.key for c in exp.cells()]
    assert len(keys) == len(set(keys))


def test_alu_grid_respects_dtype_legality():
    exp = registry.get("alu_chain")
    for cell in exp.cells():
        p = cell.params
        if p["dtype"] == "int32":
            assert p["op"] not in {"exp", "div", "rsqrt", "fma"}
        else:
            assert p["op"] not in {"and", "xor", "popc", "clz"}


# ---------------------------------------------------------------------------
# scheduler: resume-skip, force, error isolation
# ---------------------------------------------------------------------------


def test_run_then_rerun_skips_completed_cells(tmp_path):
    calls = []
    exp = _toy_experiment(calls)
    rep1 = runner.run(exp, out_dir=tmp_path, backend="cpu")
    assert (rep1.ran, rep1.skipped, rep1.failed) == (5, 0, 0)
    assert rep1.path.exists()

    rep2 = runner.run(exp, out_dir=tmp_path, backend="cpu")
    assert (rep2.ran, rep2.skipped) == (0, 5)
    assert len(calls) == 5                     # runner never re-invoked

    rep3 = runner.run(exp, out_dir=tmp_path, backend="cpu", force=True)
    assert rep3.ran == 5 and len(calls) == 10


def test_failed_cells_recorded_and_retried(tmp_path):
    exp = _toy_experiment(fail_on=("mul",))
    rep = runner.run(exp, out_dir=tmp_path, backend="cpu")
    assert rep.failed == 2 and rep.ran == 3    # campaign survived the errors
    doc = load_results(rep.path)
    errs = [r for r in doc["cells"].values() if r["status"] == "error"]
    assert len(errs) == 2 and "boom" in errs[0]["error"]

    # a rerun retries ONLY the failed cells
    ok = _toy_experiment()
    rep2 = runner.run(ok, out_dir=tmp_path, backend="cpu")
    assert (rep2.ran, rep2.skipped, rep2.failed) == (2, 3, 0)


def test_full_run_does_not_reuse_quick_measurements(tmp_path):
    calls = []
    exp = _toy_experiment(calls)
    runner.run(exp, out_dir=tmp_path, backend="cpu", quick=True)
    assert len(calls) == 1                     # quick grid is 1 cell

    # full run must re-measure the quick cell (shorter sweeps don't count)
    rep = runner.run(exp, out_dir=tmp_path, backend="cpu", quick=False)
    assert (rep.ran, rep.skipped) == (5, 0)
    doc = load_results(rep.path)
    assert doc["quick"] is False
    assert all(not r["quick"] for r in doc["cells"].values())

    # ...but a quick run happily reuses full-sweep measurements
    rep2 = runner.run(exp, out_dir=tmp_path, backend="cpu", quick=True)
    assert (rep2.ran, rep2.skipped) == (0, 1)


def test_backend_mismatch_refuses_to_mix(tmp_path):
    exp = _toy_experiment()
    runner.run(exp, out_dir=tmp_path, backend="cpu")
    with pytest.raises(RuntimeError, match="mixing backends"):
        runner.run(exp, out_dir=tmp_path, backend="tpu")
    # force re-measures everything and relabels the file
    rep = runner.run(exp, out_dir=tmp_path, backend="tpu", force=True)
    assert rep.ran == 5
    assert load_results(rep.path)["backend"] == "tpu"


def test_run_filter_restricts_grid(tmp_path):
    exp = _toy_experiment()
    rep = runner.run(exp, out_dir=tmp_path, backend="cpu",
                     only={"op": "add"})
    assert rep.total_cells == 2 and rep.ran == 2


def test_backend_requirement_enforced(tmp_path):
    exp = Experiment(name="tpu_only", description="", grid={"x": (1,)},
                     runner=lambda p, quick=False: {}, backends=("tpu",))
    with pytest.raises(RuntimeError, match="requires"):
        runner.run(exp, out_dir=tmp_path, backend="cpu")


# ---------------------------------------------------------------------------
# result schema round-trip
# ---------------------------------------------------------------------------


def test_result_schema_round_trip(tmp_path):
    path = tmp_path / "toy.json"
    store = ResultStore(path, "toy", backend="cpu", quick=True)
    store.record("k=1,op=add", {"op": "add", "k": 1},
                 {"latency_ns": 12.5, "curve": {"4": 1.0}}, elapsed_s=0.01,
                 quick=True)

    doc = load_results(path)
    assert doc["schema_version"] == results_mod.SCHEMA_VERSION
    assert doc["experiment"] == "toy" and doc["quick"] is True
    rec = doc["cells"]["k=1,op=add"]
    assert rec["params"] == {"op": "add", "k": 1}
    assert rec["metrics"]["latency_ns"] == 12.5

    # reopening the store resumes from the persisted state
    store2 = ResultStore(path, "toy")
    assert store2.completed == {"k=1,op=add"}

    csv_path = store2.write_csv()
    header, row = csv_path.read_text().strip().splitlines()
    assert header.startswith("experiment,cell,status,")
    assert "latency_ns" in header and "12.5" in row


def test_result_schema_rejects_newer_and_mismatched(tmp_path):
    path = tmp_path / "toy.json"
    path.write_text(json.dumps({"schema_version": 99, "experiment": "toy",
                                "cells": {}}))
    with pytest.raises(ValueError, match="newer"):
        load_results(path)

    path.write_text(json.dumps(
        results_mod.new_document("other", "cpu", False)))
    with pytest.raises(ValueError, match="other"):
        ResultStore(path, "toy")


def test_v0_document_migrates_forward(tmp_path):
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"experiment": "toy", "hardware": "cpu",
                                "ops": {"add": {}}}))
    doc = load_results(path)
    assert doc["schema_version"] == results_mod.SCHEMA_VERSION
    assert doc["cells"] == {}                  # unversioned cells re-measure


# ---------------------------------------------------------------------------
# report generation + calibration bridge (from files alone)
# ---------------------------------------------------------------------------


def _fake_alu_doc():
    doc = results_mod.new_document("alu_chain", "cpu", True)
    doc["cells"]["dependent=true,dtype=float32,op=add"] = {
        "params": {"op": "add", "dtype": "float32", "dependent": True},
        "metrics": {"per_op_ns": 1000.0, "overhead_ns": 50.0,
                    "lengths": [4, 16], "times_us": [4.2, 16.4],
                    "cpi_curve": {"4": 1.05, "16": 1.0}},
        "status": "ok", "elapsed_s": 0.1,
    }
    return doc


def test_cpi_table_regenerated_from_result_doc():
    rows = report.table_for(_fake_alu_doc())
    names = [r[0] for r in rows]
    assert "table2/add.float32.dep" in names
    assert "table1/add.float32.dep/K=4" in names
    t2 = dict((r[0], r) for r in rows)["table2/add.float32.dep"]
    assert t2[1] == pytest.approx(1.0)         # 1000 ns -> 1 us per call


def test_calibration_from_results_feeds_predictor():
    from repro.core.perfmodel import predictor
    from repro.core.perfmodel.hardware import TPU_V5E

    table = report.calibration_from_results({"alu_chain": _fake_alu_doc()},
                                            clock_hz=1e9)
    assert table["vpu"]["add.f32"]["cpi"] == pytest.approx(1000.0)
    overhead = predictor.issue_overhead({"add": 100.0}, table)
    assert overhead == pytest.approx(100 * 1000.0 / TPU_V5E.clock_hz)


def test_table_from_results_loads_dir(tmp_path):
    from repro.core.microbench import tables

    doc = _fake_alu_doc()
    (tmp_path / "alu_chain.json").write_text(json.dumps(doc))
    table = tables.table_from_results(tmp_path, experiments=("alu_chain",))
    assert "add.float32.dep" in table["ops"]
    with pytest.raises(FileNotFoundError):
        tables.table_from_results(tmp_path / "empty")
