"""Per-architecture smoke tests (REQUIRED by the task): reduced same-family
configs, one forward/train step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells_for, get_config, reduced
from repro.models.zoo import build_model, count_params
from repro.train.optim import make_optimizer
from repro.train.step import make_train_step

ALL = sorted(ARCHS)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jnp.arange(B * S).reshape(B, S).astype(jnp.int32)
         % cfg.vocab_size}
    b["labels"] = b["tokens"]
    if cfg.encdec:
        b["frames"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        b["prefix_embeds"] = jnp.zeros((B, 4, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss, aux = model.loss(params, _batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert float(loss) < 3 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL)
def test_one_train_step(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(cfg.optimizer)
    ostate = opt.init(params)
    step = jax.jit(make_train_step(model, opt, accum=2))
    p2, o2, metrics = step(params, ostate, _batch(cfg, B=4))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, ab: acc or bool(jnp.any(ab)), jax.tree.map(
            lambda a, b: jnp.any(a != b), params, p2), False)
    assert moved


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_shapes(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {k: v for k, v in _batch(cfg, B, S).items() if k != "labels"}
    pfx = (cfg.meta_tokens or 0) + (4 if cfg.frontend == "vision" else 0)
    logits, cache = model.prefill(params, batch, max_len=S + pfx + 4)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S + pfx, jnp.int32)
    logits2, cache2 = model.decode(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_full_configs_param_counts_sane():
    # full configs are never materialized (eval_shape only)
    expect = {
        "yi-34b": (33e9, 36e9),
        "internlm2-20b": (18e9, 22e9),
        "gemma2-2b": (2.0e9, 3.3e9),
        "gemma3-1b": (0.9e9, 1.6e9),
        "deepseek-v2-236b": (220e9, 250e9),
        "olmoe-1b-7b": (6.0e9, 8.0e9),
        "rwkv6-1.6b": (1.4e9, 2.2e9),
        "hymba-1.5b": (1.1e9, 2.0e9),
        "llava-next-34b": (33e9, 36e9),
        "seamless-m4t-medium": (0.5e9, 1.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"


def test_cells_for_long_context_policy():
    assert any(c.name == "long_500k" for c in cells_for(get_config("rwkv6-1.6b")))
    assert any(c.name == "long_500k" for c in cells_for(get_config("hymba-1.5b")))
    assert not any(c.name == "long_500k" for c in cells_for(get_config("yi-34b")))
