"""use_pallas=True routes the model hot paths through the Pallas kernels
(interpret mode on CPU); outputs must match the pure-jnp reference paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import transformer as lm_mod
from repro.models.zoo import build_model


@pytest.mark.parametrize("arch", ["internlm2-20b", "gemma2-2b", "rwkv6-1.6b"])
def test_pallas_path_matches_reference(arch):
    cfg0 = reduced(ARCHS[arch])
    cfg1 = cfg0.replace(use_pallas=True)
    model = build_model(cfg0)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg0.vocab_size, jnp.int32)
    l0, _, _ = lm_mod.lm_apply(params, cfg0, tokens=toks, mode="train",
                               remat=False)
    l1, _, _ = lm_mod.lm_apply(params, cfg1, tokens=toks, mode="train",
                               remat=False)
    err = float(jnp.max(jnp.abs(l0 - l1)))
    scale = float(jnp.max(jnp.abs(l0)))
    assert err < 0.02 * max(scale, 1.0), (err, scale)


def test_pallas_train_grads_match():
    cfg0 = reduced(ARCHS["gemma2-2b"])
    cfg1 = cfg0.replace(use_pallas=True)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-3, rtol=2e-2)
