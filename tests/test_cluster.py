"""Multi-replica cluster invariants on the deterministic sim harness.

The exact-trace contracts the ISSUE's cluster tier has to honor:

* one replica is a no-op wrapper — byte-identical tokens and timestamps
  vs a bare paged engine on the same scripted trace;
* the scheduler's new ``requeue_policy`` hook defaults to the old
  unconditional front-requeue (single-replica behavior byte-identical),
  and a hook that declines (returns False) changes nothing;
* at two-plus replicas every admitted token is conserved under
  preemption + cross-replica re-route, and every request's tokens stay
  the greedy-exact ``expected_tokens`` sequence regardless of where it
  bounced;
* on the skewed trace the cost-aware policy strictly beats round-robin
  on cluster wall time and p99 latency (exact virtual-clock numbers);
* the router's bookkeeping (routed counts, shed, reroute caps) and the
  cluster telemetry merge are pinned.
"""
import numpy as np
import pytest

from repro.serve import PagedServingEngine
from repro.serve.cluster import (CostAwarePolicy, LeastLoadedPolicy,
                                 RoundRobinPolicy, Router, ServingCluster,
                                 make_policy, predicted_queue_seconds,
                                 serve_trace, skewed_trace, unit_latency)
from repro.serve.scheduler import ChunkedPrefillScheduler
from repro.serve.sim import (FakeCostModel, FakeModel, SimClock, drive,
                             expected_tokens)

VOCAB = 97
STEP = unit_latency(decode_s=0.5, chunk_s=0.25, overhead_s=0.01)


def build_cluster(n, policy="cost_aware", clock=None, shed_wait_s=None,
                  **kw):
    clock = clock if clock is not None else SimClock()
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("n_blocks", 24)
    kw.setdefault("block_size", 8)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("cost_model", FakeCostModel(decode_s=0.5, prefill_s=0.25))
    cl = ServingCluster.build(FakeModel(vocab=VOCAB), None, n_replicas=n,
                              policy=policy, clock=clock,
                              shed_wait_s=shed_wait_s, **kw)
    return cl, clock


def run_trace(cl, clock, trace):
    return serve_trace(cl, trace, clock, step_seconds=STEP, min_dt=0.25)


TRACE = skewed_trace(12, vocab=VOCAB, period=2, long_len=24, short_len=4,
                     long_new=12, short_new=4, interval_s=1.0, load=2.0)


# ---------------------------------------------------------------------------
# replica_count=1: the cluster is a transparent wrapper
# ---------------------------------------------------------------------------


def test_single_replica_byte_identical_to_bare_engine():
    cl, clock = build_cluster(1)
    admitted = run_trace(cl, clock, TRACE)
    assert len(cl.done) == len(TRACE) and cl.stats.shed == 0

    clock2 = SimClock()
    eng = PagedServingEngine(FakeModel(vocab=VOCAB), None, max_batch=4,
                             max_len=64, n_blocks=24, block_size=8,
                             chunk_size=8, clock=clock2,
                             cost_model=FakeCostModel(decode_s=0.5,
                                                      prefill_s=0.25))
    rids = drive(eng, clock2, TRACE, dt=0.5)
    assert len(eng.done) == len(TRACE)
    # crids and rids both enumerate the trace in arrival order
    for (crid, t_c), (rid, t_b) in zip(sorted(admitted.items()),
                                       sorted(rids.items())):
        assert t_c == t_b
        assert list(cl.done[crid].tokens) == list(eng.done[rid].tokens)
        # stamped at the admitting tick, never before the arrival
        assert cl.done[crid].submitted_s >= t_c


def test_single_replica_tokens_greedy_exact():
    cl, clock = build_cluster(1)
    run_trace(cl, clock, TRACE)
    for crid in cl.done:
        _, prompt, new, eos = TRACE[crid]
        assert list(cl.done[crid].tokens) == expected_tokens(
            prompt, new, VOCAB, eos)


# ---------------------------------------------------------------------------
# requeue_policy: default + declining hook are byte-identical (regression
# for the unconditional-front-requeue fix)
# ---------------------------------------------------------------------------


def _run_bare(requeue_policy, probe):
    clock = SimClock()
    eng = PagedServingEngine(FakeModel(vocab=VOCAB), None, max_batch=4,
                             max_len=48, n_blocks=8, block_size=8,
                             chunk_size=8, clock=clock)
    if requeue_policy is not None:
        eng.scheduler.requeue_policy = requeue_policy
    trace = skewed_trace(8, vocab=VOCAB, period=2, long_len=24, short_len=4,
                         long_new=12, short_new=4, interval_s=1.0, load=4.0)
    rids = drive(eng, clock, trace, dt=0.5, max_steps=2000)
    assert eng.stats.preemptions > 0, "trace must exercise the requeue path"
    if probe is not None:
        assert probe["calls"] == eng.stats.preemptions
    return [(rid, list(eng.done[rid].tokens), eng.done[rid].finished_s)
            for rid in sorted(eng.done)]


def test_requeue_policy_default_and_declining_hook_identical():
    baseline = _run_bare(None, None)
    probe = {"calls": 0}

    def decline(req):
        probe["calls"] += 1
        return False

    assert _run_bare(decline, probe) == baseline


def test_requeue_policy_claim_removes_from_queue():
    sched = ChunkedPrefillScheduler(chunk_size=8)

    class Req:
        prompt = np.arange(4)
        max_new_tokens = 2
    claimed = []
    sched.requeue_policy = lambda r: claimed.append(r) is None
    sched.requeue(Req())
    assert len(claimed) == 1 and len(sched.queue) == 0
    sched.requeue_policy = lambda r: False
    sched.requeue(Req())
    assert len(sched.queue) == 1


# ---------------------------------------------------------------------------
# replica_count>=2: conservation under preemption + re-route
# ---------------------------------------------------------------------------


def tight_trace(n=10):
    # pools of 8x8-token blocks per replica: a long request needs 5, so
    # concurrent longs evict each other -> preemptions + reroute chances
    return skewed_trace(n, vocab=VOCAB, period=2, long_len=24, short_len=4,
                        long_new=12, short_new=4, interval_s=1.0, load=4.0)


@pytest.mark.parametrize("policy", ["round_robin", "least_loaded",
                                    "cost_aware"])
def test_tokens_conserved_under_preemption_and_reroute(policy):
    cl, clock = build_cluster(2, policy=policy, max_len=48, n_blocks=8)
    trace = tight_trace()
    admitted = run_trace(cl, clock, trace)
    assert sum(e.stats.preemptions for e in cl.replicas) > 0
    assert len(cl.done) == len(admitted) == len(trace)
    total = 0
    for crid in cl.done:
        _, prompt, new, eos = trace[crid]
        assert list(cl.done[crid].tokens) == expected_tokens(
            prompt, new, VOCAB, eos)
        total += len(cl.done[crid].tokens)
    assert total == sum(len(expected_tokens(p, n, VOCAB, e))
                        for _, p, n, e in trace)


def test_cost_aware_reroutes_and_tokens_survive_the_move():
    cl, clock = build_cluster(2, policy="cost_aware", max_len=48, n_blocks=8)
    trace = tight_trace()
    run_trace(cl, clock, trace)
    assert cl.stats.reroutes > 0, "tight pools must trigger a re-route"
    assert cl.stats.reroutes + cl.stats.front_requeues == sum(
        e.stats.preemptions for e in cl.replicas)
    for crid in cl.done:      # the moved requests still decode exactly
        _, prompt, new, eos = trace[crid]
        assert list(cl.done[crid].tokens) == expected_tokens(
            prompt, new, VOCAB, eos)


def test_round_robin_never_reroutes():
    cl, clock = build_cluster(2, policy="round_robin", max_len=48,
                              n_blocks=8)
    run_trace(cl, clock, tight_trace())
    assert cl.stats.reroutes == 0
    assert cl.stats.front_requeues == sum(e.stats.preemptions
                                          for e in cl.replicas)


# ---------------------------------------------------------------------------
# the campaign's headline: cost-aware beats round-robin on the skewed
# trace, in exact virtual-clock arithmetic
# ---------------------------------------------------------------------------


def test_cost_aware_beats_round_robin_on_skewed_trace():
    results = {}
    for policy in ("round_robin", "cost_aware"):
        cl, clock = build_cluster(2, policy=policy)
        admitted = run_trace(cl, clock, TRACE)
        lats = sorted(cl.done[c].finished_s - admitted[c] for c in cl.done)
        results[policy] = {
            "wall": clock.t,
            "p99": lats[int(0.99 * (len(lats) - 1))],
            "tokens": {c: list(cl.done[c].tokens) for c in cl.done},
        }
    rr, ca = results["round_robin"], results["cost_aware"]
    assert ca["wall"] < rr["wall"]          # higher tok/s, same tokens
    assert ca["p99"] < rr["p99"]
    assert ca["tokens"] == rr["tokens"]     # placement is not semantics


# ---------------------------------------------------------------------------
# router bookkeeping
# ---------------------------------------------------------------------------


def test_router_shed_and_routed_accounting():
    cl, clock = build_cluster(2, policy="round_robin", shed_wait_s=3.0)
    trace = skewed_trace(16, vocab=VOCAB, period=2, long_len=24,
                         short_len=4, long_new=12, short_new=4,
                         interval_s=1.0, load=8.0)
    admitted = run_trace(cl, clock, trace)
    st = cl.stats
    assert st.shed > 0 and st.submitted == len(admitted)
    assert st.shed + st.submitted == len(trace)
    assert sum(st.routed) >= st.submitted   # routed counts re-routes too
    assert len(cl.done) == len(admitted)    # shed requests are refused,
    #                                         admitted ones all finish


def test_router_refuses_double_ownership_and_unknown_policy():
    cl, _ = build_cluster(2)
    with pytest.raises(ValueError):
        Router(cl.replicas, policy="round_robin")
    with pytest.raises(ValueError):
        make_policy("nope")


def test_reroute_cap_limits_ping_pong():
    cl, clock = build_cluster(2, policy="cost_aware", max_len=48,
                              n_blocks=8)
    cl.router.max_reroutes = 0
    run_trace(cl, clock, tight_trace())
    assert cl.stats.reroutes == 0           # cap forces front-requeue
    assert len(cl.done) == len(tight_trace())


def test_router_bookkeeping_drains_and_leaks_are_loud():
    # regression for the drain-audit sweep: after every admitted request
    # finishes, the rid maps and the in-flight move set must be EMPTY —
    # and a leaked entry must fail the assert with the dict named
    cl, clock = build_cluster(2, policy="cost_aware", max_len=48,
                              n_blocks=8)
    run_trace(cl, clock, tight_trace())
    assert cl.stats.reroutes > 0     # the trace must exercise _moves
    cl.router.assert_drained()
    cl.router._moves[999] = 0
    with pytest.raises(AssertionError, match="_moves"):
        cl.router.assert_drained()


def test_predicted_queue_seconds_empty_and_loaded():
    cl, _ = build_cluster(1)
    eng = cl.replicas[0]
    assert predicted_queue_seconds(eng) == 0.0
    eng.submit(np.arange(8, dtype=np.int32), max_new_tokens=4)
    # 1 chunk * 0.25s + 4 tokens * (0.5s / 4 rows)
    assert predicted_queue_seconds(eng) == pytest.approx(0.75)


def test_policy_place_prefers_empty_replica():
    cl, _ = build_cluster(2)
    cl.replicas[0].submit(np.arange(8, dtype=np.int32), max_new_tokens=8)
    for policy in (LeastLoadedPolicy(), CostAwarePolicy()):
        assert policy.place(4, 4, cl.replicas) == 1
    assert RoundRobinPolicy().place(4, 4, cl.replicas) == 0


# ---------------------------------------------------------------------------
# cluster telemetry: per-replica controllers, merged views
# ---------------------------------------------------------------------------


def test_cluster_telemetry_merge_and_tags(tmp_path):
    from repro.serve.cluster import ClusterTelemetry
    from repro.serve.sim import work_latency_model
    tel = ClusterTelemetry(2, latency_model=work_latency_model(0.5, 0.25))
    cl, clock = build_cluster(2, policy="round_robin", telemetry=tel)
    run_trace(cl, clock, TRACE)
    s = tel.summary()
    assert s["n_replicas"] == 2 and len(s["per_replica"]) == 2
    assert s["requests"] == len(TRACE)
    assert s["latency_p99_s"] >= s["latency_p50_s"] > 0
    lines = tel.export_jsonl(tmp_path / "cluster.jsonl").read_text()
    import json
    tags = {json.loads(ln)["replica"] for ln in lines.splitlines()}
    assert tags == {0, 1}


def test_build_from_device_budget_uses_cost_model_topology():
    from repro.configs.base import ShapeCell
    from repro.core.costmodel import CostModel
    from repro.sharding.plans import rank_cluster_topologies
    model = FakeModel(vocab=VOCAB)
    cm = CostModel.from_named("tpu_v5e")
    cell = ShapeCell("t", "decode", 64, 4)
    cluster = ServingCluster.build(model, None, clock=SimClock(),
                                   cost_model=cm, n_devices=4, cell=cell,
                                   max_batch=4, max_len=64, n_blocks=24,
                                   block_size=8, chunk_size=8)
    top = rank_cluster_topologies(model.cfg, cell, 4, cm)[0]
    assert cluster.topology is not None
    assert len(cluster.replicas) == top.n_replicas
    assert cluster.topology.devices_per_replica * top.n_replicas == 4
    with pytest.raises(ValueError):
        ServingCluster.build(model, None)   # neither n_replicas nor budget


# ---------------------------------------------------------------------------
# sharding CLI (satellite): ranked factorization table
# ---------------------------------------------------------------------------


def test_sharding_cli_prints_ranked_tables(capsys):
    from repro.sharding.cli import main
    rc = main(["--calibration", "tpu_v5e", "--topology", "4,8,128",
               "--devices", "4"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "data=" in out and "<- best" in out
    assert "replicas=" in out


def test_sharding_cli_rejects_bad_topology():
    from repro.sharding.cli import main
    with pytest.raises(SystemExit):
        main(["--topology", "8,8"])


# ---------------------------------------------------------------------------
# bench schema v4 round-trip + trajectory pickup
# ---------------------------------------------------------------------------


def test_bench_v5_validate_and_compare_scenarios(tmp_path):
    import importlib.util
    import json
    import sys
    root = __import__("pathlib").Path(__file__).resolve().parent.parent
    for name, rel in (("bench_serve", "benchmarks/bench_serve.py"),
                      ("traj_compare", "benchmarks/trajectory/compare.py")):
        spec = importlib.util.spec_from_file_location(name, root / rel)
        mod = importlib.util.module_from_spec(spec)
        sys.modules[name] = mod
        spec.loader.exec_module(mod)
    bench, comp = sys.modules["bench_serve"], sys.modules["traj_compare"]

    assert bench.SCHEMA == "bench_serve/v6" and bench.BENCH_ID == 10
    doc = {"schema": bench.SCHEMA, "bench_id": 10, "engines": {},
           "cluster": {"r1": {"rr_tok_per_s": 10.0, "ca_tok_per_s": 11.0},
                       "r2": {"rr_tok_per_s": 17.0, "ca_tok_per_s": 20.0}},
           "sharded": {"ref_step_s": 0.5, "d1m1_step_s": 0.5,
                       "d1m1_pred_step_s": 1e-6, "d2m2_step_s": 0.25},
           "chaos": {"crash": {"ok": True, "tokens_lost": 0}}}
    path = tmp_path / "BENCH_10.json"
    path.write_text(json.dumps(doc))
    loaded = bench.validate_bench_doc(json.loads(path.read_text()))
    assert loaded == doc                                 # round-trip
    s = comp.scenarios(loaded)
    assert s["cluster.r1.rr"] == 10.0 and s["cluster.r2.ca"] == 20.0
    # sharded step times gate as inverted rates; predictions are
    # diagnostics, not gated scenarios
    assert s["sharded.d1m1.steps_per_s"] == 2.0
    assert s["sharded.d2m2.steps_per_s"] == 4.0
    assert s["sharded.ref.steps_per_s"] == 2.0
    assert not any("pred" in k for k in s)
    # older schemas still validate (blocks only required from their
    # introducing version on)
    bench.validate_bench_doc({"schema": "bench_serve/v3", "engines": {}})
    bench.validate_bench_doc({"schema": "bench_serve/v4", "engines": {},
                              "cluster": {}})
    with pytest.raises(ValueError):
        bench.validate_bench_doc({"schema": "bench_serve/v4",
                                  "engines": {}})        # missing cluster
    with pytest.raises(ValueError):
        bench.validate_bench_doc({"schema": "bench_serve/v5",
                                  "engines": {},
                                  "cluster": {}})        # missing sharded
    with pytest.raises(ValueError):
        bench.validate_bench_doc({"schema": "bench_serve/v6",
                                  "engines": {}, "cluster": {},
                                  "sharded": {}})        # missing chaos
    with pytest.raises(ValueError):
        bench.validate_bench_doc({"schema": "bench_serve/v99",
                                  "engines": {}, "cluster": {},
                                  "sharded": {}, "chaos": {}})
    with pytest.raises(ValueError):
        bench.validate_bench_doc({"schema": "autotune.cache/v1"})


def test_committed_trajectory_carries_bench9_sharded():
    import importlib.util
    import sys
    root = __import__("pathlib").Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "traj_compare3", root / "benchmarks/trajectory/compare.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["traj_compare3"] = mod
    spec.loader.exec_module(mod)
    traj = mod.load_trajectory(root / "benchmarks/trajectory")
    ids = [i for i, _ in traj]
    assert 9 in ids, "BENCH_9.json must be committed with this change"
    doc = dict(traj)[9]
    assert doc["schema"] == "bench_serve/v5"
    assert doc["sharded_ok"] and doc["identical_tokens"]
    sh = doc["sharded"]
    assert sh["identical_all"]
    for d, m in ((1, 1), (2, 1), (1, 2), (2, 2)):
        assert sh[f"d{d}m{m}_identical"], (d, m)
        assert sh[f"d{d}m{m}_sync_ok"] and sh[f"d{d}m{m}_donated"], (d, m)
        assert sh[f"d{d}m{m}_pred_step_s"] > 0, (d, m)
    assert mod.compare(traj, tolerance=0.6) == []


def test_committed_trajectory_carries_bench10_chaos():
    import importlib.util
    import sys
    root = __import__("pathlib").Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "traj_compare4", root / "benchmarks/trajectory/compare.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["traj_compare4"] = mod
    spec.loader.exec_module(mod)
    traj = mod.load_trajectory(root / "benchmarks/trajectory")
    ids = [i for i, _ in traj]
    assert 10 in ids, "BENCH_10.json must be committed with this change"
    doc = dict(traj)[10]
    assert doc["schema"] == "bench_serve/v6"
    assert doc["chaos_ok"] and doc["identical_tokens"]
    for fault in ("crash", "hang", "corrupt", "crashloop"):
        m = doc["chaos"][fault]
        assert m["ok"], fault
        assert m["survivors_identical"] and m["all_accounted"], fault
        assert m["tokens_lost"] == 0 and m["blocks_leaked"] == 0, fault
    assert doc["chaos"]["crashloop"]["quarantined"]
    # the chaos block is invisible to the tok/s trajectory gate
    assert not any(k.startswith("chaos") for k in mod.scenarios(doc))
    assert mod.compare(traj, tolerance=0.6) == []


def test_committed_trajectory_carries_bench8_cluster():
    import importlib.util
    import sys
    root = __import__("pathlib").Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "traj_compare2", root / "benchmarks/trajectory/compare.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["traj_compare2"] = mod
    spec.loader.exec_module(mod)
    traj = mod.load_trajectory(root / "benchmarks/trajectory")
    ids = [i for i, _ in traj]
    assert 8 in ids, "BENCH_8.json must be committed with this change"
    doc = dict(traj)[8]
    assert doc["schema"] == "bench_serve/v4"
    assert doc["cluster_ok"] and doc["identical_tokens"]
    m = doc["cluster"]["r2"]
    assert m["speedup_tok_s"] > 1.0 and m["p99_ratio"] > 1.0, \
        "cost-aware placement must beat round-robin in the snapshot"
    assert mod.compare(traj, tolerance=0.6) == []


# ---------------------------------------------------------------------------
# topology ranking
# ---------------------------------------------------------------------------


def test_rank_cluster_topologies_orders_and_factors():
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeCell
    from repro.core.costmodel import CostModel
    from repro.sharding.plans import rank_cluster_topologies
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    cell = ShapeCell("t", "decode", 128, 8)
    cm = CostModel.from_named("tpu_v5e")
    tops = rank_cluster_topologies(cfg, cell, 8, cm)
    assert [t.predicted_tok_s for t in tops] == sorted(
        (t.predicted_tok_s for t in tops), reverse=True)
    for t in tops:
        assert 8 % t.n_replicas == 0
        assert t.devices_per_replica * t.n_replicas == 8
        assert t.predicted_tok_s == pytest.approx(
            t.n_replicas * cell.global_batch / t.plan.step_s)
    assert rank_cluster_topologies(cfg, cell, 8, cm, max_replicas=1)[
        0].n_replicas == 1
